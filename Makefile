# cimdse — top-level convenience targets.
#
# `make artifacts` is the one step that needs Python: it lowers the
# JAX/Pallas graphs under python/compile/ to HLO *text* artifacts plus
# the shape-contract manifest (see rust/configs/manifest.example.json),
# which the Rust runtime loads via PJRT. Python never runs after this.

PYTHON ?= python3
ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts build test lint bench ci clean-artifacts

## Lower the JAX graphs to $(ARTIFACTS_DIR)/*.hlo.txt + manifest.json.
artifacts:
	@$(PYTHON) -c "import jax" 2>/dev/null || { \
	  echo "error: 'make artifacts' needs JAX, which this Python cannot import."; \
	  echo "       Install it (e.g. 'pip install jax') or point PYTHON at an"; \
	  echo "       environment that has it: 'make artifacts PYTHON=/path/to/python'."; \
	  echo "       The Rust crate itself builds and tests fine without artifacts:"; \
	  echo "       the PJRT backend self-skips until they exist (rust/README.md)."; \
	  exit 1; } >&2
	cd python && $(PYTHON) -m compile.aot --out-dir $(abspath $(ARTIFACTS_DIR))
	@echo "artifacts ready in ./$(ARTIFACTS_DIR) (manifest + HLO text)"

## Build the Rust crate (release).
build:
	cd rust && cargo build --release

## Tier-1 tests (ROADMAP.md's verify line).
test:
	cd rust && cargo build --release && cargo test -q

## Static invariant checks (rules + suppressions: rust/docs/lints.md).
lint:
	cd rust && cargo run --quiet --release -- lint .

## Quick perf bench in both numeric-tier configurations (portable and,
## on x86_64, the AVX2 `simd` feature), each validated by bench-report.
## Artifacts: rust/BENCH_sweep.json + rust/BENCH_sweep_simd.json
## (schema + tier policy: rust/docs/numeric_tiers.md).
bench:
	cd rust && CIMDSE_BENCH_QUICK=1 cargo bench --bench perf_hotpaths
	cd rust && cargo run --quiet --release -- bench-report --path BENCH_sweep.json
	@if [ "$$(uname -m)" = "x86_64" ]; then \
	  cd rust && CIMDSE_BENCH_QUICK=1 CIMDSE_BENCH_OUT=BENCH_sweep_simd.json \
	    cargo bench --bench perf_hotpaths --features simd && \
	  cargo run --quiet --release -- bench-report --path BENCH_sweep_simd.json; \
	else \
	  echo "make bench: SKIP simd pass — host is $$(uname -m), AVX2 kernel is x86_64-only"; \
	fi

## Full CI: tier-1 + bench/example compile checks + shard and serve
## smoke tests + perf artifacts.
ci:
	./ci.sh

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)
