//! Self-check for `cimdse lint`: every rule is exercised against its
//! known-bad and known-good fixture trees under `tests/lint_fixtures/`
//! (exact finding counts, not just "some findings"), the `--json`
//! report shape is pinned, the real crate tree must be clean, and the
//! protocol error-code registries are asserted identical by direct set
//! comparison — independently of the rule that also checks them.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use cimdse::config::{Value, parse_json};
use cimdse::lint::rules::error_codes;
use cimdse::lint::{LintReport, lint_root, report, rule_names};

/// (fixture dir, rule name, expected findings in the bad tree).
const FIXTURES: &[(&str, &str, usize)] = &[
    ("unsafe_audit", "unsafe-audit", 2),
    ("error_code_registry", "error-code-registry", 3),
    ("float_display", "float-display", 3),
    ("mutex_hold", "mutex-hold", 2),
    ("determinism", "determinism", 6),
    ("dep_hygiene", "dep-hygiene", 5),
];

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_root(name: &str, kind: &str) -> PathBuf {
    crate_root()
        .join("tests")
        .join("lint_fixtures")
        .join(name)
        .join(kind)
}

fn lint(path: &Path) -> LintReport {
    lint_root(path).unwrap_or_else(|e| panic!("lint of {} failed: {e}", path.display()))
}

#[test]
fn every_rule_flags_its_bad_fixture_exactly() {
    for &(dir, rule, expected) in FIXTURES {
        let report = lint(&fixture_root(dir, "bad"));
        let got = report.findings.len();
        assert_eq!(
            got, expected,
            "{dir}/bad: expected {expected} findings, got {got}: {:?}",
            report
                .findings
                .iter()
                .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
        );
        for f in &report.findings {
            assert_eq!(
                f.rule, rule,
                "{dir}/bad: finding from unexpected rule: {}:{} [{}] {}",
                f.file, f.line, f.rule, f.message
            );
        }
    }
}

#[test]
fn every_good_fixture_is_clean() {
    for &(dir, _, _) in FIXTURES {
        let report = lint(&fixture_root(dir, "good"));
        assert!(
            report.findings.is_empty(),
            "{dir}/good: expected 0 findings, got: {:?}",
            report
                .findings
                .iter()
                .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn real_tree_is_clean() {
    let report = lint(&crate_root());
    assert!(
        report.files_scanned >= 60,
        "suspiciously few files scanned ({}) — did the walk break?",
        report.files_scanned
    );
    assert!(
        report.findings.is_empty(),
        "real tree must lint clean; findings: {:?}",
        report
            .findings
            .iter()
            .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
    );
}

#[test]
fn json_report_schema_is_stable() {
    let report = lint(&fixture_root("unsafe_audit", "bad"));
    let json = report::to_json_value(&report).to_json_string().unwrap();
    // must round-trip through the crate's own parser
    let doc = parse_json(&json).unwrap_or_else(|e| panic!("unparsable lint JSON: {e}\n{json}"));
    assert_eq!(doc.get("schema").and_then(Value::as_f64), Some(1.0));
    assert!(doc.get("root").and_then(Value::as_str).is_some());
    let scanned = doc
        .get("files_scanned")
        .and_then(Value::as_f64)
        .expect("files_scanned");
    assert!(scanned >= 1.0);
    let rules = doc.get("rules").and_then(Value::as_array).expect("rules");
    let listed: Vec<&str> = rules
        .iter()
        .map(|r| r.get("name").and_then(Value::as_str).expect("rule name"))
        .collect();
    assert_eq!(listed, rule_names(), "rule list drifted");
    for r in rules {
        assert!(r.get("description").and_then(Value::as_str).is_some());
    }
    let findings = doc
        .get("findings")
        .and_then(Value::as_array)
        .expect("findings");
    assert_eq!(findings.len(), 2);
    for f in findings {
        let Value::Table(map) = f else {
            panic!("finding is not an object")
        };
        let keys: Vec<&str> = map.keys().map(String::as_str).collect();
        assert_eq!(keys, ["file", "line", "message", "rule"], "finding keys drifted");
        assert!(f.get("line").and_then(Value::as_f64).unwrap() >= 1.0);
        assert_eq!(
            f.get("rule").and_then(Value::as_str),
            Some("unsafe-audit")
        );
    }
}

/// The tentpole contract of the `error-code-registry` rule, asserted
/// directly: protocol.rs, docs/protocol.md and the corpus agree on the
/// exact same code set — including `internal` and `over-budget`, the
/// two codes that had drifted before this rule existed.
#[test]
fn error_code_registries_are_identical() {
    let sets = error_codes::code_sets(&crate_root()).expect("all three registries readable");
    let src: BTreeSet<&str> = sets.source.keys().map(String::as_str).collect();
    let docs: BTreeSet<&str> = sets.docs.keys().map(String::as_str).collect();
    let corpus: BTreeSet<&str> = sets.corpus.keys().map(String::as_str).collect();
    assert_eq!(src, docs, "protocol.rs vs docs/protocol.md code sets");
    assert_eq!(src, corpus, "protocol.rs vs corpus expect codes");
    for must in ["internal", "over-budget"] {
        assert!(src.contains(must), "`{must}` missing from protocol.rs");
    }
    assert!(
        src.len() >= 7,
        "expected at least the 7 stable protocol codes, got {src:?}"
    );
}
