//! Property-based round-trip tests for the config/CSV parsing substrates,
//! using the crate's own quickcheck-style harness, plus lossless
//! round-trips of every example spec under `configs/`.

use std::collections::BTreeMap;
use std::path::Path;

use cimdse::config::{Value, parse_json, parse_toml};
use cimdse::survey::parse_survey_csv;
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::testing::{Config, check};
use cimdse::util::Rng;

/// Serialize a Value back to JSON (test-local; the crate only needs the
/// parser at runtime).
fn to_json(v: &Value) -> String {
    match v {
        Value::Null => "null".into(),
        Value::Bool(b) => b.to_string(),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                format!("{}", *n as i64)
            } else {
                format!("{n:e}")
            }
        }
        Value::String(s) => format!(
            "\"{}\"",
            s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
        ),
        Value::Array(items) => {
            format!("[{}]", items.iter().map(to_json).collect::<Vec<_>>().join(","))
        }
        Value::Table(map) => format!(
            "{{{}}}",
            map.iter()
                .map(|(k, v)| format!("\"{}\":{}", k.replace('"', "\\\""), to_json(v)))
                .collect::<Vec<_>>()
                .join(",")
        ),
    }
}

/// Generate a random JSON value of bounded depth.
fn random_value(rng: &mut Rng, depth: usize) -> Value {
    let choice = if depth == 0 { rng.index(4) } else { rng.index(6) };
    match choice {
        0 => Value::Null,
        1 => Value::Bool(rng.bool(0.5)),
        2 => Value::Number((rng.normal(0.0, 1e6) * 1000.0).round() / 1000.0),
        3 => {
            let len = rng.index(12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.range(32, 127) as u8 as char;
                    c
                })
                .collect();
            Value::String(s)
        }
        4 => {
            let len = rng.index(5);
            Value::Array((0..len).map(|_| random_value(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.index(5);
            let mut map = BTreeMap::new();
            for i in 0..len {
                map.insert(format!("k{i}"), random_value(rng, depth - 1));
            }
            Value::Table(map)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    check(Config::default().cases(300), |rng| {
        let v = random_value(rng, 3);
        let text = to_json(&v);
        let parsed = parse_json(&text)
            .unwrap_or_else(|e| panic!("failed to parse {text}: {e}"));
        assert_eq!(parsed, v, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_toml_flat_roundtrip() {
    // Tables of numbers/strings/bools survive a TOML print+parse cycle.
    check(Config::default().cases(200).seed(5), |rng| {
        let n = 1 + rng.index(8);
        let mut doc = String::new();
        let mut expect: Vec<(String, Value)> = Vec::new();
        for i in 0..n {
            let key = format!("key{i}");
            let v = match rng.index(3) {
                0 => Value::Number((rng.normal(0.0, 1e3) * 100.0).round() / 100.0),
                1 => Value::Bool(rng.bool(0.5)),
                _ => Value::String(format!("s{}", rng.index(1000))),
            };
            match &v {
                Value::Number(x) => doc.push_str(&format!("{key} = {x}\n")),
                Value::Bool(b) => doc.push_str(&format!("{key} = {b}\n")),
                Value::String(s) => doc.push_str(&format!("{key} = \"{s}\"\n")),
                _ => unreachable!(),
            }
            expect.push((key, v));
        }
        let parsed = parse_toml(&doc).unwrap();
        for (key, v) in expect {
            assert_eq!(parsed.get(&key), Some(&v), "key {key} in:\n{doc}");
        }
    });
}

#[test]
fn prop_survey_csv_roundtrip_random_subsets() {
    // Any subset of a generated survey round-trips through CSV.
    let full = generate_survey(&SurveyConfig::default());
    check(Config::default().cases(40).seed(9), |rng| {
        let take = 1 + rng.index(50);
        let mut subset = full.clone();
        rng.shuffle(&mut subset.records);
        subset.records.truncate(take);
        let parsed = parse_survey_csv(&subset.to_csv()).unwrap();
        assert_eq!(parsed.len(), take);
        for (a, b) in subset.records.iter().zip(&parsed.records) {
            assert_eq!(a.id, b.id);
            assert!((a.energy_pj - b.energy_pj).abs() / a.energy_pj < 1e-5);
        }
    });
}

/// Randomized strings over an adversarial alphabet (quotes, backslashes,
/// newlines, tabs, comment/array/assignment metacharacters) survive a
/// TOML print+parse cycle bit-for-bit, standalone and inside arrays —
/// the round-trip contract of the subset's `\"` `\\` `\n` `\t` escapes.
#[test]
fn prop_toml_escaped_strings_roundtrip() {
    const ALPHABET: &[char] = &[
        'a', 'Z', '0', ' ', '"', '\\', '\n', '\t', '#', '[', ']', ',', '=', '.', '/', 'é',
    ];
    check(Config::default().cases(400).seed(33), |rng: &mut Rng| {
        let rand_string = |rng: &mut Rng| -> String {
            (0..rng.index(18)).map(|_| ALPHABET[rng.index(ALPHABET.len())]).collect()
        };
        let mut map = BTreeMap::new();
        map.insert("plain".to_string(), Value::String(rand_string(rng)));
        map.insert(
            "arr".to_string(),
            Value::Array(vec![
                Value::String(rand_string(rng)),
                Value::String(rand_string(rng)),
                Value::Number(1.5),
            ]),
        );
        let mut section = BTreeMap::new();
        section.insert("nested".to_string(), Value::String(rand_string(rng)));
        map.insert("sec".to_string(), Value::Table(section));
        let v = Value::Table(map);
        let text = v.to_toml_string().unwrap_or_else(|e| panic!("serialize {v:?}: {e}"));
        let parsed = parse_toml(&text).unwrap_or_else(|e| panic!("parse {text:?}: {e}"));
        assert_eq!(parsed, v, "round-trip mismatch for {text:?}");
    });
}

/// Hand-picked worst cases for the escape scanner: strings that end in
/// backslashes or quotes, and quotes adjacent to comment/array syntax.
#[test]
fn toml_escape_pathological_cases_roundtrip() {
    for s in [
        "", "\\", "\\\\", "\"", "\\\"", "a\\", "\"b", "a\"b\"c", "\n", "\t\n\t", "x#y",
        "a,b]c[", "= \"#\" =", "ends with quote\"", "\"starts with quote",
    ] {
        let mut map = BTreeMap::new();
        map.insert("s".to_string(), Value::String(s.to_string()));
        map.insert(
            "a".to_string(),
            Value::Array(vec![Value::String(s.to_string()), Value::Bool(true)]),
        );
        let v = Value::Table(map);
        let text = v.to_toml_string().unwrap();
        let parsed = parse_toml(&text).unwrap_or_else(|e| panic!("{s:?} via {text:?}: {e}"));
        assert_eq!(parsed, v, "{s:?} via {text:?}");
    }
}

/// Every example spec shipped under `configs/` must parse through the
/// config layer and re-serialize losslessly (value-identical after a
/// second parse). This is the canary for parser/serializer drift.
#[test]
fn config_specs_roundtrip_losslessly() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut checked = 0usize;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        let text = std::fs::read_to_string(&path).unwrap();
        match path.extension().and_then(|e| e.to_str()) {
            Some("toml") => {
                let v = parse_toml(&text).unwrap_or_else(|e| panic!("{path:?}: {e}"));
                let re = v.to_toml_string().unwrap_or_else(|e| panic!("{path:?}: {e}"));
                let v2 = parse_toml(&re).unwrap_or_else(|e| panic!("{path:?} reparse: {e}"));
                assert_eq!(v, v2, "lossy TOML round-trip for {path:?}:\n{re}");
                checked += 1;
            }
            Some("json") => {
                let v = parse_json(&text).unwrap_or_else(|e| panic!("{path:?}: {e}"));
                let re = v.to_json_string().unwrap_or_else(|e| panic!("{path:?}: {e}"));
                let v2 = parse_json(&re).unwrap_or_else(|e| panic!("{path:?} reparse: {e}"));
                assert_eq!(v, v2, "lossy JSON round-trip for {path:?}:\n{re}");
                checked += 1;
            }
            _ => {}
        }
    }
    assert!(checked >= 3, "only {checked} specs found under {dir:?}");
}

/// The example specs are not just parseable — they load through the typed
/// config consumers and match the built-in presets they document.
#[test]
fn config_specs_load_through_typed_consumers() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");

    let arch_text = std::fs::read_to_string(dir.join("raella_m.toml")).unwrap();
    let arch = cimdse::arch::from_toml(&arch_text).unwrap();
    let preset = cimdse::arch::raella::raella(cimdse::arch::raella::RaellaVariant::Medium);
    assert_eq!(arch, preset);

    let wl_text = std::fs::read_to_string(dir.join("lenet.toml")).unwrap();
    let workload = cimdse::workload::zoo::from_toml(&wl_text).unwrap();
    let builtin = cimdse::workload::zoo::lenet();
    assert_eq!(workload.name, builtin.name);
    assert_eq!(workload.layers, builtin.layers);

    let manifest_text = std::fs::read_to_string(dir.join("manifest.example.json")).unwrap();
    let doc = parse_json(&manifest_text).unwrap();
    assert_eq!(doc.require_str("adc_model.file").unwrap(), "adc_model.hlo.txt");
    assert_eq!(doc.require_usize("adc_model.batch").unwrap(), 4096);
    assert_eq!(doc.require_usize("crossbar.n_sum").unwrap(), 128);
    let coefs = doc.get("adc_model.default_coefs").unwrap().as_array().unwrap();
    let truth = cimdse::adc::Coefficients::generator_truth().to_vec();
    assert_eq!(coefs.len(), truth.len());
    for (i, (c, t)) in coefs.iter().zip(&truth).enumerate() {
        assert!((c.as_f64().unwrap() - t).abs() < 1e-3, "coef {i}");
    }
}

/// The serializer matches the hand-rolled property-test serializer on
/// random values (two independent implementations agreeing).
#[test]
fn prop_value_to_json_string_roundtrips() {
    check(Config::default().cases(300).seed(21), |rng: &mut Rng| {
        let v = random_value(rng, 3);
        let text = v.to_json_string().unwrap();
        let parsed =
            parse_json(&text).unwrap_or_else(|e| panic!("failed to parse {text}: {e}"));
        assert_eq!(parsed, v, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    // Fuzz-ish: arbitrary byte soup must produce Ok or Err, never a panic.
    check(Config::default().cases(500).seed(13), |rng| {
        let len = rng.index(64);
        let soup: String = (0..len)
            .map(|_| {
                // Mix of JSON-ish characters and noise.
                const CHARS: &[u8] = b"{}[]\",:0123456789.eE+-truefalsnl \n\t\\";
                CHARS[rng.index(CHARS.len())] as char
            })
            .collect();
        let _ = parse_json(&soup); // must not panic
        let _ = parse_toml(&soup);
    });
}
