//! Integration tests over the PJRT runtime: the AOT artifacts (lowered
//! from JAX/Pallas by `make artifacts`) must agree with the native Rust
//! model — the contract that lets the DSE engine use either backend.
//!
//! These tests PASS with a printed `SKIP` notice on any fresh checkout:
//! when `artifacts/manifest.json` has not been built, or when the crate
//! was compiled without the `pjrt` feature (the default, where the
//! runtime backend is a stub that errors at engine-load time).

use cimdse::adc::tuning::TuningPoint;
use cimdse::adc::{AdcModel, AdcQuery, Coefficients, fit_model};
use cimdse::dse::{NativeEvaluator, PjrtEvaluator, SweepSpec, run_sweep};
use cimdse::runtime::{AdcModelEngine, CimMlpEngine, CrossbarEngine, Manifest};
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::Rng;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::locate() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (artifacts not built): {e}");
            None
        }
    }
}

/// Load an engine from the manifest, or skip (pass with a notice) when
/// the backend is unavailable — e.g. built without the `pjrt` feature.
fn load_or_skip<T>(
    manifest: &Manifest,
    load: impl FnOnce(&Manifest) -> cimdse::Result<T>,
) -> Option<T> {
    match load(manifest) {
        Ok(engine) => Some(engine),
        Err(e) => {
            eprintln!("SKIP (PJRT backend unavailable): {e}");
            None
        }
    }
}

fn sample_queries(n: usize, seed: u64) -> Vec<AdcQuery> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| AdcQuery {
            enob: rng.uniform(2.0, 14.0),
            total_throughput: 10f64.powf(rng.uniform(4.0, 10.5)),
            tech_nm: *rng.choice(&[16.0, 22.0, 32.0, 65.0, 130.0]),
            n_adcs: rng.range(1, 33) as u32,
        })
        .collect()
}

#[test]
fn adc_artifact_matches_native_model_on_default_coefs() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(engine) = load_or_skip(&manifest, AdcModelEngine::load) else { return };
    let model = AdcModel::default();
    let queries = sample_queries(1000, 7);

    let native: Vec<_> = queries.iter().map(|q| model.eval(q)).collect();
    let pjrt = engine.eval(&queries, &model.coefs).unwrap();

    assert_eq!(native.len(), pjrt.len());
    for (i, (n, p)) in native.iter().zip(&pjrt).enumerate() {
        // Artifact computes in f32; allow f32-level relative error.
        let rel = |a: f64, b: f64| (a - b).abs() / a.abs().max(1e-30);
        assert!(
            rel(n.energy_pj_per_convert, p.energy_pj_per_convert) < 1e-4,
            "energy mismatch at {i}: {n:?} vs {p:?} ({:?})",
            queries[i]
        );
        assert!(
            rel(n.area_um2_per_adc, p.area_um2_per_adc) < 1e-4,
            "area mismatch at {i}"
        );
        assert!(rel(n.total_power_w, p.total_power_w) < 1e-3, "power mismatch at {i}");
        assert!(rel(n.total_area_um2, p.total_area_um2) < 1e-3, "total area at {i}");
    }
}

#[test]
fn adc_artifact_matches_fitted_and_tuned_models() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(engine) = load_or_skip(&manifest, AdcModelEngine::load) else { return };

    // Fit on the synthetic survey, then tune to a reference point: the
    // artifact must track both through the folded coefficients.
    let survey = generate_survey(&SurveyConfig::default());
    let fitted = AdcModel::new(fit_model(&survey).unwrap().coefs);
    let tuned = fitted.tuned_to(&TuningPoint {
        query: AdcQuery { enob: 7.0, total_throughput: 1e9, tech_nm: 32.0, n_adcs: 1 },
        energy_pj_per_convert: 2.5,
        area_um2: Some(4.2e4),
    });

    for model in [fitted, tuned] {
        let queries = sample_queries(300, 11);
        let native: Vec<_> = queries.iter().map(|q| model.eval(q)).collect();
        let pjrt = engine.eval(&queries, &model.folded_coefficients()).unwrap();
        for (n, p) in native.iter().zip(&pjrt) {
            let rel =
                (n.energy_pj_per_convert - p.energy_pj_per_convert).abs() / n.energy_pj_per_convert;
            assert!(rel < 1e-4, "{n:?} vs {p:?}");
        }
    }
}

#[test]
fn pjrt_evaluator_handles_partial_batches() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(engine) = load_or_skip(&manifest, AdcModelEngine::load) else { return };
    let batch = engine.batch_size();
    let model = AdcModel::default();

    // 1 query, batch-1, batch, batch+1: all must round-trip exactly.
    for n in [1usize, batch - 1, batch, batch + 1] {
        let queries = sample_queries(n, n as u64);
        let out = engine.eval(&queries, &model.coefs).unwrap();
        assert_eq!(out.len(), n, "padding broke result length for n={n}");
        let native = model.eval(&queries[n - 1]);
        let rel = (out[n - 1].energy_pj_per_convert - native.energy_pj_per_convert).abs()
            / native.energy_pj_per_convert;
        assert!(rel < 1e-4);
    }
}

#[test]
fn sweep_backends_agree() {
    let Some(manifest) = manifest_or_skip() else { return };
    let model = AdcModel::default();
    let spec = SweepSpec {
        enobs: vec![4.0, 7.0, 12.0],
        total_throughputs: vec![1.3e9, 1e10, 4e10],
        tech_nms: vec![32.0, 65.0],
        n_adcs: vec![1, 4, 16],
    };
    let native = run_sweep(&spec, &NativeEvaluator::new(model)).unwrap();
    let Some(engine) = load_or_skip(&manifest, AdcModelEngine::load) else { return };
    let pjrt = run_sweep(&spec, &PjrtEvaluator::new(engine, model)).unwrap();
    assert_eq!(native.len(), pjrt.len());
    for (a, b) in native.iter().zip(&pjrt) {
        assert_eq!(a.query, b.query);
        let rel = (a.metrics.energy_pj_per_convert - b.metrics.energy_pj_per_convert).abs()
            / a.metrics.energy_pj_per_convert;
        assert!(rel < 1e-4);
    }
}

// ---------------------------------------------------------------------------
// Functional CiM datapath artifacts
// ---------------------------------------------------------------------------

/// Native mirror of the crossbar kernel (bit-sliced, per-chunk ADC
/// quantization) — the Rust-side oracle for the HLO artifact.
fn cim_matmul_native(
    x: &[f32],
    w: &[f32],
    b: usize,
    in_dim: usize,
    out_dim: usize,
    n_sum: usize,
    x_bits: u32,
    cell_bits: u32,
    step: f32,
) -> Vec<f32> {
    let full_scale = (n_sum as f32) * ((1u32 << cell_bits) - 1) as f32;
    let w_levels = (1u32 << cell_bits) as f32;
    let mut y = vec![0f32; b * out_dim];
    let n_chunks = in_dim / n_sum;
    for s in 0..x_bits {
        for ci in 0..2u32 {
            for row in 0..b {
                for col in 0..out_dim {
                    let mut acc = 0f32;
                    for chunk in 0..n_chunks {
                        let mut analog = 0f32;
                        for r in chunk * n_sum..(chunk + 1) * n_sum {
                            let xv = x[row * in_dim + r];
                            let x_bit = ((xv / (1u32 << s) as f32).floor()) % 2.0;
                            let wv = w[r * out_dim + col];
                            let w_slice = if ci == 0 {
                                wv % w_levels
                            } else {
                                (wv / w_levels).floor()
                            };
                            analog += x_bit * w_slice;
                        }
                        let clipped = analog.clamp(0.0, full_scale);
                        // jnp.round is round-half-to-even; match it.
                        acc += (clipped / step).round_ties_even() * step;
                    }
                    y[row * out_dim + col] +=
                        2f32.powi((s + cell_bits * ci) as i32) * acc;
                }
            }
        }
    }
    y
}

#[test]
fn crossbar_artifact_matches_native_bit_sliced_matmul() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(engine) = load_or_skip(&manifest, CrossbarEngine::load) else { return };
    let (b, i, o) = engine.shape;
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..b * i).map(|_| rng.range(0, 16) as f32).collect();
    let w: Vec<f32> = (0..i * o).map(|_| rng.range(0, 16) as f32).collect();

    for step in [1.0f32, 2.0, 6.0] {
        let got = engine.run(&x, &w, step).unwrap();
        let want = cim_matmul_native(&x, &w, b, i, o, engine.n_sum, 4, 2, step);
        assert_eq!(got.len(), want.len());
        for (g, wv) in got.iter().zip(&want) {
            assert!((g - wv).abs() <= 1e-2 * wv.abs().max(1.0), "step={step}: {g} vs {wv}");
        }
    }
}

#[test]
fn crossbar_artifact_with_unit_step_is_lossless() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(engine) = load_or_skip(&manifest, CrossbarEngine::load) else { return };
    let (b, i, o) = engine.shape;
    let mut rng = Rng::new(43);
    let x: Vec<f32> = (0..b * i).map(|_| rng.range(0, 16) as f32).collect();
    let w: Vec<f32> = (0..i * o).map(|_| rng.range(0, 16) as f32).collect();
    let got = engine.run(&x, &w, 1.0).unwrap();
    // Exact integer matmul.
    for row in 0..b {
        for col in 0..o {
            let exact: f32 = (0..i).map(|r| x[row * i + r] * w[r * o + col]).sum();
            let g = got[row * o + col];
            assert!((g - exact).abs() < 1e-1, "({row},{col}): {g} vs {exact}");
        }
    }
}

#[test]
fn mlp_artifact_runs_and_padded_classes_are_zero() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(engine) = load_or_skip(&manifest, CimMlpEngine::load) else { return };
    let (b, i, h, o) = engine.shape;
    let mut rng = Rng::new(44);
    let x: Vec<f32> = (0..b * i).map(|_| rng.range(0, 16) as f32).collect();
    let w1: Vec<f32> = (0..i * h).map(|_| rng.range(0, 16) as f32).collect();
    let mut w2: Vec<f32> = (0..h * o).map(|_| rng.range(0, 16) as f32).collect();
    // Zero the padded class columns (10..16).
    for row in 0..h {
        for col in 10..o {
            w2[row * o + col] = 0.0;
        }
    }
    let logits = engine.forward(&x, &w1, &w2, 1.0, 1.0, 0.002).unwrap();
    assert_eq!(logits.len(), b * o);
    assert!(logits.iter().all(|v| v.is_finite()));
    for row in 0..b {
        for col in 10..o {
            assert_eq!(logits[row * o + col], 0.0, "padded class leaked at ({row},{col})");
        }
    }
    // Some real logit must be non-zero.
    assert!(logits.iter().any(|&v| v > 0.0));
}

#[test]
fn manifest_coefs_match_rust_defaults() {
    // The artifact's baked default coefficients are the generator truth —
    // one contract, two languages (python/compile/coeffs.py vs
    // adc::Coefficients::generator_truth).
    let Some(manifest) = manifest_or_skip() else { return };
    let defaults = manifest
        .doc
        .get("adc_model.default_coefs")
        .and_then(|v| v.as_array())
        .expect("manifest missing default_coefs");
    let truth = Coefficients::generator_truth().to_vec();
    assert_eq!(defaults.len(), truth.len());
    for (i, (d, t)) in defaults.iter().zip(&truth).enumerate() {
        let d = d.as_f64().unwrap();
        assert!((d - t).abs() < 1e-3, "coef {i}: python {d} vs rust {t}");
    }
}
