//! Property-based tests on model/mapper/rollup invariants, using the
//! crate's own mini property-testing substrate.

use cimdse::adc::{AdcModel, AdcQuery};
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::energy::{AreaScope, accel_area, layer_energy};
use cimdse::mapper::map_layer;
use cimdse::testing::{Config, check};
use cimdse::util::Rng;
use cimdse::workload::Layer;

fn random_query(rng: &mut Rng) -> AdcQuery {
    AdcQuery {
        enob: rng.uniform(1.5, 15.0),
        total_throughput: 10f64.powf(rng.uniform(4.0, 10.5)),
        tech_nm: rng.uniform(8.0, 500.0),
        n_adcs: rng.range(1, 65) as u32,
    }
}

fn random_layer(rng: &mut Rng) -> Layer {
    Layer::conv(
        "prop",
        rng.range(1, 513) as usize,
        rng.range(1, 513) as usize,
        *rng.choice(&[1usize, 3, 5, 7]),
        *rng.choice(&[1usize, 3, 5, 7]),
        rng.range(1, 57) as usize,
        rng.range(1, 57) as usize,
    )
}

#[test]
fn prop_metrics_always_positive_and_finite() {
    let model = AdcModel::default();
    check(Config::default().cases(500), |rng| {
        let q = random_query(rng);
        let m = model.eval(&q);
        assert!(m.energy_pj_per_convert.is_finite() && m.energy_pj_per_convert > 0.0);
        assert!(m.area_um2_per_adc.is_finite() && m.area_um2_per_adc > 0.0);
        assert!(m.total_power_w.is_finite() && m.total_power_w > 0.0);
        assert!(m.total_area_um2.is_finite() && m.total_area_um2 > 0.0);
    });
}

#[test]
fn prop_energy_monotone_in_enob() {
    let model = AdcModel::default();
    check(Config::default().cases(300), |rng| {
        let q = random_query(rng);
        let hi = AdcQuery { enob: q.enob + rng.uniform(0.1, 3.0), ..q };
        assert!(
            model.energy_pj_per_convert(&hi) > model.energy_pj_per_convert(&q),
            "energy not increasing in ENOB at {q:?}"
        );
    });
}

#[test]
fn prop_energy_monotone_in_throughput_and_tech() {
    let model = AdcModel::default();
    check(Config::default().cases(300).seed(1), |rng| {
        let q = random_query(rng);
        let faster = AdcQuery { total_throughput: q.total_throughput * 3.0, ..q };
        assert!(model.energy_pj_per_convert(&faster) >= model.energy_pj_per_convert(&q));
        let bigger = AdcQuery { tech_nm: q.tech_nm * 2.0, ..q };
        assert!(model.energy_pj_per_convert(&bigger) > model.energy_pj_per_convert(&q));
    });
}

#[test]
fn prop_more_adcs_never_increase_per_convert_energy() {
    let model = AdcModel::default();
    check(Config::default().cases(300).seed(2), |rng| {
        let q = random_query(rng);
        let more = AdcQuery { n_adcs: q.n_adcs * 2, ..q };
        assert!(model.energy_pj_per_convert(&more) <= model.energy_pj_per_convert(&q) * (1.0 + 1e-12));
        // ...but total area grows (each ADC may shrink, yet count doubles
        // and per-ADC area shrinks sublinearly: area ~ f^0.2 E^0.3).
        assert!(model.eval(&more).total_area_um2 >= model.eval(&q).total_area_um2 * 0.999);
    });
}

#[test]
fn prop_area_monotone_in_energy_via_eq1() {
    // Eq. 1 has positive exponents: at fixed tech/throughput, higher-ENOB
    // (=> higher-energy) ADCs are larger.
    let model = AdcModel::default();
    check(Config::default().cases(300).seed(3), |rng| {
        let q = random_query(rng);
        let hi = AdcQuery { enob: (q.enob + 2.0).min(16.0), ..q };
        assert!(model.area_um2_per_adc(&hi) > model.area_um2_per_adc(&q));
    });
}

#[test]
fn prop_mapping_conservation_laws() {
    check(Config::default().cases(300).seed(4), |rng| {
        let variant = *rng.choice(&RaellaVariant::ALL);
        let arch = raella(variant);
        let layer = random_layer(rng);
        let m = map_layer(&arch, &layer).unwrap();

        // Utilization in (0, 1].
        assert!(m.utilization > 0.0 && m.utilization <= 1.0);
        // Chunk covering: chunks * sum_size >= rows > (chunks-1) * sum_size.
        let rows = layer.weight_rows();
        assert!(m.row_chunks * arch.sum_size >= rows);
        assert!((m.row_chunks - 1) * arch.sum_size < rows);
        // Every MAC is computed: cell_reads = MACs * planes * col_slices.
        let expect =
            layer.macs() as f64 * arch.planes() as f64 * arch.col_slices() as f64;
        assert!((m.counts.cell_reads - expect).abs() / expect < 1e-9);
        // ADC converts >= one per (position, plane, column).
        let floor = layer.output_positions() as f64
            * arch.planes() as f64
            * (layer.weight_cols() * arch.col_slices()) as f64;
        assert!(m.counts.adc_converts >= floor - 1e-9);
        // Arrays hold the weights.
        assert!(
            m.arrays_used * arch.array_rows * arch.array_cols
                >= layer.weights() * arch.col_slices()
        );
    });
}

#[test]
fn prop_energy_rollup_dominates_its_parts_and_scales() {
    let model = AdcModel::default();
    check(Config::default().cases(200).seed(5), |rng| {
        let arch = raella(*rng.choice(&RaellaVariant::ALL));
        let layer = random_layer(rng);
        let e = layer_energy(&arch, &model, &layer).unwrap();
        assert!(e.total_pj() >= e.adc_pj);
        assert!(e.adc_fraction() > 0.0 && e.adc_fraction() < 1.0);

        // Doubling output positions ~doubles every energy component.
        let double = Layer { q: layer.q * 2, ..layer.clone() };
        let e2 = layer_energy(&arch, &model, &double).unwrap();
        let ratio = e2.total_pj() / e.total_pj();
        assert!((ratio - 2.0).abs() < 1e-9, "ratio {ratio}");
    });
}

#[test]
fn prop_area_scope_monotone_in_arrays() {
    let model = AdcModel::default();
    check(Config::default().cases(200).seed(6), |rng| {
        let arch = raella(*rng.choice(&RaellaVariant::ALL));
        let n = 1 + rng.index(64);
        let a1 = accel_area(&arch, &model, AreaScope::ArrayGroup { n_arrays: n });
        let a2 = accel_area(&arch, &model, AreaScope::ArrayGroup { n_arrays: n + 1 });
        assert!(a2.total_um2() > a1.total_um2());
        // ADC area does not depend on array count.
        assert_eq!(a1.adc_um2, a2.adc_um2);
    });
}

#[test]
fn prop_tuning_is_idempotent_and_exact() {
    let base = AdcModel::default();
    check(Config::default().cases(200).seed(7), |rng| {
        let q = random_query(rng);
        let target_e = base.energy_pj_per_convert(&q) * rng.log10_normal(0.0, 0.5);
        let target_a = base.area_um2_per_adc(&q) * rng.log10_normal(0.0, 0.5);
        let point = cimdse::adc::tuning::TuningPoint {
            query: q,
            energy_pj_per_convert: target_e,
            area_um2: Some(target_a),
        };
        let tuned = base.tuned_to(&point);
        assert!((tuned.energy_pj_per_convert(&q) - target_e).abs() / target_e < 1e-9);
        assert!((tuned.area_um2_per_adc(&q) - target_a).abs() / target_a < 1e-9);
        // Tuning again to the same point changes nothing.
        let twice = tuned.tuned_to(&point);
        assert!((twice.energy_offset_decades - tuned.energy_offset_decades).abs() < 1e-9);
        assert!((twice.area_offset_decades - tuned.area_offset_decades).abs() < 1e-9);
    });
}
