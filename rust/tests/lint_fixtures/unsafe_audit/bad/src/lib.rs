//! Known-bad fixture for the `unsafe-audit` rule: two undocumented
//! `unsafe` sites (a block and a fn). The lint must emit exactly two
//! findings here — and must not count this doc comment's own mention
//! of `unsafe` as a third.

pub fn read_first(data: &[f32]) -> f32 {
    let p = data.as_ptr();
    unsafe { *p }
}

pub unsafe fn assume_positive(x: *const u32) -> u32 {
    *x
}
