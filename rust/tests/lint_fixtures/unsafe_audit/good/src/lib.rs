//! Known-good fixture for the `unsafe-audit` rule: every `unsafe` is
//! either documented with `// SAFETY:` within the five preceding lines
//! or explicitly suppressed with an inline `lint:allow`. The word
//! "unsafe" in comments and string literals must not trip the rule.

pub fn read_first(data: &[f32]) -> f32 {
    let p = data.as_ptr();
    // SAFETY: the caller's contract guarantees `data` is non-empty, so
    // reading one element at its base pointer stays in bounds.
    unsafe { *p }
}

pub fn spelled_out() -> &'static str {
    "this string mentions unsafe but is not code"
}

pub fn suppressed(x: &u32) -> u32 {
    // lint:allow(unsafe-audit) — suppression-syntax demo; the
    // justification for this site lives in the module docs instead.
    unsafe { *(x as *const u32) }
}
