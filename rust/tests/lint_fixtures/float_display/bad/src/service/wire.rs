//! Known-bad fixture for the `float-display` rule: three sites where
//! an f64/f32 reaches Display/Debug formatting or `to_string()` on a
//! wire-shaped path.

pub fn encode_energy(energy_pj: f64) -> String {
    format!("{}", energy_pj)
}

pub fn encode_ratio(ratio: f32) -> String {
    ratio.to_string()
}

pub fn debug_line(enob: f64) -> String {
    format!("enob={enob:?} done")
}
