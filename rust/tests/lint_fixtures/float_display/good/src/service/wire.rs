//! Known-good fixture for the `float-display` rule: floats reach the
//! wire as IEEE-754 bit-hex, human display uses explicit precision
//! specs (intentional, lossy-by-design output), and one audited site
//! is suppressed inline.

pub fn encode_energy(energy_pj: f64) -> String {
    format!("{:016x}", energy_pj.to_bits())
}

pub fn human_row(energy_pj: f64, area_um2: f64) -> String {
    format!("{energy_pj:.3} pJ, {area_um2:.1} um^2")
}

pub fn audited(count: f64) -> String {
    // lint:allow(float-display) — `count` is an integral counter
    // carried as f64; its shortest-decimal Display form is exact.
    format!("{count} points")
}
