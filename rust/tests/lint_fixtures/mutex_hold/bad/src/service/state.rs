//! Known-bad fixture for the `mutex-hold` rule: I/O and quantile
//! computation while a mutex guard is lexically alive.

use std::io::Write;
use std::sync::Mutex;

pub fn flush_under_lock(counters: &Mutex<Vec<u64>>, out: &mut impl Write) {
    let guard = counters.lock().unwrap();
    writeln!(out, "count={}", guard.len()).unwrap();
}

pub fn quantile_under_lock(latencies: &Mutex<Vec<f64>>) -> f64 {
    let samples = latencies.lock().unwrap();
    quantile(&samples, 0.99)
}

fn quantile(xs: &[f64], _q: f64) -> f64 {
    xs.first().copied().unwrap_or(0.0)
}
