//! Known-good fixture for the `mutex-hold` rule: the guard lives in an
//! inner block that ends before any I/O or quantile computation — the
//! clone is taken under the lock, everything expensive happens after
//! the guard is dropped.

use std::io::Write;
use std::sync::Mutex;

pub fn snapshot(latencies: &Mutex<Vec<f64>>, out: &mut impl Write) -> f64 {
    let samples = {
        let guard = latencies.lock().unwrap();
        guard.clone()
    };
    let p99 = quantile(&samples, 0.99);
    writeln!(out, "p99={p99:.6}").unwrap();
    p99
}

fn quantile(xs: &[f64], _q: f64) -> f64 {
    xs.first().copied().unwrap_or(0.0)
}
