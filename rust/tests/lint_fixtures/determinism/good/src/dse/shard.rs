//! Known-good fixture for the `determinism` rule: ordered maps only,
//! and the one timing read is explicitly suppressed with its
//! justification.

pub fn artifact_keys() -> Vec<String> {
    let mut keys = std::collections::BTreeMap::new();
    keys.insert("a".to_string(), 1.0_f64);
    keys.into_keys().collect()
}

pub fn observability_latency() -> f64 {
    // lint:allow(determinism) — log-only latency probe; the reading is
    // never serialized into an artifact or response.
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
