//! Known-bad fixture for the `determinism` rule: wall-clock reads and
//! an unordered map on a fingerprinted artifact path. Exactly three
//! findings.

pub fn artifact_stamp() -> (usize, f64) {
    let t0 = std::time::Instant::now();
    let wall = std::time::SystemTime::now();
    let mut keys = std::collections::HashMap::new();
    keys.insert("a", 1.0_f64);
    let _ = wall;
    (keys.len(), t0.elapsed().as_secs_f64())
}
