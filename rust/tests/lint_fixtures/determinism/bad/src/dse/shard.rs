//! Known-bad fixture for the `determinism` rule: wall-clock reads, an
//! unordered map, ULP-bounded fast-tier math, and a trace span on a
//! fingerprinted artifact path. Exactly six findings.

pub fn artifact_stamp() -> (usize, f64) {
    let t0 = std::time::Instant::now();
    let wall = std::time::SystemTime::now();
    let mut keys = std::collections::HashMap::new();
    keys.insert("a", 1.0_f64);
    let _ = wall;
    (keys.len(), t0.elapsed().as_secs_f64())
}

pub fn approximate_fingerprint(x: f64) -> f64 {
    let e = crate::util::fastmath::exp2_fast(x);
    let lanes = PreparedRowLanes::gather_stub(e);
    e + lanes
}

pub fn traced_fingerprint() -> u64 {
    let span = crate::obs::span("fingerprint");
    span.ctx().span_id
}
