//! Known-good fixture (dep-hygiene): every `xla::` reference sits on a
//! `#[cfg(feature = "pjrt")]`-gated item, and the backend module is
//! gated in runtime/mod.rs.

pub mod runtime;

#[cfg(feature = "pjrt")]
pub fn backend_error_name(e: &xla::Error) -> String {
    format!("{e:?}")
}
