//! Backend module — exempt from the `xla`-reference check.

pub fn platform_name() -> &'static str {
    "cpu"
}
