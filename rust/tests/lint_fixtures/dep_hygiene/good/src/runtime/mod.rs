//! Known-good fixture (dep-hygiene): the backend module only compiles
//! with the `pjrt` feature.

#[cfg(feature = "pjrt")]
pub mod pjrt;
