//! Known-bad fixture (dep-hygiene): `xla::` referenced with no
//! `#[cfg(feature = "pjrt")]` gate on the enclosing item.

pub mod runtime;

pub fn backend_error_name(e: &xla::Error) -> String {
    format!("{e:?}")
}
