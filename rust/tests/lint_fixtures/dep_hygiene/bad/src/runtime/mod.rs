//! Known-bad fixture (dep-hygiene): `mod pjrt` is compiled
//! unconditionally instead of behind `#[cfg(feature = "pjrt")]`.

pub mod pjrt;
