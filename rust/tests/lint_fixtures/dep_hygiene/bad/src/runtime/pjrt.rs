//! Backend module itself — exempt from the `xla`-reference check (it
//! is the one place the bridge is allowed to live).

pub fn platform_name() -> &'static str {
    "cpu"
}
