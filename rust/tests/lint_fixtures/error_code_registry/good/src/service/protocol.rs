//! Known-good fixture for the `error-code-registry` rule: the two
//! codes defined here are exactly the documented set, and each has a
//! corpus case.

/// First stable code.
pub const CODE_ALPHA: &str = "alpha-code";
/// Second stable code.
pub const CODE_BETA: &str = "beta-code";
