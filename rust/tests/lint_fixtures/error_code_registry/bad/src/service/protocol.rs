//! Known-bad fixture for the `error-code-registry` rule. Exactly three
//! findings: `extra-code` is defined here but undocumented,
//! `lost-code` has no corpus case, and `ghost-code` is documented but
//! not defined.

/// Shared happy-path code: documented and corpus-covered.
pub const CODE_SHARED: &str = "shared-code";
/// Defined but missing from docs/protocol.md.
pub const CODE_EXTRA: &str = "extra-code";
/// Defined and documented, but no corpus case exercises it.
pub const CODE_LOST: &str = "lost-code";
