//! Multi-process shard/merge round-trip: the acceptance contract of the
//! sharded sweep driver.
//!
//! For two non-trivial specs, N ∈ {1, 3, 7} separate `cimdse sweep
//! --shard i/N` *process* invocations followed by a merge must reproduce
//! the single-process streaming results bit-identically (`to_bits`-level
//! for every payload float, byte-level for the canonical summary JSON).
//! Also covers resume semantics (a completed artifact is detected by
//! fingerprint and skipped; a deleted one is rebuilt) and the negative
//! paths: malformed `--shard` specs, missing files, and
//! fingerprint-mismatched artifacts are typed errors, never panics.

use std::path::{Path, PathBuf};
use std::process::Command;

use cimdse::adc::{AdcModel, fit_model};
use cimdse::dse::{
    ShardArtifact, SnrContext, SweepSpec, SweepSummary, merge_shards,
    sweep_energy_area_snr_front, sweep_min_eap, sweep_power_area_front,
};
use cimdse::survey::generator::{SurveyConfig, generate_survey};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_cimdse")
}

/// Fresh per-test scratch directory (unique per process and tag so
/// `cargo test` threads cannot collide).
fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cimdse_shard_rt_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the binary expecting success; returns stdout.
fn run_ok(args: &[&str]) -> String {
    let out = Command::new(bin()).args(args).output().unwrap();
    assert!(
        out.status.success(),
        "cimdse {args:?} failed\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Run the binary expecting a *typed* failure: nonzero exit that is not
/// the panic code (101), an `error:` line on stderr, and no panic trace.
fn run_err(args: &[&str]) -> String {
    let out = Command::new(bin()).args(args).output().unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(!out.status.success(), "cimdse {args:?} unexpectedly succeeded");
    assert_ne!(out.status.code(), Some(101), "cimdse {args:?} panicked: {stderr}");
    assert!(stderr.contains("error:"), "cimdse {args:?} stderr lacks `error:`: {stderr}");
    assert!(!stderr.contains("panicked"), "cimdse {args:?} panicked: {stderr}");
    stderr
}

/// The model the binary's `sweep` subcommand uses with default flags
/// (`--n 700 --seed 1997`) — the library-side reference must be built
/// from the identical fit for bit-comparisons to be meaningful.
fn cli_model() -> AdcModel {
    let survey = generate_survey(&SurveyConfig {
        n_records: 700,
        seed: 1997,
        ..SurveyConfig::default()
    });
    AdcModel::new(fit_model(&survey).unwrap().coefs)
}

/// The two sweep grids under test, as (tag, CLI flags, library spec).
fn test_specs() -> Vec<(&'static str, Vec<&'static str>, SweepSpec)> {
    vec![
        // 5×5×4×6 = 600-point dense interpolation grid.
        ("dense5", vec!["--spec", "dense", "--points", "5"], SweepSpec::dense(5)),
        // 1×6×1×5 = 30-point Fig. 5 grid (7 shards ⇒ uneven 5/5/4/4/4/4/4 split).
        ("fig5", vec!["--spec", "fig5", "--enob", "7", "--tsteps", "6"], SweepSpec::fig5(7.0, 6)),
    ]
}

fn shard_files(dir: &Path, n: usize) -> Vec<String> {
    (0..n).map(|i| dir.join(format!("shard_{i}.json")).to_str().unwrap().to_string()).collect()
}

fn run_shard(cli: &[&str], shard: &str, out: &str) -> String {
    let mut args = vec!["sweep"];
    args.extend_from_slice(cli);
    args.extend_from_slice(&["--shard", shard, "--out", out]);
    run_ok(&args)
}

#[test]
fn multi_process_shards_merge_bit_identical_for_1_3_7() {
    let model = cli_model();
    for (tag, cli, spec) in test_specs() {
        let reference = SweepSummary::compute(&spec, &model, 4);
        let ref_json = reference.to_json_string().unwrap();
        // The reference summary itself matches the public streaming
        // entry points (guards against the summary fold drifting).
        assert_eq!(reference.count(), spec.len());
        assert_eq!(reference.front_indices(), sweep_power_area_front(&spec, &model, 4));
        let brute = sweep_min_eap(&spec, &model, 1).unwrap();
        assert_eq!(reference.min_eap().unwrap().metrics.to_bits(), brute.metrics.to_bits());

        for n in [1usize, 3, 7] {
            let dir = tmpdir(&format!("{tag}_{n}"));
            let files = shard_files(&dir, n);
            for (i, out) in files.iter().enumerate() {
                let stdout = run_shard(&cli, &format!("{i}/{n}"), out);
                assert!(
                    stdout.contains(&format!("shard {i}/{n}")),
                    "{tag} {i}/{n}: {stdout}"
                );
            }

            // Library-level merge in reversed order: bit-identical to the
            // single-process streaming rollup.
            let mut artifacts: Vec<ShardArtifact> =
                files.iter().map(|p| ShardArtifact::load(p).unwrap()).collect();
            artifacts.reverse();
            let merged = merge_shards(&artifacts).unwrap();
            assert!(merged.is_complete(), "{tag} n={n}");
            assert_eq!(
                merged.summary.to_json_string().unwrap(),
                ref_json,
                "{tag} n={n}: merged summary must be bit-identical"
            );
            let m = merged.summary.min_eap().unwrap();
            assert_eq!(m.query, brute.query, "{tag} n={n}");
            assert_eq!(m.metrics.to_bits(), brute.metrics.to_bits(), "{tag} n={n}");

            // Binary-level round-trip: `merge-shards --out` and the
            // single-process `sweep --summary-json` write byte-identical
            // files.
            let merged_path = dir.join("merged.json");
            let mut margs = vec!["merge-shards"];
            margs.extend(files.iter().map(String::as_str));
            let merged_str = merged_path.to_str().unwrap();
            margs.extend_from_slice(&["--out", merged_str]);
            run_ok(&margs);

            let single_path = dir.join("single.json");
            let single_str = single_path.to_str().unwrap();
            let mut sargs = vec!["sweep"];
            sargs.extend_from_slice(&cli);
            sargs.extend_from_slice(&["--summary-json", single_str]);
            run_ok(&sargs);

            let merged_bytes = std::fs::read(&merged_path).unwrap();
            let single_bytes = std::fs::read(&single_path).unwrap();
            assert_eq!(merged_bytes, single_bytes, "{tag} n={n}: file bytes must match");
            assert_eq!(
                String::from_utf8(single_bytes).unwrap(),
                format!("{ref_json}\n"),
                "{tag} n={n}: binary summary must equal the library reference"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

/// Tri-objective (`--objectives energy,area,snr`) multi-process
/// round-trip: shard processes + `merge-shards` reproduce the
/// single-process `--summary-json` byte-for-byte, the snr-aware
/// fingerprint keeps tri and classic artifacts of the same grid from
/// resuming or merging into each other, and the snr flag surface is
/// validated with typed errors.
#[test]
fn tri_objective_shards_merge_bit_identical_and_never_mix_with_classic() {
    let model = cli_model();
    let spec = SweepSpec::dense(5);
    let ctx = SnrContext { n_sum: 2048, cell_bits: 3 };
    let reference = SweepSummary::compute_with(&spec, &model, 4, Some(ctx));
    let ref_json = reference.to_json_string().unwrap();
    // The summary's tri front matches the public streaming entry point.
    assert_eq!(
        reference.snr_front_indices().unwrap(),
        sweep_energy_area_snr_front(&spec, &model, 4, &ctx).into_indices()
    );

    let cli = [
        "--spec", "dense", "--points", "5", "--objectives", "energy,area,snr",
        "--snr-sum", "2048", "--snr-cell-bits", "3",
    ];
    let classic_cli = ["--spec", "dense", "--points", "5"];
    let n = 3usize;
    let dir = tmpdir("tri");
    let files = shard_files(&dir, n);
    for (i, out) in files.iter().enumerate() {
        let stdout = run_shard(&cli, &format!("{i}/{n}"), out);
        assert!(stdout.contains("evaluated"), "{i}/{n}: {stdout}");
    }
    // Resume works within the tri objective set...
    let stdout = run_shard(&cli, "1/3", &files[1]);
    assert!(stdout.contains("already complete"), "{stdout}");
    // ...but a classic run of the same grid must NOT resume from a tri
    // artifact (the snr context is part of the fingerprint), and vice
    // versa after it overwrites.
    let stdout = run_shard(&classic_cli, "1/3", &files[1]);
    assert!(stdout.contains("evaluated"), "objective change must recompute: {stdout}");
    let stdout = run_shard(&cli, "1/3", &files[1]);
    assert!(stdout.contains("evaluated"), "context restore must recompute: {stdout}");

    // Binary-level: merge-shards --out == tri sweep --summary-json, and
    // both equal the library reference bytes.
    let merged_path = dir.join("merged.json");
    let merged_str = merged_path.to_str().unwrap();
    let mut margs = vec!["merge-shards"];
    margs.extend(files.iter().map(String::as_str));
    margs.extend_from_slice(&["--out", merged_str]);
    let stdout = run_ok(&margs);
    assert!(stdout.contains("energy-area-SNR Pareto front"), "{stdout}");
    let single_path = dir.join("single.json");
    let single_str = single_path.to_str().unwrap();
    let mut sargs = vec!["sweep"];
    sargs.extend_from_slice(&cli);
    sargs.extend_from_slice(&["--summary-json", single_str]);
    run_ok(&sargs);
    assert_eq!(
        std::fs::read(&merged_path).unwrap(),
        std::fs::read(&single_path).unwrap(),
        "tri merge and single-process summary bytes must match"
    );
    assert_eq!(
        String::from_utf8(std::fs::read(&single_path).unwrap()).unwrap(),
        format!("{ref_json}\n"),
        "tri binary summary must equal the library reference"
    );

    // Mixing classic and tri artifacts of the same grid is a typed
    // fingerprint error at merge time.
    let classic = dir.join("classic.json");
    run_shard(&classic_cli, "0/3", classic.to_str().unwrap());
    let stderr = run_err(&[
        "merge-shards", classic.to_str().unwrap(), files[1].as_str(), files[2].as_str(),
    ]);
    assert!(stderr.contains("fingerprint"), "{stderr}");

    // Flag validation: snr knobs require the tri objective set; unknown
    // sets are named in the error.
    let stderr = run_err(&["sweep", "--spec", "dense", "--points", "4", "--snr-sum", "64"]);
    assert!(stderr.contains("--objectives energy,area,snr"), "{stderr}");
    let stderr = run_err(&[
        "sweep", "--spec", "dense", "--points", "4", "--objectives", "energy,snr",
    ]);
    assert!(stderr.contains("unsupported objective set"), "{stderr}");
    let stderr = run_err(&[
        "sweep", "--spec", "dense", "--points", "4", "--objectives", "energy,area,snr",
        "--snr-sum", "0",
    ]);
    assert!(stderr.contains("n_sum"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_skips_completed_shards_and_rebuilds_deleted_ones() {
    let dir = tmpdir("resume");
    let cli = ["--spec", "dense", "--points", "5"];
    let n = 3usize;
    let files = shard_files(&dir, n);
    for (i, out) in files.iter().enumerate() {
        let stdout = run_shard(&cli, &format!("{i}/{n}"), out);
        assert!(stdout.contains("evaluated"), "first run must compute: {stdout}");
    }
    // Re-running a completed shard is a fingerprint-verified no-op.
    let stdout = run_shard(&cli, "1/3", &files[1]);
    assert!(
        stdout.contains("already complete") && stdout.contains("skipping"),
        "{stdout}"
    );
    // A different spec does NOT resume from the same artifact (the
    // fingerprint differs), it recomputes and overwrites.
    let stdout = run_shard(&["--spec", "dense", "--points", "4"], "1/3", &files[1]);
    assert!(stdout.contains("evaluated"), "fingerprint change must recompute: {stdout}");
    // Restore shard 1 for the original spec, then kill shard 2 and
    // re-run the whole set: only shard 2 recomputes.
    run_shard(&cli, "1/3", &files[1]);
    std::fs::remove_file(&files[2]).unwrap();
    let mut recomputed = 0;
    for (i, out) in files.iter().enumerate() {
        let stdout = run_shard(&cli, &format!("{i}/{n}"), out);
        if stdout.contains("evaluated") {
            recomputed += 1;
            assert_eq!(i, 2, "only the deleted shard may recompute: {stdout}");
        } else {
            assert!(stdout.contains("already complete"), "{stdout}");
        }
    }
    assert_eq!(recomputed, 1);
    // The resumed set still merges bit-identically.
    let artifacts: Vec<ShardArtifact> =
        files.iter().map(|p| ShardArtifact::load(p).unwrap()).collect();
    let merged = merge_shards(&artifacts).unwrap();
    assert!(merged.is_complete());
    let reference = SweepSummary::compute(&SweepSpec::dense(5), &cli_model(), 4);
    assert_eq!(
        merged.summary.to_json_string().unwrap(),
        reference.to_json_string().unwrap()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_shard_specs_are_typed_errors() {
    for bad in ["0/0", "3/2", "junk", "1/", "/3", "1.5/3", "0x1/3"] {
        let stderr = run_err(&["sweep", "--spec", "dense", "--points", "4", "--shard", bad]);
        assert!(stderr.contains("error: config error"), "`{bad}`: {stderr}");
    }
    // Unknown spec name and undersized grids are typed errors too.
    let stderr = run_err(&["sweep", "--spec", "nope", "--shard", "0/2"]);
    assert!(stderr.contains("unknown sweep spec"), "{stderr}");
    let stderr = run_err(&["sweep", "--points", "1", "--shard", "0/2"]);
    assert!(stderr.contains("--points"), "{stderr}");
    // Shard mode refuses the PJRT backend explicitly.
    let stderr = run_err(&[
        "sweep", "--spec", "dense", "--points", "4", "--backend", "pjrt", "--shard", "0/2",
    ]);
    assert!(stderr.contains("native"), "{stderr}");
    // --shard and --summary-json are mutually exclusive (a silent
    // missing summary file would break downstream scripts).
    let stderr = run_err(&[
        "sweep", "--spec", "dense", "--points", "4", "--shard", "0/2", "--summary-json",
        "/tmp/never_written.json",
    ]);
    assert!(stderr.contains("mutually exclusive"), "{stderr}");
}

#[test]
fn merge_shards_negative_paths_are_typed_errors() {
    let dir = tmpdir("merge_neg");
    // No inputs / missing file.
    let stderr = run_err(&["merge-shards"]);
    assert!(stderr.contains("at least one"), "{stderr}");
    let missing = dir.join("not_there.json");
    let stderr = run_err(&["merge-shards", missing.to_str().unwrap()]);
    assert!(stderr.contains("cannot read"), "{stderr}");

    // Build artifacts from two different sweeps and one overlapping plan.
    let a = dir.join("a.json");
    let b_other_spec = dir.join("b.json");
    let c_overlap = dir.join("c.json");
    run_shard(&["--spec", "dense", "--points", "4"], "0/2", a.to_str().unwrap());
    run_shard(&["--spec", "dense", "--points", "5"], "1/2", b_other_spec.to_str().unwrap());
    run_shard(&["--spec", "dense", "--points", "4"], "0/1", c_overlap.to_str().unwrap());

    let stderr = run_err(&["merge-shards", a.to_str().unwrap(), b_other_spec.to_str().unwrap()]);
    assert!(stderr.contains("fingerprint"), "{stderr}");
    let stderr = run_err(&["merge-shards", a.to_str().unwrap(), c_overlap.to_str().unwrap()]);
    assert!(stderr.contains("overlap"), "{stderr}");

    // Incomplete coverage: refused by default (naming the gap), accepted
    // with --allow-partial.
    let stderr = run_err(&["merge-shards", a.to_str().unwrap()]);
    assert!(stderr.contains("allow-partial"), "{stderr}");
    assert!(stderr.contains("192..384"), "gap range should be named: {stderr}");
    let stdout = run_ok(&["merge-shards", a.to_str().unwrap(), "--allow-partial"]);
    assert!(stdout.contains("192/384"), "{stdout}");
    // Flag-first order: the parser consumes the first path as the flag's
    // value; merge-shards must recover it rather than merge one file short.
    let stdout = run_ok(&["merge-shards", "--allow-partial", a.to_str().unwrap()]);
    assert!(stdout.contains("192/384"), "flag-first must still load the file: {stdout}");

    // A corrupted artifact is a typed load error.
    let garbled = dir.join("garbled.json");
    std::fs::write(&garbled, "{\"kind\": \"cimdse-shard-artifact\", \"schema\": 1}").unwrap();
    let stderr = run_err(&["merge-shards", garbled.to_str().unwrap()]);
    assert!(stderr.contains("error: config error"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}
