//! Property tests for the streaming sweep engine: the indexed /
//! chunked / folded / prepared-kernel paths must reproduce the
//! materialized `run_sweep` + `AdcModel::eval` path *exactly* — same
//! order, same bits (stronger than the 1-ulp contract) — across
//! randomized specs including empty and single-axis grids.

use cimdse::adc::{AdcMetrics, AdcModel, AdcQuery, PreparedModel, TuningPoint};
use cimdse::dse::{
    FrontK, NativeEvaluator, ShardPlan, SnrContext, StreamingFront, SweepSpec, pareto_front,
    pareto_front_k, run_sweep, run_sweep_fold, run_sweep_prepared, sweep_energy_area_snr_front,
    sweep_min_eap, sweep_power_area_front,
};
use cimdse::testing::{Config, check};
use cimdse::util::Rng;
use cimdse::util::logspace::log10;

fn metric_bits(m: &AdcMetrics) -> [u64; 4] {
    m.to_bits()
}

/// A random spec with 0..=4 values per axis (so empty and single-axis
/// grids appear regularly), all inside the model's valid ranges.
fn arbitrary_spec(rng: &mut Rng, allow_empty: bool) -> SweepSpec {
    let min = usize::from(!allow_empty);
    let axis_len = |rng: &mut Rng| min + rng.index(5 - min);
    SweepSpec {
        enobs: (0..axis_len(rng)).map(|_| rng.uniform(2.0, 14.0)).collect(),
        total_throughputs: (0..axis_len(rng))
            .map(|_| 10f64.powf(rng.uniform(4.0, 10.5)))
            .collect(),
        tech_nms: (0..axis_len(rng)).map(|_| rng.uniform(7.0, 180.0)).collect(),
        n_adcs: (0..axis_len(rng)).map(|_| 1 + rng.index(64) as u32).collect(),
    }
}

/// A model that is sometimes tuned, so the offset-decade paths are
/// exercised too.
fn arbitrary_model(rng: &mut Rng) -> AdcModel {
    let base = AdcModel::default();
    if rng.bool(0.5) {
        return base;
    }
    base.tuned_to(&TuningPoint {
        query: AdcQuery {
            enob: rng.uniform(4.0, 10.0),
            total_throughput: 10f64.powf(rng.uniform(6.0, 10.0)),
            tech_nm: 32.0,
            n_adcs: 1,
        },
        energy_pj_per_convert: 10f64.powf(rng.uniform(-1.0, 1.5)),
        area_um2: if rng.bool(0.5) { Some(10f64.powf(rng.uniform(2.0, 5.0))) } else { None },
    })
}

#[test]
fn point_at_and_fill_range_match_materialized_points() {
    check(Config::default().cases(60), |rng| {
        let spec = arbitrary_spec(rng, true);
        let pts = spec.points();
        assert_eq!(pts.len(), spec.len());
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(&spec.point_at(i), p);
        }
        if !pts.is_empty() {
            let a = rng.index(pts.len());
            let b = a + rng.index(pts.len() - a + 1);
            let mut buf = Vec::new();
            spec.fill_range(a..b, &mut buf);
            assert_eq!(buf.as_slice(), &pts[a..b]);
        }
    });
}

#[test]
fn prepared_row_evaluation_is_bit_identical_to_eval() {
    check(Config::default().cases(120), |rng| {
        let model = arbitrary_model(rng);
        let prepared = PreparedModel::new(&model);
        let q = AdcQuery {
            enob: rng.uniform(2.0, 14.0),
            total_throughput: 10f64.powf(rng.uniform(4.0, 10.5)),
            tech_nm: rng.uniform(7.0, 180.0),
            n_adcs: 1 + rng.index(64) as u32,
        };
        let row = prepared.row(q.enob, q.tech_nm);
        assert_eq!(metric_bits(&row.eval_query(&q)), metric_bits(&model.eval(&q)));
        // And through the sweep's cached-log10 route.
        let cached = log10(q.total_throughput / q.n_adcs as f64);
        assert_eq!(
            metric_bits(&row.eval_log_f(cached, q.total_throughput, q.n_adcs)),
            metric_bits(&model.eval(&q))
        );
    });
}

#[test]
fn prepared_sweep_matches_materialized_run_sweep_bitwise() {
    check(Config::default().cases(40), |rng| {
        let spec = arbitrary_spec(rng, true);
        let model = arbitrary_model(rng);
        let baseline = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        for workers in [1usize, 4] {
            let fast = run_sweep_prepared(&spec, &model, workers).unwrap();
            assert_eq!(fast.len(), baseline.len(), "workers={workers}");
            for (a, b) in baseline.iter().zip(&fast) {
                assert_eq!(a.query, b.query);
                assert_eq!(metric_bits(&a.metrics), metric_bits(&b.metrics));
            }
        }
    });
}

#[test]
fn serial_fold_replays_the_materialized_sweep_in_order() {
    check(Config::default().cases(40), |rng| {
        let spec = arbitrary_spec(rng, true);
        let model = arbitrary_model(rng);
        let baseline = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        let replayed = run_sweep_fold(
            &spec,
            &model,
            1,
            Vec::new,
            |acc: &mut Vec<(usize, AdcQuery, AdcMetrics)>, i, q, m| acc.push((i, *q, *m)),
            |mut a, b| {
                a.extend(b);
                a
            },
        );
        assert_eq!(replayed.len(), baseline.len());
        for (j, (i, q, m)) in replayed.iter().enumerate() {
            assert_eq!(*i, j, "serial fold must visit points in grid order");
            assert_eq!(*q, baseline[j].query);
            assert_eq!(metric_bits(m), metric_bits(&baseline[j].metrics));
        }
    });
}

#[test]
fn parallel_fold_rollups_match_materialized_exactly() {
    check(Config::default().cases(25), |rng| {
        let spec = arbitrary_spec(rng, true);
        let model = arbitrary_model(rng);
        let all = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();

        // Count rollup.
        let count = run_sweep_fold(
            &spec,
            &model,
            4,
            || 0usize,
            |acc, _, _, _| *acc += 1,
            |a, b| a + b,
        );
        assert_eq!(count, all.len());

        // Min-EAP rollup (deterministic index tie-break).
        let brute = all
            .iter()
            .enumerate()
            .min_by(|(i, a), (j, b)| {
                let ea = a.metrics.energy_pj_per_convert * a.metrics.total_area_um2;
                let eb = b.metrics.energy_pj_per_convert * b.metrics.total_area_um2;
                ea.total_cmp(&eb).then(i.cmp(j))
            })
            .map(|(_, p)| p);
        for workers in [1usize, 4] {
            let streamed = sweep_min_eap(&spec, &model, workers);
            match (brute, streamed) {
                (None, None) => {}
                (Some(b), Some(s)) => {
                    assert_eq!(s.query, b.query, "workers={workers}");
                    assert_eq!(metric_bits(&s.metrics), metric_bits(&b.metrics));
                }
                (b, s) => panic!("mismatch: brute={:?} streamed={:?}", b.is_some(), s.is_some()),
            }
        }

        // Pareto-front rollup: exactly `pareto_front` on the materialized
        // objectives, regardless of worker count / steal order.
        let objectives: Vec<(f64, f64)> = all
            .iter()
            .map(|p| (p.metrics.total_power_w, p.metrics.total_area_um2))
            .collect();
        let brute_front = pareto_front(&objectives);
        for workers in [1usize, 4] {
            assert_eq!(
                sweep_power_area_front(&spec, &model, workers),
                brute_front,
                "workers={workers}"
            );
        }
    });
}

#[test]
fn single_axis_and_single_point_grids() {
    let model = AdcModel::default();
    // Single point.
    let spec = SweepSpec {
        enobs: vec![8.0],
        total_throughputs: vec![1e9],
        tech_nms: vec![32.0],
        n_adcs: vec![4],
    };
    assert_eq!(spec.len(), 1);
    let all = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
    let fast = run_sweep_prepared(&spec, &model, 4).unwrap();
    assert_eq!(all.len(), 1);
    assert_eq!(metric_bits(&all[0].metrics), metric_bits(&fast[0].metrics));
    assert_eq!(
        metric_bits(&sweep_min_eap(&spec, &model, 4).unwrap().metrics),
        metric_bits(&all[0].metrics)
    );

    // One long axis, the rest singletons (row-kernel degenerate shapes).
    let spec = SweepSpec {
        enobs: vec![7.0],
        total_throughputs: cimdse::util::logspace::logspace(1e5, 1e10, 41),
        tech_nms: vec![32.0],
        n_adcs: vec![1],
    };
    let all = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
    let fast = run_sweep_prepared(&spec, &model, 1).unwrap();
    for (a, b) in all.iter().zip(&fast) {
        assert_eq!(metric_bits(&a.metrics), metric_bits(&b.metrics));
    }
}

/// NaN/±inf objectives: the front must never panic, must drop the
/// non-finite points, and must stay order-independent — and on the finite
/// subset it must match the materialized `pareto_front` exactly however
/// the pushes are split across sub-fronts and merged.
#[test]
fn front_merge_with_non_finite_objectives_never_panics_and_matches_finite_front() {
    check(Config::default().cases(150).seed(41), |rng| {
        let n = rng.index(40);
        let coord = |rng: &mut Rng| match rng.index(8) {
            0 => f64::NAN,
            1 => f64::INFINITY,
            2 => f64::NEG_INFINITY,
            // Coarse values so duplicates and dominance ties are common.
            _ => rng.uniform(0.0, 4.0).round(),
        };
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (coord(rng), coord(rng))).collect();

        // One front fed directly...
        let mut whole = StreamingFront::new();
        for (i, &(a, b)) in pts.iter().enumerate() {
            whole.push(a, b, i);
        }
        // ...versus a random split into sub-fronts merged in random order
        // (the multi-process merge shape).
        let k = 1 + rng.index(5);
        let mut parts: Vec<StreamingFront> = (0..k).map(|_| StreamingFront::new()).collect();
        for (i, &(a, b)) in pts.iter().enumerate() {
            parts[rng.index(k)].push(a, b, i);
        }
        rng.shuffle(&mut parts);
        let merged = parts
            .into_iter()
            .fold(StreamingFront::new(), |acc, part| acc.merge(part));
        assert_eq!(merged.indices(), whole.indices());

        // Ground truth: pareto_front over only the finite points, with
        // indices mapped back to the original list.
        let finite: Vec<(usize, (f64, f64))> = pts
            .iter()
            .copied()
            .enumerate()
            .filter(|(_, (a, b))| a.is_finite() && b.is_finite())
            .collect();
        let objectives: Vec<(f64, f64)> = finite.iter().map(|&(_, p)| p).collect();
        let brute: Vec<usize> =
            pareto_front(&objectives).into_iter().map(|j| finite[j].0).collect();
        assert_eq!(whole.into_indices(), brute);
    });
}

/// The k-objective generalization of the test above: a [`FrontK`] fed
/// whole must equal the same points split across random sub-fronts and
/// merged in random order, and both must equal the materialized
/// [`pareto_front_k`] — including under NaN/±inf injection (non-finite
/// rows are dropped identically by the streaming and materialized
/// paths, so their index sets cannot diverge).
#[test]
fn front_k_merge_with_non_finite_objectives_matches_materialized_front() {
    check(Config::default().cases(150).seed(43), |rng| {
        let n = rng.index(40);
        let coord = |rng: &mut Rng| match rng.index(8) {
            0 => f64::NAN,
            1 => f64::INFINITY,
            2 => f64::NEG_INFINITY,
            // Coarse values so duplicates and dominance ties are common.
            _ => rng.uniform(0.0, 4.0).round(),
        };
        let pts: Vec<[f64; 3]> =
            (0..n).map(|_| [coord(rng), coord(rng), coord(rng)]).collect();

        let mut whole = FrontK::<3>::new();
        for (i, &p) in pts.iter().enumerate() {
            whole.push(p, i);
        }
        let k = 1 + rng.index(5);
        let mut parts: Vec<FrontK<3>> = (0..k).map(|_| FrontK::new()).collect();
        for (i, &p) in pts.iter().enumerate() {
            parts[rng.index(k)].push(p, i);
        }
        rng.shuffle(&mut parts);
        let merged = parts.into_iter().fold(FrontK::new(), |acc, part| acc.merge(part));
        assert_eq!(merged.indices(), whole.indices());

        // `pareto_front_k` skips non-finite rows itself and reports
        // original indices, so it is the ground truth directly.
        assert_eq!(whole.into_indices(), pareto_front_k(&pts));
    });
}

/// The streamed tri-objective sweep front equals the brute-force one:
/// materialize the sweep, build the (energy, area, -SNR) rows, run
/// [`pareto_front_k`]. Random SNR contexts include degenerate cell
/// widths whose saturated math yields -inf SNR (the whole grid drops
/// off the front on that axis) — both paths must agree there too.
#[test]
fn streamed_snr_front_matches_materialized_for_random_contexts() {
    check(Config::default().cases(25).seed(47), |rng| {
        let spec = arbitrary_spec(rng, true);
        let model = arbitrary_model(rng);
        let ctx = SnrContext {
            n_sum: 1 + rng.index(10_000),
            // Mostly realistic widths; occasionally huge so pow2_f64
            // saturates and the SNR term goes to -inf without panicking.
            cell_bits: if rng.bool(0.1) { 1_000 } else { 1 + rng.index(8) as u32 },
        };
        let all = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        let objectives: Vec<[f64; 3]> = all
            .iter()
            .map(|p| {
                [
                    p.metrics.energy_pj_per_convert,
                    p.metrics.total_area_um2,
                    -ctx.compute_snr_db(p.query.enob),
                ]
            })
            .collect();
        let brute = pareto_front_k(&objectives);
        for workers in [1usize, 4] {
            assert_eq!(
                sweep_energy_area_snr_front(&spec, &model, workers, &ctx).into_indices(),
                brute,
                "workers={workers} ctx={ctx:?}"
            );
        }
    });
}

/// Shard planning composes with the spec's index machinery on degenerate
/// shapes: empty grids, single-point grids, and more shards than points
/// (so most shard ranges are empty) all partition exactly and every
/// sub-range is materializable via `fill_range`.
#[test]
fn shard_plans_cover_degenerate_specs_exactly() {
    check(Config::default().cases(80).seed(55), |rng| {
        let spec = arbitrary_spec(rng, true);
        let pts = spec.points();
        for n_shards in [1usize, 2, 7, pts.len().max(1), pts.len() + 3] {
            let plan = ShardPlan::new(&spec, n_shards).unwrap();
            assert_eq!(plan.len(), pts.len());
            let mut seen = Vec::new();
            for shard in 0..n_shards {
                let range = plan.range(shard);
                let mut buf = Vec::new();
                spec.fill_range(range.clone(), &mut buf);
                assert_eq!(buf.len(), range.len());
                for (offset, q) in buf.iter().enumerate() {
                    assert_eq!(q, &spec.point_at(range.start + offset));
                }
                seen.extend(buf);
            }
            assert_eq!(seen, pts, "shards must tile the grid in order");
        }
    });
}

/// `checked_len` overflow surfaces as a typed planning error (no panic),
/// while `len()` still saturates for display purposes.
#[test]
fn overflowing_grids_are_typed_shard_planning_errors() {
    let spec = SweepSpec {
        enobs: vec![8.0; 1 << 17],
        total_throughputs: vec![1e9; 1 << 17],
        tech_nms: vec![32.0; 1 << 17],
        n_adcs: vec![1; 1 << 17],
    };
    assert_eq!(spec.checked_len(), None);
    assert_eq!(spec.len(), usize::MAX);
    for n_shards in [1usize, 7] {
        let err = ShardPlan::new(&spec, n_shards).unwrap_err();
        assert!(
            matches!(err, cimdse::Error::Numeric(_)),
            "want a typed numeric error, got {err}"
        );
    }
}

#[test]
fn empty_grids_stream_to_empty_results() {
    let model = AdcModel::default();
    for empty_axis in 0..4usize {
        let mut spec = SweepSpec {
            enobs: vec![8.0],
            total_throughputs: vec![1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1],
        };
        match empty_axis {
            0 => spec.enobs.clear(),
            1 => spec.total_throughputs.clear(),
            2 => spec.tech_nms.clear(),
            _ => spec.n_adcs.clear(),
        }
        assert!(spec.is_empty());
        assert!(run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap().is_empty());
        assert!(run_sweep_prepared(&spec, &model, 4).unwrap().is_empty());
        assert!(sweep_min_eap(&spec, &model, 4).is_none());
        assert!(sweep_power_area_front(&spec, &model, 4).is_empty());
        let count = run_sweep_fold(
            &spec,
            &model,
            4,
            || 0usize,
            |acc, _, _, _| *acc += 1,
            |a, b| a + b,
        );
        assert_eq!(count, 0);
    }
}
