//! Socket-level contract of the serving daemon.
//!
//! The acceptance criterion: a served `eval` / `sweep` response is
//! **bit-identical** to the corresponding direct library call, asserted
//! across a real TCP socket — plus a concurrent-client stress test
//! (N threads × M interleaved eval/sweep frames, every response
//! byte-compared against direct library output) and the negative paths:
//! malformed JSON, unknown op, oversized frame, and mid-frame
//! disconnect each yield a typed error frame or a clean close, never a
//! server panic. A final process-level test drives the real
//! `cimdse serve` / `cimdse query` binaries end to end.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::Duration;

use cimdse::adc::{AdcModel, AdcQuery};
use cimdse::config::{Value, parse_json};
use cimdse::dse::{ShardArtifact, ShardSelector, SweepSpec, SweepSummary, merge_shards};
use cimdse::service::protocol::{
    CODE_BAD_FRAME, CODE_BAD_REQUEST, CODE_MALFORMED_JSON, CODE_OVERSIZED_FRAME,
    CODE_UNKNOWN_OP, MAX_FRAME_BYTES,
};
use cimdse::service::{Client, ServeOptions, Server, ServerHandle};

/// Spin up an in-process server on an ephemeral port; returns its
/// address string, a shutdown handle, and the serve-thread join handle.
fn start_server(model: AdcModel) -> (String, ServerHandle, thread::JoinHandle<()>) {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        model,
        cache_capacity: 8,
        workers: 2,
        ..ServeOptions::default()
    })
    .expect("bind");
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let join = thread::spawn(move || server.serve().expect("serve"));
    (addr, handle, join)
}

fn stop_server(addr: &str, join: thread::JoinHandle<()>) {
    let mut client = Client::connect(addr).expect("connect for shutdown");
    client.shutdown().expect("shutdown ack");
    join.join().expect("serve thread exits cleanly");
}

/// Raw-socket helper: send one line, read one response line.
fn raw_roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Value {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut response = String::new();
    let n = reader.read_line(&mut response).unwrap();
    assert!(n > 0, "server closed instead of answering `{line}`");
    parse_json(response.trim_end()).expect("response parses")
}

fn raw_pair(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn err_code(v: &Value) -> &str {
    assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false), "{v:?}");
    v.require_str("error.code").unwrap()
}

fn small_spec() -> SweepSpec {
    SweepSpec {
        enobs: vec![4.0, 8.0, 12.0],
        total_throughputs: vec![1e6, 1e8, 1e10],
        tech_nms: vec![16.0, 32.0],
        n_adcs: vec![1, 4],
    }
}

#[test]
fn served_eval_is_bit_identical_to_direct_eval() {
    let model = AdcModel::default();
    let (addr, _handle, join) = start_server(model);
    let mut client = Client::connect(&addr).unwrap();
    for (enob, total, tech, n) in [
        (7.0, 1.3e9, 32.0, 8u32),
        (4.5, 1e6, 16.0, 1),
        (12.0, 4e10, 65.0, 32),
        (2.1, 1e4, 130.0, 2),
    ] {
        let q = AdcQuery { enob, total_throughput: total, tech_nm: tech, n_adcs: n };
        let served = client.eval_metrics(&q, None).unwrap();
        assert_eq!(
            served.to_bits(),
            model.eval(&q).to_bits(),
            "served eval must be bit-identical (enob={enob} total={total})"
        );
        // Tuned model rides through the wire bit-exactly too.
        let tuned = AdcModel { energy_offset_decades: 0.125, ..model };
        let served = client.eval_metrics(&q, Some(&tuned)).unwrap();
        assert_eq!(served.to_bits(), tuned.eval(&q).to_bits());
    }
    stop_server(&addr, join);
}

#[test]
fn served_sweep_summary_is_byte_identical_to_direct_rollup() {
    let model = AdcModel::default();
    let (addr, _handle, join) = start_server(model);
    let mut client = Client::connect(&addr).unwrap();
    for spec in [small_spec(), SweepSpec::dense(5), SweepSpec::fig5(7.0, 6)] {
        let (result, summary) = client.sweep(&spec, None).unwrap();
        let direct = SweepSummary::compute(&spec, &model, 4);
        assert_eq!(
            summary.to_json_string().unwrap(),
            direct.to_json_string().unwrap(),
            "served summary must be byte-identical to the direct rollup"
        );
        // And the raw payload on the wire is the canonical serialization.
        assert_eq!(
            result.get("summary").unwrap().to_json_string().unwrap(),
            direct.to_value().to_json_string().unwrap()
        );
    }
    stop_server(&addr, join);
}

#[test]
fn served_shard_artifacts_merge_bit_identically_over_the_wire() {
    let model = AdcModel::default();
    let (addr, _handle, join) = start_server(model);
    let mut client = Client::connect(&addr).unwrap();
    let spec = small_spec();
    let tuned = AdcModel { energy_offset_decades: 0.125, ..model };
    for m in [model, tuned] {
        let mut served = Vec::new();
        for i in 0..3usize {
            let selector = ShardSelector::new(i, 3).unwrap();
            let artifact = client.shard(&spec, Some(&m), selector).unwrap();
            // Byte-identical to the artifact `sweep --shard i/3` would
            // write locally for the same spec and model.
            let direct = ShardArtifact::compute(&spec, &m, selector, 2).unwrap();
            assert_eq!(
                artifact.to_json_string().unwrap(),
                direct.to_json_string().unwrap(),
                "served shard {i}/3 must be byte-identical to local compute"
            );
            served.push(artifact);
        }
        // And the served set merges to the exact single-process rollup.
        let merged = merge_shards(&served).unwrap();
        assert!(merged.is_complete());
        assert_eq!(
            merged.summary.to_json_string().unwrap(),
            SweepSummary::compute(&spec, &m, 4).to_json_string().unwrap()
        );
    }
    stop_server(&addr, join);
}

#[test]
fn concurrent_clients_see_bit_identical_responses() {
    let model = AdcModel::default();
    let (addr, _handle, join) = start_server(model);
    const THREADS: usize = 6;
    const ROUNDS: usize = 10;
    let spec = SweepSpec::fig5(7.0, 4);
    let direct_summary = SweepSummary::compute(&spec, &model, 2).to_json_string().unwrap();
    thread::scope(|s| {
        for t in 0..THREADS {
            let addr = addr.clone();
            let spec = spec.clone();
            let direct_summary = direct_summary.clone();
            s.spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                for i in 0..ROUNDS {
                    // Interleave eval and sweep frames on one connection.
                    let q = AdcQuery {
                        enob: 2.0 + ((t + i) % 12) as f64,
                        total_throughput: 1e6 * 10f64.powi((i % 4) as i32),
                        tech_nm: 32.0,
                        n_adcs: 1 + (t as u32 % 4),
                    };
                    let served = client.eval_metrics(&q, None).expect("eval");
                    assert_eq!(served.to_bits(), model.eval(&q).to_bits(), "t={t} i={i}");
                    if i % 3 == 0 {
                        let (_, summary) = client.sweep(&spec, None).expect("sweep");
                        assert_eq!(
                            summary.to_json_string().unwrap(),
                            direct_summary,
                            "t={t} i={i}"
                        );
                    }
                }
            });
        }
    });
    // The shared default model means every lookup after the first hits.
    let mut client = Client::connect(&addr).unwrap();
    let snapshot = client.metrics().unwrap();
    assert!(snapshot.require_f64("cache.hits").unwrap() > 0.0);
    assert!(
        snapshot.require_f64("requests_total").unwrap() >= (THREADS * ROUNDS) as f64,
        "{snapshot:?}"
    );
    assert!(snapshot.require_f64("latency.p50_s").unwrap() >= 0.0);
    stop_server(&addr, join);
}

#[test]
fn malformed_input_yields_typed_error_frames_not_disconnects() {
    let (addr, _handle, join) = start_server(AdcModel::default());
    let (mut stream, mut reader) = raw_pair(&addr);

    let resp = raw_roundtrip(&mut stream, &mut reader, "{ this is not json");
    assert_eq!(err_code(&resp), CODE_MALFORMED_JSON);

    let resp = raw_roundtrip(&mut stream, &mut reader, "[1, 2, 3]");
    assert_eq!(err_code(&resp), CODE_BAD_FRAME);

    let resp = raw_roundtrip(&mut stream, &mut reader, r#"{"op": "frobnicate"}"#);
    assert_eq!(err_code(&resp), CODE_UNKNOWN_OP);

    let resp = raw_roundtrip(&mut stream, &mut reader, r#"{"op": "eval", "id": 9}"#);
    assert_eq!(err_code(&resp), CODE_BAD_REQUEST);
    assert_eq!(resp.get("id").and_then(Value::as_f64), Some(9.0), "id echoes on errors");

    // After all that abuse the connection still serves real requests.
    let resp = raw_roundtrip(
        &mut stream,
        &mut reader,
        r#"{"op": "eval", "query": {"enob": 7, "total_throughput": 1e9}}"#,
    );
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp:?}");
    stop_server(&addr, join);
}

#[test]
fn oversized_frames_are_rejected_and_the_connection_recovers() {
    let (addr, _handle, join) = start_server(AdcModel::default());
    let (mut stream, mut reader) = raw_pair(&addr);
    // A single line well past the cap (sent in chunks, no newline until
    // the end).
    let chunk = vec![b'x'; 64 * 1024];
    let mut sent = 0usize;
    while sent <= MAX_FRAME_BYTES + chunk.len() {
        stream.write_all(&chunk).unwrap();
        sent += chunk.len();
    }
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut response = String::new();
    reader.read_line(&mut response).unwrap();
    let resp = parse_json(response.trim_end()).unwrap();
    assert_eq!(err_code(&resp), CODE_OVERSIZED_FRAME);
    // The tail of the oversized line was discarded; the next frame works.
    let resp = raw_roundtrip(&mut stream, &mut reader, r#"{"op": "metrics"}"#);
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp:?}");
    stop_server(&addr, join);
}

#[test]
fn mid_frame_disconnect_is_a_clean_close_not_a_panic() {
    let (addr, _handle, join) = start_server(AdcModel::default());
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(br#"{"op": "eval", "query": {"en"#).unwrap();
        stream.flush().unwrap();
        // Drop mid-frame.
    }
    {
        // A second client disconnects mid-line after an oversized burst.
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.write_all(&vec![b'y'; 256 * 1024]).unwrap();
        stream.flush().unwrap();
    }
    // Give the reader threads a moment to observe the closes.
    thread::sleep(Duration::from_millis(200));
    // The server survived both and still answers.
    let mut client = Client::connect(&addr).unwrap();
    let q = AdcQuery { enob: 7.0, total_throughput: 1e9, tech_nm: 32.0, n_adcs: 1 };
    assert!(client.eval_metrics(&q, None).is_ok());
    stop_server(&addr, join);
}

#[test]
fn graceful_shutdown_drains_and_stops_accepting() {
    let (addr, handle, join) = start_server(AdcModel::default());
    assert!(!handle.is_shutting_down());
    let mut client = Client::connect(&addr).unwrap();
    client.shutdown().unwrap();
    assert!(handle.is_shutting_down());
    join.join().expect("serve returns after drain");
    // The listener is gone: new connections are refused (or reset).
    thread::sleep(Duration::from_millis(50));
    let refused = TcpStream::connect(&addr);
    if let Ok(stream) = refused {
        // Some platforms accept briefly from the backlog; the socket
        // must at least be dead (EOF on read).
        stream.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut s = stream;
        s.write_all(b"{\"op\": \"metrics\"}\n").ok();
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap_or(0);
        assert_eq!(n, 0, "drained server must not serve: {line}");
    }
}

// ---------------------------------------------------------------------------
// Process-level: the real binaries, end to end.
// ---------------------------------------------------------------------------

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_cimdse")
}

/// Spawn `cimdse serve` and wait for its "listening on" line.
fn spawn_serve_binary(extra: &[&str]) -> (Child, String) {
    let mut child = Command::new(bin())
        .arg("serve")
        .args(["--addr", "127.0.0.1:0"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn cimdse serve");
    let stdout = child.stdout.take().expect("child stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).expect("read serve banner");
    let addr = line
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in serve banner: {line}"))
        .to_string();
    // Keep draining the child's stdout in the background so it can
    // never block on a full pipe.
    thread::spawn(move || {
        let mut sink = String::new();
        let _ = reader.read_to_string(&mut sink);
    });
    (child, addr)
}

fn run_capture(args: &[&str]) -> String {
    let out = Command::new(bin()).args(args).output().unwrap();
    assert!(
        out.status.success(),
        "cimdse {args:?} failed\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn serve_and_query_binaries_roundtrip_end_to_end() {
    let (mut child, addr) = spawn_serve_binary(&[]);
    let result = std::panic::catch_unwind(|| {
        // Served eval output is byte-identical to the direct `model`
        // subcommand (same default fit, shared printer, bit-hex floats
        // on the wire).
        let eval_args =
            ["--enob", "7", "--throughput", "1.3e9", "--tech", "32", "--n-adcs", "8"];
        let mut query: Vec<&str> =
            vec!["query", "--addr", &addr, "--op", "eval"];
        query.extend_from_slice(&eval_args);
        let served = run_capture(&query);
        let mut direct: Vec<&str> = vec!["model"];
        direct.extend_from_slice(&eval_args);
        let direct = run_capture(&direct);
        assert_eq!(served, direct, "served eval output must match `cimdse model`");

        // Served sweep summary file is byte-identical to
        // `sweep --summary-json`.
        let dir = std::env::temp_dir()
            .join(format!("cimdse_serve_rt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let served_path = dir.join("served.json");
        let direct_path = dir.join("direct.json");
        run_capture(&[
            "query", "--addr", &addr, "--op", "sweep", "--spec", "dense", "--points", "5",
            "--out", served_path.to_str().unwrap(),
        ]);
        run_capture(&[
            "sweep", "--spec", "dense", "--points", "5", "--summary-json",
            direct_path.to_str().unwrap(),
        ]);
        assert_eq!(
            std::fs::read(&served_path).unwrap(),
            std::fs::read(&direct_path).unwrap(),
            "served summary file must be byte-identical"
        );

        // Metrics show the repeated default model hitting the cache
        // (eval + sweep share one fingerprint).
        let metrics = run_capture(&["query", "--addr", &addr, "--op", "metrics"]);
        assert!(metrics.contains("cimdse service metrics"), "{metrics}");
        let hits_line = metrics
            .lines()
            .find(|l| l.trim_start().starts_with("cache"))
            .unwrap_or_else(|| panic!("no cache line: {metrics}"));
        let hits: u64 = hits_line
            .trim_start()
            .trim_start_matches("cache")
            .trim_start()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap_or_else(|_| panic!("unparsable cache line: {hits_line}"));
        assert!(hits >= 1, "repeated model must hit the cache: {hits_line}");

        let _ = std::fs::remove_dir_all(&dir);
        run_capture(&["query", "--addr", &addr, "--op", "shutdown"])
    });
    match result {
        Ok(shutdown_stdout) => {
            assert!(shutdown_stdout.contains("draining"), "{shutdown_stdout}");
            let status = child.wait().expect("serve exits");
            assert!(status.success(), "serve must exit 0 after graceful drain: {status:?}");
        }
        Err(panic) => {
            let _ = child.kill();
            let _ = child.wait();
            std::panic::resume_unwind(panic);
        }
    }
}
