//! Golden-figure regression suite: pins the paper-facing outputs —
//! Fig. 2 model energy lines, Fig. 3 model area lines, and the Fig. 5
//! EAP surface — to checked-in expected values
//! (`tests/golden_figures.json`) with explicit tolerances, so model
//! refactors cannot silently drift from the paper's numbers.
//!
//! Everything is computed from [`AdcModel::default`] (the generator
//! truth), so the goldens are deterministic: pure closed-form float math
//! with no survey fit in the loop. The relative tolerance (1e-9, stored
//! in the golden file) absorbs last-ulp libm differences across
//! platforms while still catching any real coefficient or formula
//! change, which moves results by many orders more.
//!
//! To intentionally re-baseline after a deliberate model change:
//! `CIMDSE_UPDATE_GOLDEN=1 cargo test --test golden_figures` rewrites
//! the golden file from the current implementation; commit the diff.
//! The file uses the same compact sorted-key layout `write_golden`
//! emits; a re-baseline may still respell individual numbers (shortest
//! round-trip decimal, e.g. `1e-09` vs `0.000000001`) without changing
//! their parsed bits.

use std::collections::BTreeMap;
use std::path::PathBuf;

use cimdse::adc::AdcModel;
use cimdse::adc::enob::ideal_sndr_db;
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::config::{Value, parse_json};
use cimdse::dse::compute_snr_db;
use cimdse::dse::figures::{Fig5Cell, fig2, fig3, fig5};
use cimdse::survey::generator::{SurveyConfig, generate_survey};

const LINE_POINTS: usize = 7;
const FIG5_STEPS: usize = 4;
const FIG5_NADCS: [u32; 5] = [1, 2, 4, 8, 16];

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden_figures.json")
}

fn assert_close(actual: f64, expected: f64, rel_tol: f64, ctx: &str) {
    let scale = actual.abs().max(expected.abs());
    assert!(
        (actual - expected).abs() <= rel_tol * scale,
        "{ctx}: actual {actual:e} vs golden {expected:e} (rel err {:.3e} > {rel_tol:e})",
        (actual - expected).abs() / scale
    );
}

fn f64_list(v: &Value, path: &str) -> Vec<f64> {
    v.get(path)
        .and_then(Value::as_array)
        .unwrap_or_else(|| panic!("golden file lacks array `{path}`"))
        .iter()
        .map(|x| x.as_f64().unwrap_or_else(|| panic!("non-number in `{path}`")))
        .collect()
}

fn f64_rows(v: &Value, path: &str) -> Vec<Vec<f64>> {
    v.get(path)
        .and_then(Value::as_array)
        .unwrap_or_else(|| panic!("golden file lacks array `{path}`"))
        .iter()
        .enumerate()
        .map(|(i, row)| {
            row.as_array()
                .unwrap_or_else(|| panic!("`{path}[{i}]` is not an array"))
                .iter()
                .map(|x| x.as_f64().unwrap())
                .collect()
        })
        .collect()
}

/// The computed figure data, in the golden file's layout.
struct Computed {
    throughputs_23: Vec<f64>,
    fig2_values: Vec<Vec<f64>>,
    fig3_values: Vec<Vec<f64>>,
    fig5_throughputs: Vec<f64>,
    fig5_energy: Vec<Vec<f64>>,
    fig5_area: Vec<Vec<f64>>,
    fig5_eap: Vec<Vec<f64>>,
    fig5_optimal: Vec<u32>,
    /// Compute-SNR anchors: the ideal 8-bit SNDR and the RAELLA
    /// S/M/L/XL clipping ladder (per-variant `compute_snr_db`).
    snr_ideal_8bit: f64,
    snr_raella: Vec<f64>,
}

fn compute() -> Computed {
    let model = AdcModel::default();
    let survey = generate_survey(&SurveyConfig::default());
    let d2 = fig2(&survey, &model, LINE_POINTS);
    let d3 = fig3(&survey, &model, LINE_POINTS);
    assert_eq!(d2.lines.len(), 3);
    let throughputs_23: Vec<f64> = d2.lines[0].1.iter().map(|p| p.0).collect();
    let line_values = |lines: &[(f64, Vec<(f64, f64)>)]| -> Vec<Vec<f64>> {
        lines.iter().map(|(_, pts)| pts.iter().map(|p| p.1).collect()).collect()
    };

    let cells = fig5(&model, FIG5_STEPS).unwrap();
    assert_eq!(cells.len(), FIG5_STEPS * FIG5_NADCS.len());
    let mut fig5_throughputs = Vec::new();
    let mut fig5_energy = Vec::new();
    let mut fig5_area = Vec::new();
    let mut fig5_eap = Vec::new();
    let mut fig5_optimal = Vec::new();
    for group in cells.chunks(FIG5_NADCS.len()) {
        fig5_throughputs.push(group[0].total_throughput);
        let ns: Vec<u32> = group.iter().map(|c| c.n_adcs).collect();
        assert_eq!(ns, FIG5_NADCS, "fig5 cell order changed");
        assert!(group.iter().all(|c| c.total_throughput == group[0].total_throughput));
        fig5_energy.push(group.iter().map(|c| c.energy_pj).collect());
        fig5_area.push(group.iter().map(|c| c.area_um2).collect());
        fig5_eap.push(group.iter().map(|c| c.eap).collect());
        let best: &Fig5Cell = group.iter().min_by(|a, b| a.eap.total_cmp(&b.eap)).unwrap();
        fig5_optimal.push(best.n_adcs);
    }
    let snr_raella = RaellaVariant::ALL
        .iter()
        .map(|&v| {
            let a = raella(v);
            compute_snr_db(a.sum_size, a.cell_bits, a.adc.enob)
        })
        .collect();
    Computed {
        throughputs_23,
        fig2_values: line_values(&d2.lines),
        fig3_values: line_values(&d3.lines),
        fig5_throughputs,
        fig5_energy,
        fig5_area,
        fig5_eap,
        fig5_optimal,
        snr_ideal_8bit: ideal_sndr_db(8.0),
        snr_raella,
    }
}

fn write_golden(c: &Computed) {
    fn rows(vals: &[Vec<f64>]) -> Value {
        Value::Array(
            vals.iter()
                .map(|row| Value::Array(row.iter().map(|&x| Value::Number(x)).collect()))
                .collect(),
        )
    }
    fn list(vals: &[f64]) -> Value {
        Value::Array(vals.iter().map(|&x| Value::Number(x)).collect())
    }
    let fig23 = |values: &[Vec<f64>], throughputs: &[f64]| {
        let mut t = BTreeMap::new();
        t.insert("line_points".into(), Value::Number(LINE_POINTS as f64));
        t.insert("enobs".into(), list(&[4.0, 8.0, 12.0]));
        t.insert("throughputs".into(), list(throughputs));
        t.insert("values".into(), rows(values));
        Value::Table(t)
    };
    let mut f5 = BTreeMap::new();
    f5.insert("throughput_steps".into(), Value::Number(FIG5_STEPS as f64));
    f5.insert("throughputs".into(), list(&c.fig5_throughputs));
    f5.insert(
        "n_adcs".into(),
        Value::Array(FIG5_NADCS.iter().map(|&n| Value::Number(n as f64)).collect()),
    );
    f5.insert("energy_pj".into(), rows(&c.fig5_energy));
    f5.insert("area_um2".into(), rows(&c.fig5_area));
    f5.insert("eap".into(), rows(&c.fig5_eap));
    f5.insert(
        "optimal_n_adcs".into(),
        Value::Array(c.fig5_optimal.iter().map(|&n| Value::Number(n as f64)).collect()),
    );
    let mut snr = BTreeMap::new();
    snr.insert("cell_bits".into(), Value::Number(2.0));
    snr.insert(
        "enobs".into(),
        list(&RaellaVariant::ALL.map(|v| raella(v).adc.enob)),
    );
    snr.insert("ideal_8bit_db".into(), Value::Number(c.snr_ideal_8bit));
    snr.insert(
        "n_sums".into(),
        list(&RaellaVariant::ALL.map(|v| raella(v).sum_size as f64)),
    );
    snr.insert("values_db".into(), list(&c.snr_raella));
    snr.insert(
        "variants".into(),
        Value::Array(
            RaellaVariant::ALL
                .iter()
                .map(|v| Value::String(v.name().to_lowercase()))
                .collect(),
        ),
    );
    let mut root = BTreeMap::new();
    root.insert("schema".into(), Value::Number(1.0));
    root.insert("snr_metric".into(), Value::Table(snr));
    root.insert("model".into(), Value::String("generator_truth".into()));
    root.insert("rel_tol".into(), Value::Number(1e-9));
    root.insert("fig2_energy".into(), fig23(&c.fig2_values, &c.throughputs_23));
    root.insert("fig3_area".into(), fig23(&c.fig3_values, &c.throughputs_23));
    root.insert("fig5_eap".into(), Value::Table(f5));
    let text = Value::Table(root).to_json_string().unwrap() + "\n";
    std::fs::write(golden_path(), text).unwrap();
    eprintln!("golden_figures: rewrote {:?} from the current model", golden_path());
}

#[test]
fn figures_match_golden_values() {
    let computed = compute();
    if std::env::var("CIMDSE_UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false) {
        write_golden(&computed);
    }
    let text = std::fs::read_to_string(golden_path()).unwrap_or_else(|e| {
        panic!(
            "missing golden file {:?} ({e}); regenerate with CIMDSE_UPDATE_GOLDEN=1",
            golden_path()
        )
    });
    let golden = parse_json(&text).unwrap();
    assert_eq!(golden.require_usize("schema").unwrap(), 1);
    assert_eq!(golden.require_str("model").unwrap(), "generator_truth");
    let rel_tol = golden.require_f64("rel_tol").unwrap();
    assert!(rel_tol > 0.0 && rel_tol <= 1e-6, "tolerance must stay tight, got {rel_tol}");

    for (fig, computed_vals) in
        [("fig2_energy", &computed.fig2_values), ("fig3_area", &computed.fig3_values)]
    {
        let section = golden.get(fig).unwrap_or_else(|| panic!("golden lacks `{fig}`"));
        assert_eq!(section.require_usize("line_points").unwrap(), LINE_POINTS);
        let throughputs = f64_list(section, "throughputs");
        assert_eq!(throughputs.len(), LINE_POINTS);
        for (j, (&got, &want)) in
            computed.throughputs_23.iter().zip(&throughputs).enumerate()
        {
            // The x-grid itself is part of the contract (logspace drift
            // would silently re-anchor every pinned value).
            assert_close(got, want, 1e-12, &format!("{fig} throughput[{j}]"));
        }
        let rows = f64_rows(section, "values");
        assert_eq!(rows.len(), 3, "{fig}: one row per ENOB line");
        let enobs = f64_list(section, "enobs");
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), LINE_POINTS);
            for (j, &want) in row.iter().enumerate() {
                assert_close(
                    computed_vals[i][j],
                    want,
                    rel_tol,
                    &format!("{fig} ENOB {} point {j}", enobs[i]),
                );
            }
        }
    }

    let f5 = golden.get("fig5_eap").expect("golden lacks `fig5_eap`");
    assert_eq!(f5.require_usize("throughput_steps").unwrap(), FIG5_STEPS);
    let throughputs = f64_list(f5, "throughputs");
    assert_eq!(throughputs.len(), FIG5_STEPS);
    for (j, (&got, &want)) in computed.fig5_throughputs.iter().zip(&throughputs).enumerate() {
        assert_close(got, want, 1e-12, &format!("fig5 throughput[{j}]"));
    }
    for (name, computed_rows) in [
        ("energy_pj", &computed.fig5_energy),
        ("area_um2", &computed.fig5_area),
        ("eap", &computed.fig5_eap),
    ] {
        let rows = f64_rows(f5, name);
        assert_eq!(rows.len(), FIG5_STEPS, "fig5 `{name}`");
        for (ti, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), FIG5_NADCS.len());
            for (ni, &want) in row.iter().enumerate() {
                assert_close(
                    computed_rows[ti][ni],
                    want,
                    rel_tol,
                    &format!("fig5 {name} tp[{ti}] n_adcs={}", FIG5_NADCS[ni]),
                );
            }
        }
    }
    // The per-throughput EAP-optimal ADC count is pinned exactly (the
    // golden optima have >=2% EAP margins, far above the tolerance).
    let optimal = f64_list(f5, "optimal_n_adcs");
    let optimal: Vec<u32> = optimal.iter().map(|&x| x as u32).collect();
    assert_eq!(computed.fig5_optimal, optimal, "fig5 optimal n_adcs per throughput");

    // Compute-SNR anchors (rust/docs/snr_metric.md): the textbook ideal
    // 8-bit SNDR and the RAELLA S/M/L/XL clipping ladder.
    let snr = golden.get("snr_metric").expect("golden lacks `snr_metric`");
    assert_eq!(snr.require_usize("cell_bits").unwrap(), 2);
    assert_close(
        computed.snr_ideal_8bit,
        snr.require_f64("ideal_8bit_db").unwrap(),
        rel_tol,
        "snr_metric ideal_8bit_db",
    );
    assert!((computed.snr_ideal_8bit - 49.92).abs() < 1e-9, "6.02*8 + 1.76 drifted");
    let n_sums = f64_list(snr, "n_sums");
    let enobs = f64_list(snr, "enobs");
    for (i, &v) in RaellaVariant::ALL.iter().enumerate() {
        let a = raella(v);
        assert_eq!(n_sums[i], a.sum_size as f64, "snr_metric n_sums[{i}]");
        assert_eq!(enobs[i], a.adc.enob, "snr_metric enobs[{i}]");
    }
    let values = f64_list(snr, "values_db");
    assert_eq!(values.len(), RaellaVariant::ALL.len());
    for (i, (&got, &want)) in computed.snr_raella.iter().zip(&values).enumerate() {
        assert_close(got, want, rel_tol, &format!("snr_metric values_db[{i}]"));
    }
    // Bigger variants trade +1 ADC bit for +2 lossless bits: the
    // combined SNR still rises monotonically S -> XL (all ~22 dB).
    for w in computed.snr_raella.windows(2) {
        assert!(w[0] < w[1], "clipping ladder must rise: {:?}", computed.snr_raella);
    }
}
