//! Differential property suite for the fast sweep tier (satellite of
//! the SIMD-lane PR): randomized inputs drive the fast kernel against
//! the exact libm-backed path and assert the documented contracts:
//!
//! * `pow10_fast` / `pow10x4` stay within `fastmath::MAX_ULP` of libm
//!   in the fast region and are bit-identical in the fallback region
//!   (extremes, denormal-scale arguments, NaN/±inf);
//! * fast-tier sweeps over arbitrary specs and tuned models stay within
//!   `MAX_ULP` of the exact tier per metric, and their bytes do not
//!   depend on worker count (quad vs. tail kernels agree bitwise);
//! * the exact tier through every driver stays **bit-identical** to
//!   `AdcModel::eval` — the fast tier must not perturb it;
//! * real workload throughputs (zoo networks mapped onto RAELLA) behave
//!   the same as synthetic grids.
//!
//! Scalar-vs-AVX2 backend parity is asserted inside
//! `util::fastmath::tests::pow10x4_matches_scalar_bitwise`; this file's
//! claims therefore hold verbatim with and without `--features simd`.

use cimdse::adc::{AdcModel, AdcQuery, PreparedModel, TuningPoint};
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::dse::{
    NativeEvaluator, SweepSpec, SweepTier, run_sweep, run_sweep_fold_tier, run_sweep_prepared,
    run_sweep_prepared_tier,
};
use cimdse::mapper::map_layer;
use cimdse::testing::{Config, check};
use cimdse::util::Rng;
use cimdse::util::fastmath::{MAX_ULP, pow10_fast, pow10x4, ulp_distance};
use cimdse::util::logspace::{log10, pow10};
use cimdse::workload::zoo::by_name;

/// A random spec with 0..=4 values per axis, inside the model's valid
/// ranges (mirrors the generator in `sweep_stream_properties.rs`).
fn arbitrary_spec(rng: &mut Rng) -> SweepSpec {
    let axis_len = |rng: &mut Rng| rng.index(5);
    SweepSpec {
        enobs: (0..axis_len(rng)).map(|_| rng.uniform(2.0, 14.0)).collect(),
        total_throughputs: (0..axis_len(rng))
            .map(|_| 10f64.powf(rng.uniform(4.0, 10.5)))
            .collect(),
        tech_nms: (0..axis_len(rng)).map(|_| rng.uniform(7.0, 180.0)).collect(),
        n_adcs: (0..axis_len(rng)).map(|_| 1 + rng.index(64) as u32).collect(),
    }
}

/// Default or tuned model, so the offset-decade rows are exercised too.
fn arbitrary_model(rng: &mut Rng) -> AdcModel {
    let base = AdcModel::default();
    if rng.bool(0.5) {
        return base;
    }
    base.tuned_to(&TuningPoint {
        query: AdcQuery {
            enob: rng.uniform(4.0, 10.0),
            total_throughput: 10f64.powf(rng.uniform(6.0, 10.0)),
            tech_nm: 32.0,
            n_adcs: 1,
        },
        energy_pj_per_convert: 10f64.powf(rng.uniform(-1.0, 1.5)),
        area_um2: if rng.bool(0.5) { Some(10f64.powf(rng.uniform(2.0, 5.0))) } else { None },
    })
}

/// Max per-metric ULP distance between two evaluated sweeps, asserting
/// the queries line up.
fn max_sweep_ulp(
    exact: &[cimdse::dse::EvaluatedPoint],
    fast: &[cimdse::dse::EvaluatedPoint],
) -> u64 {
    assert_eq!(exact.len(), fast.len());
    let mut worst = 0u64;
    for (a, b) in exact.iter().zip(fast) {
        assert_eq!(a.query, b.query);
        for (ea, eb) in a.metrics.to_bits().iter().zip(b.metrics.to_bits()) {
            worst = worst.max(ulp_distance(f64::from_bits(*ea), f64::from_bits(eb)));
        }
    }
    worst
}

#[test]
fn pow10_fast_randomized_ulp_bound_and_fallback_bit_identity() {
    check(Config::default().cases(400).seed(71), |rng| {
        // Fast region: within the documented bound of libm.
        for _ in 0..256 {
            let x = rng.uniform(-15.5, 15.5);
            let d = ulp_distance(pow10_fast(x), pow10(x));
            assert!(d <= MAX_ULP, "x={x} ulp={d}");
        }
        // Fallback region (|round(x)| > 15): bit-identical to libm,
        // overflow/underflow/denormal results included.
        let x = if rng.bool(0.5) { rng.uniform(15.5, 340.0) } else { rng.uniform(-340.0, -15.5) };
        if x.abs() > 15.5 {
            assert_eq!(pow10_fast(x).to_bits(), pow10(x).to_bits(), "x={x}");
        }
        // Lane batches equal four scalar calls bit-for-bit on random
        // quads straddling both regions.
        let lane = |rng: &mut Rng| rng.uniform(-20.0, 20.0);
        let xs = [lane(rng), lane(rng), lane(rng), lane(rng)];
        let batch = pow10x4(xs);
        for l in 0..4 {
            assert_eq!(batch[l].to_bits(), pow10_fast(xs[l]).to_bits(), "lane {l} of {xs:?}");
        }
    });
}

#[test]
fn fast_sweeps_are_ulp_bounded_and_worker_independent() {
    check(Config::default().cases(60).seed(72), |rng| {
        let spec = arbitrary_spec(rng);
        let model = arbitrary_model(rng);
        let exact = run_sweep_prepared(&spec, &model, 1).unwrap();
        let fast1 = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
        let fast4 = run_sweep_prepared_tier(&spec, &model, 4, SweepTier::Fast).unwrap();
        assert!(max_sweep_ulp(&exact, &fast1) <= MAX_ULP);
        // Worker count must not change a single byte of fast output.
        assert_eq!(fast1.len(), fast4.len());
        for (a, b) in fast1.iter().zip(&fast4) {
            assert_eq!(a.query, b.query);
            assert_eq!(a.metrics.to_bits(), b.metrics.to_bits());
        }
    });
}

#[test]
fn odd_tail_specs_match_the_scalar_fast_reference_bitwise() {
    // Grid sizes with every lane remainder (len % 4 ∈ {0,1,2,3}): the
    // quad kernel and the scalar tail must be indistinguishable, so the
    // whole fast sweep equals a pure `eval_log_f_fast` replay bit-wise.
    let model = AdcModel::default();
    let prepared = PreparedModel::new(&model);
    for n_thr in [1usize, 2, 3, 4, 5, 6, 7, 9, 13] {
        let spec = SweepSpec {
            enobs: vec![4.0, 9.5],
            total_throughputs: cimdse::util::logspace::logspace(1e5, 1e10, n_thr),
            tech_nms: vec![32.0],
            n_adcs: vec![1, 8, 64],
        };
        let fast = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
        assert_eq!(fast.len(), spec.len());
        for (p, q) in fast.iter().zip(spec.points()) {
            assert_eq!(p.query, q);
            let row = prepared.row(q.enob, q.tech_nm);
            let reference = row.eval_log_f_fast(
                log10(q.total_throughput / q.n_adcs as f64),
                q.total_throughput,
                q.n_adcs,
            );
            assert_eq!(p.metrics.to_bits(), reference.to_bits(), "n_thr={n_thr} q={q:?}");
        }
    }
}

#[test]
fn fast_fold_streams_the_same_bytes_as_the_materialized_fast_sweep() {
    check(Config::default().cases(40).seed(73), |rng| {
        let spec = arbitrary_spec(rng);
        let model = arbitrary_model(rng);
        let materialized = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
        for workers in [1usize, 4] {
            let mut replayed = run_sweep_fold_tier(
                &spec,
                &model,
                workers,
                SweepTier::Fast,
                Vec::new,
                |acc: &mut Vec<(usize, AdcQuery, [u64; 4])>, i, q, m| {
                    acc.push((i, *q, m.to_bits()));
                },
                |mut a, b| {
                    a.extend(b);
                    a
                },
            );
            replayed.sort_by_key(|(i, _, _)| *i);
            assert_eq!(replayed.len(), materialized.len(), "workers={workers}");
            for ((i, q, bits), p) in replayed.iter().zip(&materialized) {
                assert_eq!(*q, p.query, "index {i}");
                assert_eq!(*bits, p.metrics.to_bits(), "index {i} workers={workers}");
            }
        }
    });
}

#[test]
fn exact_tier_through_every_driver_stays_bit_identical_to_model_eval() {
    check(Config::default().cases(40).seed(74), |rng| {
        let spec = arbitrary_spec(rng);
        let model = arbitrary_model(rng);
        let baseline = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        // Explicit-tier prepared driver on Exact == eval path.
        let exact = run_sweep_prepared_tier(&spec, &model, 4, SweepTier::Exact).unwrap();
        assert_eq!(baseline.len(), exact.len());
        for (a, b) in baseline.iter().zip(&exact) {
            assert_eq!(a.query, b.query);
            assert_eq!(a.metrics.to_bits(), b.metrics.to_bits());
        }
        // NativeEvaluator defaults to Exact; with_tier(Fast) routes to
        // the lane kernel and must equal the prepared fast driver.
        let fast_eval =
            run_sweep(&spec, &NativeEvaluator::serial(model).with_tier(SweepTier::Fast)).unwrap();
        let fast_prep = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
        for (a, b) in fast_eval.iter().zip(&fast_prep) {
            assert_eq!(a.query, b.query);
            assert_eq!(a.metrics.to_bits(), b.metrics.to_bits());
        }
    });
}

#[test]
fn extreme_log_f_regimes_fall_back_bit_identically() {
    // Denormal-scale per-ADC throughput (log_f ≈ -308) and huge
    // throughput / n_adcs combinations push `pow10` far outside the
    // decade table: the fast tier must take the libm fallback there and
    // thus reproduce the exact tier bit-for-bit.
    let model = AdcModel::default();
    let spec = SweepSpec {
        enobs: vec![2.0, 8.0, 14.0],
        total_throughputs: vec![f64::MIN_POSITIVE, 1e-30, 1e30, 1e300],
        tech_nms: vec![7.0, 180.0],
        n_adcs: vec![1, u32::MAX],
    };
    let exact = run_sweep_prepared(&spec, &model, 1).unwrap();
    let fast = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
    assert_eq!(exact.len(), fast.len());
    for (a, b) in exact.iter().zip(&fast) {
        assert_eq!(a.query, b.query);
        // Not every extreme point lands in the fallback (the energy
        // exponent may stay in range while the area one leaves it, and
        // vice versa), so assert the ULP envelope everywhere and bit
        // identity wherever both pow10 arguments left the fast region.
        let ulp = a
            .metrics
            .to_bits()
            .iter()
            .zip(b.metrics.to_bits())
            .map(|(ea, eb)| ulp_distance(f64::from_bits(*ea), f64::from_bits(eb)))
            .max()
            .unwrap();
        assert!(ulp <= MAX_ULP, "q={:?} ulp={ulp}", a.query);
        if a.query.total_throughput >= 1e300 {
            // log_f ≥ ~290 pushes both the energy exponent (b3·log_f)
            // and the area exponent (d2·log_f + d3·log_e) far outside
            // the decade table -> both pow10s take the libm fallback
            // and every metric is bit-identical (energy overflows to
            // +inf identically on both tiers).
            assert_eq!(a.metrics.to_bits(), b.metrics.to_bits(), "q={:?}", a.query);
        }
        if a.query.total_throughput == f64::MIN_POSITIVE {
            // log_f ≈ -308: the energy exponent clamps to its in-range
            // floor (still approximate), but d2·log_f throws the area
            // exponent out of range -> the area metrics fall back and
            // must match bit-for-bit.
            assert_eq!(
                a.metrics.area_um2_per_adc.to_bits(),
                b.metrics.area_um2_per_adc.to_bits(),
                "q={:?}",
                a.query
            );
            assert_eq!(
                a.metrics.total_area_um2.to_bits(),
                b.metrics.total_area_um2.to_bits(),
                "q={:?}",
                a.query
            );
        }
    }
}

#[test]
fn zoo_workload_throughputs_stay_in_the_ulp_envelope() {
    // Real adc_converts rates from the three zoo networks mapped onto
    // RAELLA-Medium, used as sweep throughput axes: the fast tier must
    // hold its bound on production-shaped inputs, not just synthetic
    // grids.
    let arch = raella(RaellaVariant::Medium);
    let model = AdcModel::default();
    for name in ["resnet18", "vgg16", "lenet"] {
        let workload = by_name(name).unwrap();
        let mut throughputs: Vec<f64> = workload
            .layers
            .iter()
            .map(|l| map_layer(&arch, l).unwrap().counts.adc_converts)
            .filter(|c| *c > 0.0)
            .collect();
        throughputs.truncate(8);
        let spec = SweepSpec {
            enobs: vec![4.0, 7.0, 11.0],
            total_throughputs: throughputs,
            tech_nms: vec![22.0, 32.0],
            n_adcs: vec![1, 16, 128],
        };
        let exact = run_sweep_prepared(&spec, &model, 1).unwrap();
        let fast = run_sweep_prepared_tier(&spec, &model, 1, SweepTier::Fast).unwrap();
        let worst = max_sweep_ulp(&exact, &fast);
        assert!(worst <= MAX_ULP, "{name}: worst ULP {worst}");
    }
}
