//! End-to-end pipeline integration: survey → fit → model → mapper →
//! rollup → figures, all through the public API (no artifacts needed).

use cimdse::adc::{AdcModel, AdcQuery, fit_model};
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::arch::{self};
use cimdse::dse::{NativeEvaluator, SweepSpec, figures, pareto_front, run_sweep};
use cimdse::energy::{AreaScope, accel_area, workload_energy};
use cimdse::mapper::{arrays_for_workload, map_layer};
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::workload::resnet18::{large_tensor_layer, resnet18};

#[test]
fn full_pipeline_survey_to_figures() {
    // 1. Survey + fit.
    let survey = generate_survey(&SurveyConfig::default());
    let report = fit_model(&survey).unwrap();
    let model = AdcModel::new(report.coefs);

    // 2. Figures 2-5 off the fitted model (shape assertions live in the
    //    figures module's unit tests; here we assert the pipeline runs and
    //    the cross-figure invariants hold).
    let f2 = figures::fig2(&survey, &model, 20);
    let f3 = figures::fig3(&survey, &model, 20);
    let f4 = figures::fig4(&model).unwrap();
    let f5 = figures::fig5(&model, 5).unwrap();

    assert_eq!(f2.lines.len(), 3);
    assert_eq!(f3.lines.len(), 3);
    assert_eq!(f4.len(), 12);
    assert_eq!(f5.len(), 25);

    // Fig. 2 lines and Fig. 3 lines are linked through Eq. 1: area grows
    // with energy at fixed (tech, throughput).
    for (le, la) in f2.lines.iter().zip(&f3.lines) {
        assert_eq!(le.0, la.0);
        for (pe, pa) in le.1.iter().zip(&la.1) {
            assert!(pa.1 > 0.0 && pe.1 > 0.0);
        }
    }
}

#[test]
fn fitted_vs_truth_model_figures_agree_qualitatively() {
    // The paper's claims must be robust to fitting noise: regenerate
    // Fig. 4 with the truth model and with a fitted model; the winning
    // variant per layer-group must match.
    let survey = generate_survey(&SurveyConfig::default());
    let fitted = AdcModel::new(fit_model(&survey).unwrap().coefs);
    let truth = AdcModel::default();

    let best = |rows: &[figures::Fig4Row], group: &str| -> &'static str {
        rows.iter()
            .filter(|r| r.group == group)
            .min_by(|a, b| a.total_pj.total_cmp(&b.total_pj))
            .unwrap()
            .variant
    };
    let rows_f = figures::fig4(&fitted).unwrap();
    let rows_t = figures::fig4(&truth).unwrap();
    for group in ["large-tensor", "small-tensor"] {
        assert_eq!(best(&rows_f, group), best(&rows_t, group), "group {group}");
    }
}

#[test]
fn toml_arch_roundtrip_matches_preset() {
    // A RAELLA-M written as TOML parses to the same mapping behaviour.
    let m = raella(RaellaVariant::Medium);
    let doc = format!(
        r#"
name = "{}"
tech_nm = {}
[array]
rows = {}
cols = {}
sum_size = {}
cell_bits = {}
[precision]
weight_bits = {}
act_bits = {}
[adc]
enob = {}
n_adcs = {}
total_throughput = {}
[buffers]
sram_bytes = {}
edram_bytes = {}
"#,
        m.name,
        m.tech_nm,
        m.array_rows,
        m.array_cols,
        m.sum_size,
        m.cell_bits,
        m.weight_bits,
        m.act_bits,
        m.adc.enob,
        m.adc.n_adcs,
        m.adc.total_throughput,
        m.sram_bytes,
        m.edram_bytes
    );
    let parsed = arch::from_toml(&doc).unwrap();
    assert_eq!(parsed, m);
    let layer = large_tensor_layer();
    let a = map_layer(&parsed, &layer).unwrap();
    let b = map_layer(&m, &layer).unwrap();
    assert_eq!(a.counts, b.counts);
}

#[test]
fn resnet18_energy_is_adc_significant_and_finite() {
    let model = AdcModel::default();
    let net = resnet18();
    for variant in RaellaVariant::ALL {
        let arch = raella(variant);
        let e = workload_energy(&arch, &model, &net).unwrap();
        assert!(e.total_pj().is_finite() && e.total_pj() > 0.0);
        // The paper's premise: ADC energy is significant at accelerator level.
        assert!(e.adc_fraction() > 0.05, "{}: {}", arch.name, e.adc_fraction());
        let arrays = arrays_for_workload(&arch, &net.layers);
        assert!(arrays > 0);
        let area = accel_area(&arch, &model, AreaScope::Tile { n_arrays: arrays });
        assert!(area.total_um2() > 0.0);
    }
}

#[test]
fn sweep_pareto_front_is_consistent_across_workers() {
    let model = AdcModel::default();
    let spec = SweepSpec::dense(8);
    let serial = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
    let parallel = run_sweep(&spec, &NativeEvaluator::new(model)).unwrap();
    let obj = |pts: &[cimdse::dse::EvaluatedPoint]| -> Vec<(f64, f64)> {
        pts.iter()
            .map(|p| (p.metrics.total_power_w, p.metrics.total_area_um2))
            .collect()
    };
    assert_eq!(pareto_front(&obj(&serial)), pareto_front(&obj(&parallel)));
}

#[test]
fn interpolation_story_prior_work_could_not_do() {
    // §I: prior work was stuck at fixed design points (e.g. 7-bit, 32 nm,
    // 1e9 conv/s) and "can not interpolate (e.g., 7-bit, 65 nm, vary
    // throughput from 1e6 to 1e9)". Verify the model interpolates that
    // exact example smoothly: energy must be finite, positive, monotone
    // non-decreasing over the sweep, flat at low throughput.
    let model = AdcModel::default();
    let mut prev = 0.0;
    for step in 0..=30 {
        let f = 1e6 * 10f64.powf(step as f64 / 10.0);
        let q = AdcQuery { enob: 7.0, total_throughput: f, tech_nm: 65.0, n_adcs: 1 };
        let e = model.energy_pj_per_convert(&q);
        assert!(e.is_finite() && e > 0.0);
        assert!(e >= prev - 1e-12, "non-monotone at {f}");
        prev = e;
    }
    // Flat region: 1e6 and 1e7 identical; knee region: 1e9 strictly higher.
    let e = |f: f64| {
        model.energy_pj_per_convert(&AdcQuery {
            enob: 7.0,
            total_throughput: f,
            tech_nm: 65.0,
            n_adcs: 1,
        })
    };
    assert!((e(1e6) - e(1e7)).abs() / e(1e6) < 1e-12);
    assert!(e(1e9) > e(1e6));
}
