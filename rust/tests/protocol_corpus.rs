//! Corpus-driven protocol contract: every frame in
//! `tests/protocol_corpus.json` is sent verbatim over a real socket to
//! a live server (running with the corpus's `--max-sweep-points`
//! budget) and must earn exactly the stable error code the corpus
//! pins — or be accepted, for the budget-boundary cases. One
//! connection carries the whole corpus, so the suite also proves that
//! no amount of consecutive abuse costs a client its connection.
//!
//! The corpus runs against **both serving cores** and every response
//! line must be byte-identical across them — the socket-level
//! cross-core contract. The protocol-v2 behaviors a lockstep corpus
//! cannot reach (interim progress frames, live-target cancel,
//! cancel-on-disconnect, v1 purity) get their own tests below, all
//! against the event-loop core that implements them.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use cimdse::adc::AdcModel;
use cimdse::config::{Value, parse_json};
use cimdse::service::protocol::{
    CODE_CANCELLED, CODE_INTERNAL, Reject, error_frame, is_interim_frame,
};
use cimdse::service::{Client, MAX_FRAME_BYTES, ServeCore, ServeOptions, Server};

/// A live server plus the plumbing tests need to talk to and stop it.
struct Harness {
    addr: String,
    handle: cimdse::service::ServerHandle,
    join: std::thread::JoinHandle<()>,
}

fn start(core: ServeCore, workers: usize, budget: Option<usize>, every: Option<usize>) -> Harness {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        model: AdcModel::default(),
        cache_capacity: 4,
        workers,
        max_sweep_points: budget,
        core,
        progress_every: every,
    })
    .expect("bind");
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.serve().expect("serve"));
    Harness { addr, handle, join }
}

impl Harness {
    fn stop(self) {
        let mut client = Client::connect(&self.addr).unwrap();
        client.shutdown().unwrap();
        drop(self.handle);
        self.join.join().expect("server drains cleanly");
    }
}

/// One lockstep line-oriented connection. Reads skip v2 interim frames
/// (progress/keepalive prove liveness, they are never the response).
struct Wire {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Wire {
    fn connect(addr: &str) -> Wire {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Wire { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send(&mut self, frame: &str) {
        self.writer.write_all(frame.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    /// Next raw line (no trailing newline), interim or final.
    fn read_raw(&mut self) -> Option<String> {
        let mut line = String::new();
        if self.reader.read_line(&mut line).unwrap() == 0 {
            return None;
        }
        Some(line.trim_end_matches(['\n', '\r']).to_string())
    }

    /// Next *final* response line, skipping interim frames.
    fn read_response(&mut self) -> String {
        loop {
            let line = self.read_raw().expect("the server must answer, never disconnect");
            let doc = parse_json(&line).expect("response parses");
            if !is_interim_frame(&doc) {
                return line;
            }
        }
    }
}

/// Run every socket case of the corpus against `core` in lockstep,
/// asserting each pinned code; returns the raw response lines for
/// cross-core comparison.
fn run_corpus_on(corpus: &Value, core: ServeCore) -> Vec<String> {
    let budget = corpus.require_usize("server.max_sweep_points").unwrap();
    let harness = start(core, 2, Some(budget), None);
    let mut wire = Wire::connect(&harness.addr);

    let cases = corpus.get("cases").and_then(Value::as_array).expect("corpus has cases");
    let mut lines = Vec::new();
    let mut expected_error_frames = 0u64;
    for case in cases {
        let name = case.require_str("name").unwrap();
        if case.get("via").is_some() {
            continue; // exercised in-process, once, by the main test
        }
        let mut frame = case.require_str("frame").unwrap().to_string();
        if let Some(pad) = case.get("pad_to").and_then(Value::as_f64) {
            frame = frame.replace("@PAD@", &"x".repeat(pad as usize));
            assert!(
                frame.len() > MAX_FRAME_BYTES,
                "{name}: padded frame must exceed the cap ({} bytes)",
                frame.len()
            );
        }
        assert!(!frame.contains('\n'), "{name}: corpus frames are single lines");
        wire.send(&frame);
        let line = wire.read_response();
        let resp = parse_json(&line)
            .unwrap_or_else(|e| panic!("{name}: unparsable response `{line}`: {e}"));
        match case.require_str("expect").unwrap() {
            "ok" => {
                assert_eq!(
                    resp.get("ok").and_then(Value::as_bool),
                    Some(true),
                    "{name}: expected acceptance, got {line}"
                );
            }
            code => {
                expected_error_frames += 1;
                assert_eq!(
                    resp.get("ok").and_then(Value::as_bool),
                    Some(false),
                    "{name}: expected rejection, got {line}"
                );
                assert_eq!(
                    resp.require_str("error.code").unwrap(),
                    code,
                    "{name}: wrong code in {line}"
                );
            }
        }
        // Trace-context contract: a traced request's every response
        // frame echoes the validated table verbatim; an untraced (or
        // null-traced) request's response must not carry a `trace` key
        // at all — that absence is what keeps untraced traffic
        // byte-identical to the pre-trace protocol.
        match case.get("echo_trace") {
            Some(expected) => assert_eq!(
                resp.get("trace"),
                Some(expected),
                "{name}: response must echo the request's trace context: {line}"
            ),
            None => assert!(
                resp.get("trace").is_none(),
                "{name}: untraced response must not grow a `trace` key: {line}"
            ),
        }
        lines.push(line);
    }

    // The same connection still serves, and the server counted exactly
    // one error frame per rejected corpus case.
    wire.send("{\"op\": \"metrics\"}");
    let line = wire.read_response();
    let resp = parse_json(&line).unwrap();
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{line}");
    assert_eq!(
        resp.require_f64("result.error_frames").unwrap(),
        expected_error_frames as f64,
        "{line}"
    );
    drop(wire);

    harness.stop();
    lines
}

fn load_corpus() -> Value {
    let corpus_text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/protocol_corpus.json"
    ))
    .expect("read protocol corpus");
    let corpus = parse_json(&corpus_text).expect("corpus parses");
    assert_eq!(corpus.require_usize("schema").unwrap(), 2);
    corpus
}

#[test]
fn corpus_frames_earn_their_exact_codes_on_both_cores_byte_identically() {
    let corpus = load_corpus();
    let cases = corpus.get("cases").and_then(Value::as_array).expect("corpus has cases");
    assert!(cases.len() >= 20, "the corpus should stay substantial ({} cases)", cases.len());

    // In-process coverage for codes a correct lockstep server cannot be
    // provoked into sending over this socket corpus. Build the frame
    // through the same public API the server uses and pin its wire
    // shape; the codes that *are* reachable live (`cancelled`) earn
    // their socket coverage in the pipelined-cancel test below.
    for case in cases {
        let Some(via) = case.get("via").and_then(Value::as_str) else { continue };
        let name = case.require_str("name").unwrap();
        assert_eq!(via, "error-frame", "{name}: unknown `via` kind `{via}`");
        let expect = case.require_str("expect").unwrap();
        let code = match expect {
            "internal" => CODE_INTERNAL,
            "cancelled" => CODE_CANCELLED,
            other => panic!("{name}: no error-frame builder for code `{other}`"),
        };
        let frame = error_frame(Some("shard"), None, &Reject::new(code, "synthetic failure"));
        assert!(!frame.contains('\n'), "{name}: frames are single lines");
        let doc = parse_json(&frame)
            .unwrap_or_else(|e| panic!("{name}: unparsable frame `{frame}`: {e}"));
        assert_eq!(doc.get("ok").and_then(Value::as_bool), Some(false), "{name}");
        assert_eq!(doc.require_str("error.code").unwrap(), expect, "{name}");
        assert_eq!(doc.require_str("op").unwrap(), "shard", "{name}");
    }

    let threaded = run_corpus_on(&corpus, ServeCore::Threads);
    let event_loop = run_corpus_on(&corpus, ServeCore::EventLoop);
    assert_eq!(threaded.len(), event_loop.len());
    for (i, (t, e)) in threaded.iter().zip(&event_loop).enumerate() {
        assert_eq!(t, e, "case #{i}: cores must answer byte-identically");
    }
}

/// A v1 connection (no `hello`) must never receive a v2 frame, even on
/// a server configured to emit progress at every point: each request
/// gets exactly one line back, and none of them carry a `frame` key.
#[cfg(unix)]
#[test]
fn v1_connection_sees_zero_v2_frames() {
    let harness = start(ServeCore::EventLoop, 1, None, Some(1));
    let mut wire = Wire::connect(&harness.addr);
    let sweep = r#"{"op": "sweep", "id": "s1", "spec": {"enobs": [4, 6, 8], "total_throughputs": [1e8, 1e9], "tech_nms": [32], "n_adcs": [1, 2]}}"#;
    wire.send(sweep);
    wire.send(r#"{"op": "metrics", "id": "m1"}"#);
    // Lockstep-read exactly two lines: if the server leaked a progress
    // frame for the sweep, the first read would surface it instead of
    // the sweep's response.
    for expect_id in ["s1", "m1"] {
        let line = wire.read_raw().expect("response");
        let doc = parse_json(&line).unwrap();
        assert!(
            !is_interim_frame(&doc),
            "v1 connection received an interim frame: {line}"
        );
        assert_eq!(doc.get("ok").and_then(Value::as_bool), Some(true), "{line}");
        assert_eq!(doc.require_str("id").unwrap(), expect_id, "{line}");
    }
    drop(wire);
    harness.stop();
}

/// After `hello v2` on a serial (workers=1) server with
/// `--progress-every 1`, a 12-point sweep must stream monotonic
/// progress frames before its final response.
#[cfg(unix)]
#[test]
fn v2_connection_streams_progress_frames_under_tiny_cadence() {
    let harness = start(ServeCore::EventLoop, 1, None, Some(1));
    let mut wire = Wire::connect(&harness.addr);
    wire.send(r#"{"op": "hello", "version": 2}"#);
    let hello = parse_json(&wire.read_response()).unwrap();
    assert_eq!(hello.require_usize("result.version").unwrap(), 2);

    let sweep = r#"{"op": "sweep", "id": "s2", "spec": {"enobs": [4, 6, 8], "total_throughputs": [1e8, 1e9], "tech_nms": [32], "n_adcs": [1, 2]}}"#;
    wire.send(sweep);
    let mut progress_done = Vec::new();
    let final_resp = loop {
        let line = wire.read_raw().expect("response");
        let doc = parse_json(&line).unwrap();
        if !is_interim_frame(&doc) {
            break doc;
        }
        match doc.require_str("frame").unwrap() {
            "keepalive" => {}
            "progress" => {
                assert_eq!(doc.require_str("op").unwrap(), "sweep", "{line}");
                assert_eq!(doc.require_str("id").unwrap(), "s2", "{line}");
                assert_eq!(doc.require_usize("total").unwrap(), 12, "{line}");
                progress_done.push(doc.require_usize("done").unwrap());
            }
            other => panic!("unknown interim frame kind `{other}`: {line}"),
        }
    };
    assert_eq!(final_resp.get("ok").and_then(Value::as_bool), Some(true));
    assert_eq!(final_resp.require_str("id").unwrap(), "s2");
    assert_eq!(final_resp.require_usize("result.points").unwrap(), 12);
    assert!(
        progress_done.len() >= 2,
        "a 12-point sweep at --progress-every 1 must stream progress, saw {progress_done:?}"
    );
    assert!(
        progress_done.windows(2).all(|w| w[0] < w[1]),
        "progress must be strictly monotonic: {progress_done:?}"
    );
    assert!(*progress_done.last().unwrap() <= 12, "{progress_done:?}");
    drop(wire);
    harness.stop();
}

/// A pipelined `cancel` naming a queued request must kill it: the
/// cancel answers `cancelled: true` out of band, the in-flight request
/// ahead of it completes normally, and the victim is answered with the
/// `cancelled` error code at its FIFO turn.
#[cfg(unix)]
#[test]
fn pipelined_cancel_kills_a_queued_request() {
    let harness = start(ServeCore::EventLoop, 1, None, None);
    let mut wire = Wire::connect(&harness.addr);
    wire.send(r#"{"op": "hello", "version": 2}"#);
    wire.read_response();

    // One burst: sweep "a", sweep "b" (queued behind "a"), cancel "b".
    // The reactor parses all three before "a" can complete, so the
    // cancel deterministically finds "b" still queued.
    let spec = r#"{"enobs": [4, 6, 8, 10], "total_throughputs": [1e8, 1e9], "tech_nms": [32], "n_adcs": [1, 2]}"#;
    let burst = format!(
        "{{\"op\": \"sweep\", \"id\": \"a\", \"spec\": {spec}}}\n{{\"op\": \"sweep\", \"id\": \"b\", \"spec\": {spec}}}\n{{\"op\": \"cancel\", \"id\": \"c\", \"target\": \"b\"}}\n"
    );
    wire.writer.write_all(burst.as_bytes()).unwrap();
    wire.writer.flush().unwrap();

    let mut by_id = std::collections::BTreeMap::new();
    for _ in 0..3 {
        let line = wire.read_response();
        let doc = parse_json(&line).unwrap();
        by_id.insert(doc.require_str("id").unwrap().to_string(), doc);
    }
    let cancel = &by_id["c"];
    assert_eq!(cancel.get("ok").and_then(Value::as_bool), Some(true));
    assert_eq!(cancel.get("result.cancelled").and_then(Value::as_bool), Some(true));
    assert_eq!(cancel.require_str("result.target").unwrap(), "b");
    let a = &by_id["a"];
    assert_eq!(a.get("ok").and_then(Value::as_bool), Some(true), "the in-flight request ahead of the cancel must finish");
    assert_eq!(a.require_usize("result.points").unwrap(), 16);
    let b = &by_id["b"];
    assert_eq!(b.get("ok").and_then(Value::as_bool), Some(false));
    assert_eq!(b.require_str("error.code").unwrap(), "cancelled");

    // And cancelling it again misses: answered ids are forgotten.
    wire.send(r#"{"op": "cancel", "target": "b"}"#);
    let again = parse_json(&wire.read_response()).unwrap();
    assert_eq!(again.require_str("error.code").unwrap(), "unknown-id");

    // The server counted the cancellation.
    wire.send(r#"{"op": "metrics"}"#);
    let metrics = parse_json(&wire.read_response()).unwrap();
    assert!(metrics.require_f64("result.work.cancelled").unwrap() >= 1.0);
    drop(wire);
    harness.stop();
}

/// Dropping a connection mid-sweep must stop the abandoned work at a
/// chunk boundary: the work counters (observed over a second
/// connection) stall far short of the sweep's full grid and the
/// cancellation is recorded.
#[cfg(unix)]
#[test]
fn disconnect_cancels_in_flight_work() {
    let harness = start(ServeCore::EventLoop, 1, None, Some(1));
    {
        let mut wire = Wire::connect(&harness.addr);
        wire.send(r#"{"op": "hello", "version": 2}"#);
        wire.read_response();
        // 100x40x5x4 = 80_000 points, chunked 1 point at a time: each
        // chunk is a cancellation checkpoint AND a progress completion,
        // so the fold cannot outrun the reactor noticing the dead peer.
        let axes = |n: usize, scale: f64| -> String {
            (1..=n).map(|i| format!("{}", i as f64 * scale)).collect::<Vec<_>>().join(", ")
        };
        let spec = format!(
            "{{\"enobs\": [{}], \"total_throughputs\": [{}], \"tech_nms\": [{}], \"n_adcs\": [1, 2, 4, 8]}}",
            axes(100, 0.1),
            axes(40, 1e8),
            axes(5, 16.0)
        );
        wire.send(&format!("{{\"op\": \"sweep\", \"id\": \"doomed\", \"spec\": {spec}}}"));
        // Wait for the first progress frame so the sweep is provably in
        // flight, then vanish without reading further.
        let line = wire.read_raw().expect("first frame");
        let doc = parse_json(&line).unwrap();
        assert!(is_interim_frame(&doc), "expected an interim frame first, got {line}");
    } // wire drops here: both directions close

    // Over a fresh connection, wait for the cancellation to land, then
    // assert the work stalled well short of the grid.
    let mut probe = Wire::connect(&harness.addr);
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let cancelled = loop {
        probe.send(r#"{"op": "metrics"}"#);
        let metrics = parse_json(&probe.read_response()).unwrap();
        if metrics.require_f64("result.work.cancelled").unwrap() >= 1.0 {
            break metrics;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "abandoned sweep was never cancelled: {metrics:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    };
    let points = cancelled.require_f64("result.work.points").unwrap();
    assert!(
        points < 80_000.0,
        "the abandoned sweep should stop short of its 80k-point grid, burned {points}"
    );
    drop(probe);
    harness.stop();
}
