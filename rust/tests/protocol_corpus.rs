//! Corpus-driven protocol contract: every frame in
//! `tests/protocol_corpus.json` is sent verbatim over a real socket to
//! a live server (running with the corpus's `--max-sweep-points`
//! budget) and must earn exactly the stable error code the corpus
//! pins — or be accepted, for the budget-boundary cases. One
//! connection carries the whole corpus, so the suite also proves that
//! no amount of consecutive abuse costs a client its connection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use cimdse::adc::AdcModel;
use cimdse::config::{Value, parse_json};
use cimdse::service::protocol::{CODE_INTERNAL, Reject, error_frame};
use cimdse::service::{Client, MAX_FRAME_BYTES, ServeOptions, Server};

#[test]
fn corpus_frames_earn_their_exact_codes_over_a_real_socket() {
    let corpus_text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/protocol_corpus.json"
    ))
    .expect("read protocol corpus");
    let corpus = parse_json(&corpus_text).expect("corpus parses");
    assert_eq!(corpus.require_usize("schema").unwrap(), 1);
    let budget = corpus.require_usize("server.max_sweep_points").unwrap();

    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        model: AdcModel::default(),
        cache_capacity: 4,
        workers: 2,
        max_sweep_points: Some(budget),
    })
    .expect("bind");
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.serve().expect("serve"));

    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    let cases = corpus.get("cases").and_then(Value::as_array).expect("corpus has cases");
    assert!(cases.len() >= 20, "the corpus should stay substantial ({} cases)", cases.len());
    let mut expected_error_frames = 0u64;
    for case in cases {
        let name = case.require_str("name").unwrap();
        if let Some(via) = case.get("via").and_then(Value::as_str) {
            // In-process coverage for codes a correct server cannot be
            // provoked into sending over a socket (`internal`: every
            // request is fully validated at parse time, so dispatch
            // cannot fail on a valid one). Build the frame through the
            // same public API the server uses and pin its wire shape.
            assert_eq!(via, "error-frame", "{name}: unknown `via` kind `{via}`");
            let expect = case.require_str("expect").unwrap();
            let code = match expect {
                "internal" => CODE_INTERNAL,
                other => panic!("{name}: no error-frame builder for code `{other}`"),
            };
            let frame =
                error_frame(Some("shard"), None, &Reject::new(code, "synthetic failure"));
            assert!(!frame.contains('\n'), "{name}: frames are single lines");
            let doc = parse_json(&frame)
                .unwrap_or_else(|e| panic!("{name}: unparsable frame `{frame}`: {e}"));
            assert_eq!(doc.get("ok").and_then(Value::as_bool), Some(false), "{name}");
            assert_eq!(doc.require_str("error.code").unwrap(), expect, "{name}");
            assert_eq!(doc.require_str("op").unwrap(), "shard", "{name}");
            continue;
        }
        let mut frame = case.require_str("frame").unwrap().to_string();
        if let Some(pad) = case.get("pad_to").and_then(Value::as_f64) {
            frame = frame.replace("@PAD@", &"x".repeat(pad as usize));
            assert!(
                frame.len() > MAX_FRAME_BYTES,
                "{name}: padded frame must exceed the cap ({} bytes)",
                frame.len()
            );
        }
        assert!(!frame.contains('\n'), "{name}: corpus frames are single lines");
        writer.write_all(frame.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "{name}: the server must answer, never disconnect");
        let resp = parse_json(line.trim_end())
            .unwrap_or_else(|e| panic!("{name}: unparsable response `{line}`: {e}"));
        match case.require_str("expect").unwrap() {
            "ok" => {
                assert_eq!(
                    resp.get("ok").and_then(Value::as_bool),
                    Some(true),
                    "{name}: expected acceptance, got {line}"
                );
            }
            code => {
                expected_error_frames += 1;
                assert_eq!(
                    resp.get("ok").and_then(Value::as_bool),
                    Some(false),
                    "{name}: expected rejection, got {line}"
                );
                assert_eq!(
                    resp.require_str("error.code").unwrap(),
                    code,
                    "{name}: wrong code in {line}"
                );
            }
        }
    }

    // The same connection still serves, and the server counted exactly
    // one error frame per rejected corpus case.
    writer.write_all(b"{\"op\": \"metrics\"}\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0);
    let resp = parse_json(line.trim_end()).unwrap();
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{line}");
    assert_eq!(
        resp.require_f64("result.error_frames").unwrap(),
        expected_error_frames as f64,
        "{line}"
    );

    let mut client = Client::connect(&addr).unwrap();
    client.shutdown().unwrap();
    drop(handle);
    join.join().expect("server drains cleanly");
}
