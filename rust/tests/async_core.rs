//! Soak and fault battery for the event-loop serving core.
//!
//! What `tests/protocol_corpus.rs` proves frame-by-frame, this file
//! proves at scale: 256 concurrent connections with mixed behaviors
//! (well-behaved clients, pipelined bursts past the backpressure gate,
//! slow readers, mid-frame disconnects), bit-identical responses vs
//! direct library calls throughout, bounded write-queue memory
//! (`write_queue_peak_bytes` never exceeds the cap), and a graceful
//! drain that no client — not even one that stops reading entirely —
//! can wedge. Plus the cross-core acceptance check: a pipelined v1
//! burst earns byte-identical response streams from the event-loop and
//! thread-per-connection cores.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use cimdse::adc::{AdcModel, AdcQuery};
use cimdse::config::{Value, parse_json};
use cimdse::dse::{SweepSpec, SweepSummary};
use cimdse::service::conn::WRITE_QUEUE_CAP;
use cimdse::service::{Client, ServeCore, ServeOptions, Server, ServerHandle};

fn start(
    core: ServeCore,
    workers: usize,
    progress_every: Option<usize>,
) -> (String, ServerHandle, thread::JoinHandle<()>) {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        model: AdcModel::default(),
        cache_capacity: 8,
        workers,
        core,
        progress_every,
        ..ServeOptions::default()
    })
    .expect("bind");
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let join = thread::spawn(move || server.serve().expect("serve"));
    (addr, handle, join)
}

/// Join the serve thread under a watchdog: a wedged drain is a test
/// failure, not a hung CI job.
fn join_within(join: thread::JoinHandle<()>, limit: Duration, what: &str) -> Duration {
    let started = Instant::now();
    let (tx, rx) = mpsc::channel();
    thread::spawn(move || {
        join.join().expect("serve thread panicked");
        let _ = tx.send(());
    });
    rx.recv_timeout(limit)
        .unwrap_or_else(|_| panic!("{what}: drain wedged past {limit:?}"));
    started.elapsed()
}

fn raw_pair(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn read_line(reader: &mut BufReader<TcpStream>) -> Value {
    let mut line = String::new();
    let n = reader.read_line(&mut line).expect("read");
    assert!(n > 0, "server closed unexpectedly");
    parse_json(line.trim_end()).expect("response parses")
}

fn eval_frame(id: usize) -> String {
    format!(
        "{{\"op\": \"eval\", \"id\": {id}, \"query\": {{\"enob\": {}, \
         \"total_throughput\": 1e9}}}}",
        3 + id % 10
    )
}

fn small_spec() -> SweepSpec {
    SweepSpec {
        enobs: vec![4.0, 8.0, 12.0],
        total_throughputs: vec![1e8, 1e10],
        tech_nms: vec![32.0],
        n_adcs: vec![1, 4],
    }
}

#[test]
fn soak_256_mixed_connections_then_clean_drain() {
    const CONNS: usize = 256;
    /// Frames per pipelined-burst connection — deliberately past the
    /// `MAX_PIPELINE` backpressure gate (64), so the event loop must
    /// throttle reading and re-pump the buffered tail as replies drain.
    const BURST: usize = 96;
    const SLOW: usize = 8;
    const NORMAL_EVALS: usize = 3;
    let model = AdcModel::default();
    let (addr, _handle, join) = start(ServeCore::EventLoop, 2, None);
    let spec = small_spec();
    let direct_summary = SweepSummary::compute(&spec, &model, 2).to_json_string().unwrap();
    thread::scope(|s| {
        for c in 0..CONNS {
            let addr = addr.as_str();
            let spec = &spec;
            let direct_summary = direct_summary.as_str();
            let model = &model;
            s.spawn(move || match c % 4 {
                // Well-behaved client: evals + one sweep, every
                // response bit-identical to the direct library call.
                0 => {
                    let mut client = Client::connect(addr).expect("connect");
                    for i in 0..NORMAL_EVALS {
                        let q = AdcQuery {
                            enob: 2.0 + ((c + i) % 12) as f64,
                            total_throughput: 1e6 * 10f64.powi((i % 4) as i32),
                            tech_nm: 32.0,
                            n_adcs: 1 + (c as u32 % 4),
                        };
                        let served = client.eval_metrics(&q, None).expect("eval");
                        assert_eq!(served.to_bits(), model.eval(&q).to_bits(), "c={c} i={i}");
                    }
                    let (_, summary) = client.sweep(spec, None).expect("sweep");
                    assert_eq!(summary.to_json_string().unwrap(), direct_summary, "c={c}");
                }
                // Pipelined burst past the backpressure gate: all
                // frames in one write, responses must come back
                // complete, in order, with ids echoed.
                1 => {
                    let (mut stream, mut reader) = raw_pair(addr);
                    let mut burst = String::new();
                    for i in 0..BURST {
                        burst.push_str(&eval_frame(c * BURST + i));
                        burst.push('\n');
                    }
                    stream.write_all(burst.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    for i in 0..BURST {
                        let resp = read_line(&mut reader);
                        assert_eq!(
                            resp.get("ok").and_then(Value::as_bool),
                            Some(true),
                            "c={c} i={i}: {resp:?}"
                        );
                        assert_eq!(
                            resp.get("id").and_then(Value::as_f64),
                            Some((c * BURST + i) as f64),
                            "responses must arrive in request order"
                        );
                    }
                }
                // Slow reader: pipeline a few requests, then dribble
                // the reads — the write queue absorbs the difference.
                2 => {
                    let (mut stream, mut reader) = raw_pair(addr);
                    let mut burst = String::new();
                    for i in 0..SLOW {
                        burst.push_str(&eval_frame(c * SLOW + i));
                        burst.push('\n');
                    }
                    stream.write_all(burst.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    for _ in 0..SLOW {
                        thread::sleep(Duration::from_millis(10));
                        let resp = read_line(&mut reader);
                        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true));
                    }
                }
                // Rude client: half a frame, then gone. The server
                // must shrug (asserted collectively: the soak's other
                // connections keep working and drain stays clean).
                _ => {
                    let mut stream = TcpStream::connect(addr).expect("connect");
                    stream
                        .write_all(br#"{"op": "eval", "query": {"en"#)
                        .unwrap();
                    stream.flush().unwrap();
                }
            });
        }
    });

    let mut client = Client::connect(&addr).expect("metrics connect");
    let snapshot = client.metrics().expect("metrics");
    let expected =
        (CONNS / 4) * (NORMAL_EVALS + 1) + (CONNS / 4) * BURST + (CONNS / 4) * SLOW;
    assert!(
        snapshot.require_f64("requests_total").unwrap() >= expected as f64,
        "{snapshot:?}"
    );
    // Bounded memory: however rude the burst, the per-connection write
    // queue never grew past its cap.
    let peak = snapshot.require_f64("write_queue_peak_bytes").unwrap();
    assert!(
        peak <= WRITE_QUEUE_CAP as f64,
        "write queue peak {peak} exceeds the {WRITE_QUEUE_CAP} cap"
    );
    client.shutdown().expect("shutdown");
    join_within(join, Duration::from_secs(30), "soak");
}

#[test]
fn pipelined_v1_bursts_are_byte_identical_across_cores() {
    // The acceptance criterion for the core swap: a v1 client cannot
    // tell the cores apart, byte for byte, even pipelined. (Ops with
    // nondeterministic payloads — `metrics` — are exercised elsewhere;
    // every frame here has a deterministic response.)
    let spec_json = small_spec().to_value().to_json_string().unwrap();
    let mut burst = String::new();
    let mut expected = 0usize;
    for i in 0..6 {
        burst.push_str(&eval_frame(i));
        burst.push('\n');
        expected += 1;
    }
    burst.push_str("{\"op\": \"frobnicate\"}\n"); // unknown-op
    burst.push_str("{ not json\n"); // malformed-json
    burst.push_str("{\"op\": \"eval\", \"id\": \"x\"}\n"); // bad-request
    expected += 3;
    burst.push_str(&format!("{{\"op\": \"sweep\", \"id\": 99, \"spec\": {spec_json}}}\n"));
    expected += 1;

    let mut streams: Vec<Vec<String>> = Vec::new();
    for core in [ServeCore::EventLoop, ServeCore::Threads] {
        let (addr, _handle, join) = start(core, 2, None);
        let (mut stream, mut reader) = raw_pair(&addr);
        stream.write_all(burst.as_bytes()).unwrap();
        stream.flush().unwrap();
        let mut lines = Vec::with_capacity(expected);
        for _ in 0..expected {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).expect("read") > 0, "early close");
            lines.push(line);
        }
        drop((stream, reader));
        let mut client = Client::connect(&addr).expect("connect");
        client.shutdown().expect("shutdown");
        join_within(join, Duration::from_secs(30), "cross-core burst");
        streams.push(lines);
    }
    for (i, (a, b)) in streams[0].iter().zip(&streams[1]).enumerate() {
        assert_eq!(a, b, "response {i} differs between cores");
    }
}

#[cfg(unix)]
#[test]
fn stuck_client_cannot_wedge_the_drain() {
    // A v2 client starts a long sweep with 1-point progress cadence,
    // reads one frame to prove the stream is flowing, then stops
    // reading entirely. Its kernel buffers fill, the server's write
    // queue stalls — and a shutdown must still complete: the reactor
    // force-drops any connection whose writes make no progress for the
    // stuck-writer grace period, cancelling its in-flight work.
    let (addr, _handle, join) = start(ServeCore::EventLoop, 1, Some(1));
    let stuck = {
        let (mut stream, mut reader) = raw_pair(&addr);
        stream.write_all(b"{\"op\": \"hello\", \"version\": 2}\n").unwrap();
        let hello = read_line(&mut reader);
        assert_eq!(hello.get("ok").and_then(Value::as_bool), Some(true), "{hello:?}");
        let big = SweepSpec {
            enobs: (0..100).map(|i| 2.0 + 0.1 * f64::from(i)).collect(),
            total_throughputs: (1..=40).map(|i| 1e8 * f64::from(i)).collect(),
            tech_nms: vec![16.0, 22.0, 32.0, 45.0, 65.0],
            n_adcs: vec![1, 2, 4, 8],
        };
        let frame = format!(
            "{{\"op\": \"sweep\", \"spec\": {}}}\n",
            big.to_value().to_json_string().unwrap()
        );
        stream.write_all(frame.as_bytes()).unwrap();
        stream.flush().unwrap();
        let first = read_line(&mut reader);
        assert!(first.get("frame").is_some(), "stream must be flowing: {first:?}");
        (stream, reader) // kept open, never read again
    };

    let mut killer = Client::connect(&addr).expect("connect");
    killer.shutdown().expect("shutdown ack");
    let elapsed = join_within(join, Duration::from_secs(15), "stuck client");
    assert!(
        elapsed < Duration::from_secs(10),
        "drain took {elapsed:?} with one stuck client"
    );
    drop(stuck);
}

#[test]
fn threads_core_drains_despite_an_unread_response_backlog() {
    // The classic wart: a client that requests and never reads. The
    // threaded core's bounded-write loop re-checks the drain flag on
    // every write timeout, so this cannot hold shutdown hostage.
    let (addr, _handle, join) = start(ServeCore::Threads, 2, None);
    let backlog = {
        let (mut stream, reader) = raw_pair(&addr);
        let mut burst = String::new();
        for i in 0..32 {
            burst.push_str(&eval_frame(i));
            burst.push('\n');
        }
        stream.write_all(burst.as_bytes()).unwrap();
        stream.flush().unwrap();
        (stream, reader) // never read
    };
    thread::sleep(Duration::from_millis(100));
    let mut killer = Client::connect(&addr).expect("connect");
    killer.shutdown().expect("shutdown ack");
    let elapsed = join_within(join, Duration::from_secs(15), "threads backlog");
    assert!(elapsed < Duration::from_secs(10), "drain took {elapsed:?}");
    drop(backlog);
}
