//! Fault injection against the distributed shard launcher.
//!
//! The contract under test: whatever a worker does — die mid-shard
//! (EOF after reading the request, exactly what a SIGKILLed daemon
//! looks like from the launcher's socket), refuse connections, return
//! a *corrupted* artifact (one flipped payload hex digit, caught by
//! the summary checksum), or hang without answering (read timeout) —
//! the launcher reassigns the shard and the final merged summary is
//! **byte-identical** to the single-process rollup. Plus the all-bad
//! negative paths (every worker broken ⇒ typed error, never a partial
//! merge), a real process-kill run, and the process-level `cmp` +
//! resume acceptance tests over the actual binaries.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::Duration;

use cimdse::adc::AdcModel;
use cimdse::config::{Value, parse_json};
use cimdse::dse::{ShardArtifact, SweepSpec, SweepSummary};
use cimdse::service::protocol::{Request, error_frame, ok_frame, parse_request, Reject};
use cimdse::service::{
    Client, LaunchOptions, ServeOptions, Server, ServerHandle, run_distributed_sweep,
};

fn small_spec() -> SweepSpec {
    SweepSpec {
        enobs: vec![4.0, 6.0, 8.0, 10.0, 12.0],
        total_throughputs: vec![1e7, 1e9],
        tech_nms: vec![32.0],
        n_adcs: vec![1, 8],
    }
}

fn reference_json(spec: &SweepSpec, model: &AdcModel) -> String {
    SweepSummary::compute(spec, model, 2).to_json_string().unwrap()
}

/// A real in-process worker daemon.
fn start_real_worker(model: AdcModel) -> (String, ServerHandle, thread::JoinHandle<()>) {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        model,
        cache_capacity: 8,
        workers: 2,
        ..ServeOptions::default()
    })
    .expect("bind");
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let join = thread::spawn(move || server.serve().expect("serve"));
    (addr, handle, join)
}

fn stop_real_worker(handle: ServerHandle, join: thread::JoinHandle<()>) {
    handle.shutdown();
    join.join().expect("worker drains cleanly");
}

/// An address that refuses connections: bind an ephemeral port, then
/// drop the listener (the port was just free, so nothing answers).
fn refusing_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    listener.local_addr().unwrap().to_string()
}

/// What a fake worker does with the first *compute* frame on each
/// accepted connection. (`hello` frames are always answered honestly —
/// the launcher negotiates v2 on every fresh connection, and a fake
/// that chokes on the handshake would test the wrong fault.)
enum FakeBehavior {
    /// Read the request, then close abruptly — the socket-level
    /// signature of a worker killed mid-shard.
    EofAfterRequest,
    /// Read the request, never answer — a hung worker; only the
    /// launcher's read timeout gets the shard back.
    Hang,
    /// Answer the shard request with a *real* artifact whose payload
    /// has one flipped hex digit — valid JSON, valid frame, corrupt
    /// bits. The client-side artifact validation must catch it.
    CorruptArtifact,
    /// A slow but *healthy* worker: heartbeat `keepalive` frames well
    /// past the launcher's read deadline, then answer honestly. Each
    /// heartbeat re-arms the deadline, so the launcher must NOT retire
    /// this worker.
    SlowHeartbeat { heartbeat: Duration, beats: usize },
}

/// Is this frame the launcher's v2 handshake?
fn is_hello(line: &str) -> bool {
    parse_json(line)
        .ok()
        .and_then(|doc| doc.get("op").and_then(Value::as_str).map(|op| op == "hello"))
        .unwrap_or(false)
}

/// Spawn a protocol-speaking fake worker; returns its address. The
/// accept loop runs until the test process exits. Each connection is
/// served frame-by-frame: `hello` gets the honest v2 handshake, the
/// first compute frame gets the configured behavior.
fn spawn_fake_worker(behavior: FakeBehavior, model: AdcModel) -> String {
    use cimdse::service::protocol::{hello_result, keepalive_frame};
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let behavior = &behavior;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { return };
            let mut reader = BufReader::new(match stream.try_clone() {
                Ok(s) => s,
                Err(_) => continue,
            });
            let mut writer = stream;
            loop {
                let mut line = String::new();
                if reader.read_line(&mut line).unwrap_or(0) == 0 {
                    break;
                }
                let frame = line.trim_end();
                if frame.is_empty() {
                    continue;
                }
                if is_hello(frame) {
                    let response = ok_frame("hello", None, hello_result(2));
                    if writer
                        .write_all(response.as_bytes())
                        .and_then(|()| writer.write_all(b"\n"))
                        .and_then(|()| writer.flush())
                        .is_err()
                    {
                        break;
                    }
                    continue;
                }
                match behavior {
                    FakeBehavior::EofAfterRequest => {}
                    FakeBehavior::Hang => {
                        // Hold the socket open well past any test
                        // timeout.
                        thread::sleep(Duration::from_secs(30));
                    }
                    FakeBehavior::CorruptArtifact => {
                        let response = corrupt_response(frame, &model);
                        let _ = writer.write_all(response.as_bytes());
                        let _ = writer.write_all(b"\n");
                        let _ = writer.flush();
                    }
                    FakeBehavior::SlowHeartbeat { heartbeat, beats } => {
                        for _ in 0..*beats {
                            thread::sleep(*heartbeat);
                            if writer
                                .write_all(keepalive_frame().as_bytes())
                                .and_then(|()| writer.write_all(b"\n"))
                                .and_then(|()| writer.flush())
                                .is_err()
                            {
                                break;
                            }
                        }
                        let response = honest_response(frame, &model);
                        let _ = writer.write_all(response.as_bytes());
                        let _ = writer.write_all(b"\n");
                        let _ = writer.flush();
                        // Healthy workers serve many shards per
                        // connection; keep this one open.
                        continue;
                    }
                }
                break;
            }
        }
    });
    addr
}

/// Build the honest `ok` shard response a real worker would send.
fn honest_response(line: &str, default_model: &AdcModel) -> String {
    let doc = parse_json(line).expect("launcher sends valid frames");
    let (_, request) = parse_request(&doc);
    let shard = match request.expect("launcher sends valid shard requests") {
        Request::Shard(s) => s,
        other => {
            return error_frame(
                None,
                None,
                &Reject::new("bad-request", format!("fake worker got {other:?}")),
            );
        }
    };
    let model = shard.model.unwrap_or(*default_model);
    let artifact = ShardArtifact::compute(&shard.spec, &model, shard.selector, 1)
        .expect("fake worker computes the artifact");
    let mut result = std::collections::BTreeMap::new();
    result.insert("artifact".to_string(), artifact.to_value());
    ok_frame("shard", None, Value::Table(result))
}

/// Build an `ok` shard response whose artifact payload has one flipped
/// hex digit — the launcher must reject it via the summary checksum and
/// reassign the shard.
fn corrupt_response(line: &str, default_model: &AdcModel) -> String {
    let doc = parse_json(line).expect("launcher sends valid frames");
    let (_, request) = parse_request(&doc);
    let shard = match request.expect("launcher sends valid shard requests") {
        Request::Shard(s) => s,
        other => {
            return error_frame(
                None,
                None,
                &Reject::new("bad-request", format!("fake worker got {other:?}")),
            );
        }
    };
    let model = shard.model.unwrap_or(*default_model);
    let artifact = ShardArtifact::compute(&shard.spec, &model, shard.selector, 1)
        .expect("fake worker computes the honest artifact first");
    let text = artifact.to_value().to_json_string().unwrap();
    // Flip the last digit of the first bit-hex float in the summary
    // payload (the min-EAP `eap` field serializes as `"eap": "<16 hex>"`).
    let needle = r#""eap": ""#;
    let at = text.find(needle).expect("non-empty shards carry a min-EAP field") + needle.len();
    let mut bytes = text.into_bytes();
    let digit = at + 15;
    bytes[digit] = if bytes[digit] == b'0' { b'1' } else { b'0' };
    let corrupted = String::from_utf8(bytes).unwrap();
    let mut result = std::collections::BTreeMap::new();
    result.insert(
        "artifact".to_string(),
        parse_json(&corrupted).expect("flip keeps the JSON well-formed"),
    );
    ok_frame("shard", None, Value::Table(result))
}

/// Run one fault scenario: a faulty worker next to a healthy one must
/// still yield the exact single-process bytes, with the shard visibly
/// reassigned.
fn assert_fault_tolerated(faulty: String, read_timeout: Duration) {
    let model = AdcModel::default();
    let spec = small_spec();
    let (real, handle, join) = start_real_worker(model);
    let mut options = LaunchOptions::new(vec![faulty.clone(), real.clone()], 5);
    options.read_timeout = Some(read_timeout);
    let report = run_distributed_sweep(&spec, &model, &options).expect("fleet survives");
    assert_eq!(
        report.merged.summary.to_json_string().unwrap(),
        reference_json(&spec, &model),
        "merge must be byte-identical to the single-process rollup"
    );
    assert_eq!(report.computed, 5);
    assert!(report.retries >= 1, "the faulty worker's shards must be reassigned");
    let faulty_report =
        report.workers.iter().find(|w| w.addr == faulty).expect("faulty worker reported");
    assert!(faulty_report.failures >= 1, "{faulty_report:?}");
    assert_eq!(faulty_report.shards_served, 0, "{faulty_report:?}");
    let real_report =
        report.workers.iter().find(|w| w.addr == real).expect("real worker reported");
    assert_eq!(real_report.shards_served, 5, "{real_report:?}");
    stop_real_worker(handle, join);
}

#[test]
fn worker_killed_mid_shard_is_rescheduled() {
    let addr = spawn_fake_worker(FakeBehavior::EofAfterRequest, AdcModel::default());
    assert_fault_tolerated(addr, Duration::from_secs(10));
}

#[test]
fn worker_refusing_connections_is_rescheduled() {
    assert_fault_tolerated(refusing_addr(), Duration::from_secs(10));
}

#[test]
fn corrupted_artifact_is_rejected_and_rescheduled() {
    let addr = spawn_fake_worker(FakeBehavior::CorruptArtifact, AdcModel::default());
    assert_fault_tolerated(addr, Duration::from_secs(10));
}

#[test]
fn hung_worker_times_out_and_is_rescheduled() {
    let addr = spawn_fake_worker(FakeBehavior::Hang, AdcModel::default());
    // Short deadline: the hang must cost ~300 ms per strike, not 30 s.
    assert_fault_tolerated(addr, Duration::from_millis(300));
}

#[test]
fn slow_but_heartbeating_worker_is_not_retired() {
    // A worker that takes 3x the read deadline per shard but streams
    // keepalive frames the whole time is *healthy*: every heartbeat
    // re-arms the launcher's deadline, so the shard must complete on
    // this worker with zero failures charged — the deadline is an
    // inter-frame liveness bound, not a compute bound. The worker is
    // the ONLY one in the fleet, so misdiagnosing it as hung would
    // fail the whole launch.
    let model = AdcModel::default();
    let spec = small_spec();
    let slow = spawn_fake_worker(
        FakeBehavior::SlowHeartbeat { heartbeat: Duration::from_millis(60), beats: 10 },
        model,
    );
    let mut options = LaunchOptions::new(vec![slow.clone()], 2);
    options.read_timeout = Some(Duration::from_millis(200));
    let report =
        run_distributed_sweep(&spec, &model, &options).expect("heartbeats keep the worker alive");
    assert_eq!(
        report.merged.summary.to_json_string().unwrap(),
        reference_json(&spec, &model),
        "merge must be byte-identical to the single-process rollup"
    );
    let worker = report.workers.iter().find(|w| w.addr == slow).expect("worker reported");
    assert_eq!(worker.failures, 0, "{worker:?}");
    assert!(!worker.retired, "{worker:?}");
    assert_eq!(worker.shards_served, 2, "{worker:?}");
}

#[cfg(unix)]
#[test]
fn abandoned_shard_is_cancelled_and_stops_burning_the_pool() {
    // When the launcher gives up on a worker it drops the connection
    // (reconnect-on-failure, retirement, or launcher death all look
    // the same from the worker's socket). An event-loop worker must
    // cancel that connection's in-flight shard so its pool stops
    // burning cycles on work nobody will read — asserted through the
    // worker's own `work.*` metrics counters.
    use cimdse::service::{ServeCore, ServeOptions, Server};
    let model = AdcModel::default();
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        model,
        cache_capacity: 8,
        workers: 1,
        core: ServeCore::EventLoop,
        // 1-point chunks: cancellation lands between chunks, so the
        // finest granularity makes the burn measurable and the stop
        // immediate.
        progress_every: Some(1),
        ..ServeOptions::default()
    })
    .expect("bind");
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let join = thread::spawn(move || server.serve().expect("serve"));

    // A shard big enough to still be mid-compute when the launcher
    // walks away (1 runner, 1-point chunks each also streaming a
    // progress completion).
    let big = SweepSpec {
        enobs: (0..100).map(|i| 2.0 + 0.1 * f64::from(i)).collect(),
        total_throughputs: (1..=40).map(|i| 1e8 * f64::from(i)).collect(),
        tech_nms: vec![16.0, 22.0, 32.0, 45.0, 65.0],
        n_adcs: vec![1, 2, 4, 8],
    };
    let total = 100 * 40 * 5 * 4;
    {
        // Raw socket (not `Client`, which would skip interim frames):
        // hello, fire the shard request, read ONE frame to prove
        // compute started streaming, then drop the connection without
        // collecting the artifact — the launcher's walk-away signature.
        let mut stream = std::net::TcpStream::connect(&addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        stream.write_all(b"{\"op\": \"hello\", \"version\": 2}\n").unwrap();
        assert!(reader.read_line(&mut line).unwrap() > 0, "hello answered");
        let mut spec_frame = std::collections::BTreeMap::new();
        spec_frame.insert("op".to_string(), Value::String("shard".to_string()));
        spec_frame.insert("shard".to_string(), Value::String("0/1".to_string()));
        spec_frame.insert("spec".to_string(), big.to_value());
        let frame = Value::Table(spec_frame).to_json_string().unwrap();
        stream.write_all(frame.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        line.clear();
        assert!(reader.read_line(&mut line).unwrap() > 0, "compute started streaming");
        let first = parse_json(line.trim_end()).unwrap();
        assert!(first.get("frame").is_some(), "first frame is interim: {first:?}");
    }

    // The worker notices the disconnect and cancels: `work.cancelled`
    // ticks up, and the chunk counter freezes well short of the grid.
    let mut probe = Client::connect(&addr).expect("probe connect");
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let cancelled = loop {
        let snapshot = probe.metrics().expect("metrics");
        let cancelled = snapshot.require_f64("work.cancelled").unwrap_or(0.0);
        if cancelled >= 1.0 {
            break snapshot;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "worker never cancelled the abandoned shard: {snapshot:?}"
        );
        thread::sleep(Duration::from_millis(20));
    };
    assert!(
        cancelled.require_f64("work.points").unwrap() < total as f64,
        "the full grid was computed despite the cancel: {cancelled:?}"
    );
    // The chunk counter must freeze. A chunk already mid-fold when the
    // cancel lands may still complete, so wait for two samples 300 ms
    // apart to agree rather than pinning the very first reading.
    let mut frozen = cancelled.require_f64("work.chunks").unwrap();
    let freeze_deadline = std::time::Instant::now() + Duration::from_secs(5);
    let settled = loop {
        thread::sleep(Duration::from_millis(300));
        let later = probe.metrics().expect("metrics");
        let now = later.require_f64("work.chunks").unwrap();
        if now == frozen {
            break later;
        }
        assert!(
            std::time::Instant::now() < freeze_deadline,
            "chunk counter still advancing after the cancel (pool still burning?): {later:?}"
        );
        frozen = now;
    };
    assert!(
        settled.require_f64("work.points").unwrap() < total as f64,
        "the pool burned the whole grid despite the cancel: {settled:?}"
    );

    handle.shutdown();
    join.join().expect("worker drains cleanly");
}

#[test]
fn all_workers_broken_is_a_typed_error_not_a_partial_merge() {
    let model = AdcModel::default();
    let spec = small_spec();
    // Refusing + corrupting: both retire after their strike limits.
    let corrupt = spawn_fake_worker(FakeBehavior::CorruptArtifact, model);
    let mut options = LaunchOptions::new(vec![refusing_addr(), corrupt], 4);
    options.read_timeout = Some(Duration::from_secs(5));
    let err = run_distributed_sweep(&spec, &model, &options)
        .expect_err("an all-bad fleet must fail loudly")
        .to_string();
    assert!(
        err.contains("distributed sweep failed"),
        "typed launch failure expected: {err}"
    );
}

#[test]
fn killed_worker_process_is_survived_by_the_fleet() {
    // A real `cimdse serve` process SIGKILLed while the launcher is
    // using it: however the timing lands (mid-shard, between shards, or
    // after finishing everything), the merge must be exact.
    let model = AdcModel::default();
    let spec = small_spec();
    let (child, child_addr) = spawn_serve_binary();
    let (real, handle, join) = start_real_worker(model);
    let killer = thread::spawn(move || {
        let mut victim = child;
        thread::sleep(Duration::from_millis(30));
        let _ = victim.kill();
        let _ = victim.wait();
    });
    let mut options = LaunchOptions::new(vec![child_addr, real], 6);
    options.read_timeout = Some(Duration::from_secs(10));
    let report = run_distributed_sweep(&spec, &model, &options).expect("fleet survives a kill");
    // NOTE: the child serves its *own* default fit, but the launcher
    // sends this process's model with every request, so bit-identity
    // holds no matter who computed what.
    assert_eq!(
        report.merged.summary.to_json_string().unwrap(),
        reference_json(&spec, &model)
    );
    killer.join().unwrap();
    stop_real_worker(handle, join);
}

// ---------------------------------------------------------------------------
// Process-level: the real binaries, end to end (`cmp` + resume).
// ---------------------------------------------------------------------------

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_cimdse")
}

/// Spawn `cimdse serve` on an ephemeral port and wait for its banner.
fn spawn_serve_binary() -> (Child, String) {
    let mut child = Command::new(bin())
        .args(["serve", "--addr", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn cimdse serve");
    let stdout = child.stdout.take().expect("child stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).expect("read serve banner");
    let addr = line
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in serve banner: {line}"))
        .to_string();
    thread::spawn(move || {
        let mut sink = String::new();
        let _ = reader.read_to_string(&mut sink);
    });
    (child, addr)
}

fn run_capture(args: &[&str]) -> String {
    let out = Command::new(bin()).args(args).output().unwrap();
    assert!(
        out.status.success(),
        "cimdse {args:?} failed\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cimdse_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn shutdown_binary(addr: &str, mut child: Child) {
    if let Ok(mut client) = Client::connect(addr) {
        let _ = client.shutdown();
    }
    let _ = child.wait();
}

fn path_str(p: &Path) -> &str {
    p.to_str().unwrap()
}

#[test]
fn distributed_binary_sweep_cmps_equal_and_resumes() {
    let dir = temp_dir("launcher_e2e");
    let art_dir = dir.join("artifacts");
    let dist = dir.join("dist.json");
    let single = dir.join("single.json");
    let (child, addr) = spawn_serve_binary();
    let result = std::panic::catch_unwind(|| {
        // One real worker plus one dead address: the launcher must shrug
        // the dead one off. (Acceptance: distributed vs single-process
        // summaries are byte-identical under an injected fault,
        // process-level.)
        let workers = format!("{addr},{}", refusing_addr());
        let spec_args = ["--spec", "dense", "--points", "5"];
        let mut cmd: Vec<&str> = vec![
            "sweep", "--workers", &workers, "--shards", "4", "--out", path_str(&art_dir),
            "--summary-json", path_str(&dist), "--timeout-ms", "30000",
        ];
        cmd.extend_from_slice(&spec_args);
        let stdout = run_capture(&cmd);
        assert!(stdout.contains("4 computed, 0 resumed"), "{stdout}");

        let mut cmd: Vec<&str> = vec!["sweep", "--summary-json", path_str(&single)];
        cmd.extend_from_slice(&spec_args);
        run_capture(&cmd);
        assert_eq!(
            std::fs::read(&dist).unwrap(),
            std::fs::read(&single).unwrap(),
            "distributed summary file must cmp equal to the single-process one"
        );

        // Resume: every artifact is already on disk, so a re-run skips
        // all shards — asserted by pointing --workers at a *dead*
        // address only: if any shard were recomputed this would fail.
        let dead = refusing_addr();
        let dist2 = dir.join("dist2.json");
        let mut cmd: Vec<&str> = vec![
            "sweep", "--workers", &dead, "--shards", "4", "--out", path_str(&art_dir),
            "--summary-json", path_str(&dist2), "--timeout-ms", "2000",
        ];
        cmd.extend_from_slice(&spec_args);
        let stdout = run_capture(&cmd);
        assert!(stdout.contains("0 computed, 4 resumed"), "{stdout}");
        assert_eq!(std::fs::read(&dist).unwrap(), std::fs::read(&dist2).unwrap());

        // Partial resume: delete one artifact; exactly that shard is
        // recomputed (needs the live worker again) and the bytes still
        // match.
        std::fs::remove_file(art_dir.join("shard_2.json")).unwrap();
        let dist3 = dir.join("dist3.json");
        let mut cmd: Vec<&str> = vec![
            "sweep", "--workers", &addr, "--shards", "4", "--out", path_str(&art_dir),
            "--summary-json", path_str(&dist3), "--timeout-ms", "30000",
        ];
        cmd.extend_from_slice(&spec_args);
        let stdout = run_capture(&cmd);
        assert!(stdout.contains("1 computed, 3 resumed"), "{stdout}");
        assert_eq!(std::fs::read(&dist).unwrap(), std::fs::read(&dist3).unwrap());
    });
    shutdown_binary(&addr, child);
    let _ = std::fs::remove_dir_all(&dir);
    if let Err(panic) = result {
        std::panic::resume_unwind(panic);
    }
}

#[test]
fn in_process_resume_skips_completed_shards() {
    // Library-level mirror of the resume semantics: first run computes
    // and persists, second run (no reachable worker needed beyond the
    // probe) resumes everything.
    let model = AdcModel::default();
    let spec = small_spec();
    let dir = temp_dir("launcher_resume");
    let (real, handle, join) = start_real_worker(model);
    let mut options = LaunchOptions::new(vec![real], 3);
    options.out_dir = Some(dir.clone());
    options.read_timeout = Some(Duration::from_secs(10));
    let first = run_distributed_sweep(&spec, &model, &options).unwrap();
    assert_eq!((first.computed, first.resumed), (3, 0));
    stop_real_worker(handle, join);

    // The worker is gone; only the artifacts remain.
    let second = run_distributed_sweep(&spec, &model, &options).unwrap();
    assert_eq!((second.computed, second.resumed), (0, 3));
    assert_eq!(
        second.merged.summary.to_json_string().unwrap(),
        first.merged.summary.to_json_string().unwrap()
    );
    // A different spec must NOT resume from these artifacts (fingerprint
    // gate) — and with no live worker it must fail rather than merge
    // the wrong shards.
    let other = SweepSpec { enobs: vec![5.0, 9.0], ..small_spec() };
    assert!(run_distributed_sweep(&other, &model, &options).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
