//! Crate-wide error type.

use thiserror::Error;

/// Unified error for all cimdse subsystems.
#[derive(Error, Debug)]
pub enum Error {
    /// Configuration file / CLI parse problems.
    #[error("config error: {0}")]
    Config(String),

    /// A numeric routine received out-of-domain input.
    #[error("numeric error: {0}")]
    Numeric(String),

    /// Regression / fitting failures (singular systems, too few points).
    #[error("fit error: {0}")]
    Fit(String),

    /// A layer cannot be mapped onto the given architecture.
    #[error("mapping error: {0}")]
    Mapping(String),

    /// PJRT runtime failures (artifact missing, compile/execute errors).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Underlying XLA/PJRT error.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O while loading artifacts or writing reports.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
