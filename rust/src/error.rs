//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the offline registry carries no
//! `thiserror`. Message formats are part of the public behaviour (tests
//! and the CLI match on them), so keep them stable.

use std::fmt;

/// Unified error for all cimdse subsystems.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / CLI parse problems.
    Config(String),

    /// A numeric routine received out-of-domain input.
    Numeric(String),

    /// Regression / fitting failures (singular systems, too few points).
    Fit(String),

    /// A layer cannot be mapped onto the given architecture.
    Mapping(String),

    /// PJRT runtime failures (artifact missing, compile/execute errors,
    /// or the backend being stubbed out without the `pjrt` feature).
    Runtime(String),

    /// Underlying XLA/PJRT error.
    Xla(String),

    /// I/O while loading artifacts or writing reports.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Numeric(msg) => write!(f, "numeric error: {msg}"),
            Error::Fit(msg) => write!(f, "fit error: {msg}"),
            Error::Mapping(msg) => write!(f, "mapping error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Xla(msg) => write!(f, "xla error: {msg}"),
            // Transparent: the io::Error message stands on its own.
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        assert_eq!(Error::Config("bad key".into()).to_string(), "config error: bad key");
        assert_eq!(
            Error::Runtime("no artifacts".into()).to_string(),
            "runtime error: no artifacts"
        );
        assert_eq!(Error::Fit("singular".into()).to_string(), "fit error: singular");
    }

    #[test]
    fn io_errors_are_transparent_and_sourced() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err: Error = io.into();
        assert_eq!(err.to_string(), "gone");
        assert!(std::error::Error::source(&err).is_some());
    }
}
