//! Survey presentation transforms used by Figs. 2–3.
//!
//! The paper, "for ease of visualization": (1) scales published ADCs to a
//! common 32 nm node, and (3) shows only ADCs that are near
//! Pareto-optimal. These transforms live here so the figure benches apply
//! exactly what the paper applied.

use super::AdcRecord;
use crate::adc::coeffs::Coefficients;
use crate::util::logspace::log10;

/// Scale a record's energy and area to a target technology node using the
/// model's tech exponents (energy ~ T^a2, area ~ T^d1 at fixed energy —
/// the same normalization the paper applies before plotting).
pub fn scale_to_tech(record: &AdcRecord, target_nm: f64, coefs: &Coefficients) -> AdcRecord {
    let ratio = target_nm / record.tech_nm;
    let energy_scale = ratio.powf(coefs.a2);
    // Area scales directly through d1 and indirectly through energy^d3.
    let area_scale = ratio.powf(coefs.d1) * energy_scale.powf(coefs.d3);
    AdcRecord {
        tech_nm: target_nm,
        energy_pj: record.energy_pj * energy_scale,
        area_um2: record.area_um2 * area_scale,
        ..record.clone()
    }
}

/// Keep records that are within `slack_decades` of the 2-D Pareto front in
/// (throughput ↑, metric ↓) space, where the metric is extracted by `key`
/// (energy for Fig. 2, area for Fig. 3).
///
/// A record is near-Pareto if no other record has >= throughput while its
/// metric is more than `slack_decades` below (in log10).
pub fn pareto_near_filter<K>(records: &[AdcRecord], slack_decades: f64, key: K) -> Vec<AdcRecord>
where
    K: Fn(&AdcRecord) -> f64,
{
    // Sort by throughput descending; sweep tracking the lowest metric seen
    // among records with throughput >= current.
    let mut order: Vec<usize> = (0..records.len()).collect();
    order.sort_by(|&i, &j| records[j].throughput.total_cmp(&records[i].throughput));

    let mut best_log_metric = f64::INFINITY;
    let mut keep = vec![false; records.len()];
    for &i in &order {
        let lm = log10(key(&records[i]));
        if lm <= best_log_metric + slack_decades {
            keep[i] = true;
        }
        best_log_metric = best_log_metric.min(lm);
    }
    records
        .iter()
        .zip(keep)
        .filter(|(_, k)| *k)
        .map(|(r, _)| r.clone())
        .collect()
}

/// Round ENOB to the nearest of the given bins (paper: 4b / 8b / 12b lines).
pub fn nearest_enob_bin(enob: f64, bins: &[f64]) -> f64 {
    assert!(!bins.is_empty());
    *bins
        .iter()
        .min_by(|a, b| (enob - **a).abs().total_cmp(&(enob - **b).abs()))
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::survey::AdcArchitecture;

    fn rec(throughput: f64, energy_pj: f64, area_um2: f64) -> AdcRecord {
        AdcRecord {
            id: "t".into(),
            year: 2020,
            architecture: AdcArchitecture::Sar,
            tech_nm: 65.0,
            enob: 8.0,
            throughput,
            energy_pj,
            area_um2,
        }
    }

    #[test]
    fn scale_to_tech_shrinks_energy_for_smaller_node() {
        let coefs = Coefficients::generator_truth();
        let r = rec(1e8, 2.0, 5e4);
        let scaled = scale_to_tech(&r, 32.0, &coefs);
        assert!(scaled.energy_pj < r.energy_pj);
        assert!(scaled.area_um2 < r.area_um2);
        assert_eq!(scaled.tech_nm, 32.0);
        // enob/throughput untouched
        assert_eq!(scaled.enob, r.enob);
        assert_eq!(scaled.throughput, r.throughput);
    }

    #[test]
    fn scale_to_same_tech_is_identity() {
        let coefs = Coefficients::generator_truth();
        let r = rec(1e8, 2.0, 5e4);
        let scaled = scale_to_tech(&r, 65.0, &coefs);
        assert!((scaled.energy_pj - 2.0).abs() < 1e-12);
        assert!((scaled.area_um2 - 5e4).abs() < 1e-9);
    }

    #[test]
    fn pareto_filter_keeps_front_drops_dominated() {
        let records = vec![
            rec(1e9, 1.0, 1.0),   // front (fastest, cheap)
            rec(1e8, 0.5, 1.0),   // front (slower but cheaper)
            rec(1e8, 100.0, 1.0), // dominated by far (2 decades worse)
            rec(1e7, 0.4, 1.0),   // front
        ];
        let kept = pareto_near_filter(&records, 0.5, |r| r.energy_pj);
        let ids: Vec<f64> = kept.iter().map(|r| r.energy_pj).collect();
        assert!(ids.contains(&1.0));
        assert!(ids.contains(&0.5));
        assert!(ids.contains(&0.4));
        assert!(!ids.contains(&100.0));
    }

    #[test]
    fn zero_slack_keeps_strict_front_only() {
        let records = vec![rec(1e9, 1.0, 1.0), rec(1e8, 2.0, 1.0), rec(1e8, 1.0, 1.0)];
        let kept = pareto_near_filter(&records, 0.0, |r| r.energy_pj);
        assert!(kept.iter().all(|r| r.energy_pj <= 1.0));
    }

    #[test]
    fn enob_binning() {
        assert_eq!(nearest_enob_bin(5.4, &[4.0, 8.0, 12.0]), 4.0);
        assert_eq!(nearest_enob_bin(6.6, &[4.0, 8.0, 12.0]), 8.0);
        assert_eq!(nearest_enob_bin(11.0, &[4.0, 8.0, 12.0]), 12.0);
    }
}
