//! Synthetic survey generation.
//!
//! Ground truth for the generator is the same two-bound + Eq. 1 structure
//! the paper observes in the real survey (constants in
//! [`crate::adc::coeffs`]); each record is that best case plus design
//! scatter:
//!
//! * **energy** — the published cloud sits *above* the best-case envelope,
//!   so the exceedance is one-sided: `Exp(mean 0.45 decades)` plus a small
//!   symmetric measurement term. The paper notes order-of-magnitude
//!   scatter for identical architecture-level parameters; exceedances
//!   reach ~2 decades here too.
//! * **area** — log-normal around the *raw* (uncalibrated) Eq. 1 power
//!   law, `sigma = 0.35` decades, chosen so the lowest-area-10% of records
//!   sit at ~0.35x the raw law — which is exactly what the paper's p10
//!   calibration then recovers as `kappa`.
//! * **marginals** — per-architecture ENOB/throughput ranges and
//!   era-weighted tech nodes match the survey's qualitative composition.

use super::{AdcArchitecture, AdcRecord, SurveyDataset};
use crate::adc::coeffs::Coefficients;
use crate::util::Rng;
use crate::util::logspace::{log10, pow10};

/// Configuration of the synthetic survey.
#[derive(Clone, Debug)]
pub struct SurveyConfig {
    /// Number of records to generate.
    pub n_records: usize,
    /// RNG seed.
    pub seed: u64,
    /// Mean one-sided energy exceedance above the envelope, in decades.
    pub energy_exceedance_decades: f64,
    /// Symmetric log10 noise on energy (measurement / reporting).
    pub energy_noise_decades: f64,
    /// Symmetric log10 noise on area around raw Eq. 1.
    pub area_sigma_decades: f64,
    /// Ground-truth model the scatter is applied around.
    pub truth: Coefficients,
}

impl Default for SurveyConfig {
    fn default() -> Self {
        SurveyConfig {
            n_records: 700,
            seed: 1997,
            energy_exceedance_decades: 0.45,
            energy_noise_decades: 0.08,
            area_sigma_decades: 0.55,
            truth: Coefficients::generator_truth(),
        }
    }
}

/// Tech nodes weighted by how often they appear across the survey years.
const TECH_NODES: [(f64, f64); 12] = [
    (16.0, 4.0),
    (22.0, 5.0),
    (28.0, 10.0),
    (32.0, 8.0),
    (40.0, 9.0),
    (45.0, 8.0),
    (65.0, 16.0),
    (90.0, 12.0),
    (130.0, 12.0),
    (180.0, 10.0),
    (250.0, 4.0),
    (350.0, 2.0),
];

/// Per-architecture sampling envelope: (weight, enob range, log10 f range).
fn arch_profile(arch: AdcArchitecture) -> (f64, (f64, f64), (f64, f64)) {
    match arch {
        AdcArchitecture::Sar => (0.40, (6.0, 13.0), (4.0, 8.5)),
        AdcArchitecture::Flash => (0.10, (3.0, 6.5), (8.0, 10.3)),
        AdcArchitecture::Pipeline => (0.20, (8.0, 12.5), (6.0, 9.0)),
        AdcArchitecture::DeltaSigma => (0.15, (10.0, 16.0), (3.0, 6.0)),
        AdcArchitecture::TimeInterleaved => (0.15, (5.0, 9.5), (9.0, 10.6)),
    }
}

/// Generate a synthetic survey.
pub fn generate_survey(config: &SurveyConfig) -> SurveyDataset {
    let mut rng = Rng::new(config.seed);
    let arch_weights: Vec<(AdcArchitecture, f64)> = AdcArchitecture::ALL
        .iter()
        .map(|&a| (a, arch_profile(a).0))
        .collect();

    let records = (0..config.n_records)
        .map(|i| {
            let architecture = *rng.weighted_choice(&arch_weights);
            let (_, enob_range, logf_range) = arch_profile(architecture);
            let enob = rng.uniform(enob_range.0, enob_range.1);
            let log_f = rng.uniform(logf_range.0, logf_range.1);
            let tech_nm = *rng.weighted_choice(&TECH_NODES);
            // Newer papers use smaller nodes: map node size to a year band.
            let year_base = 1997.0 + 26.0 * (1.0 - (log10(tech_nm / 16.0) / 1.34)).clamp(0.0, 1.0);
            let year = (year_base + rng.uniform(-2.0, 2.0)).clamp(1997.0, 2023.0) as u32;

            let log_t = log10(tech_nm / 32.0);
            // Best-case envelope, then one-sided exceedance + noise.
            let log_e_bound = config.truth.log_energy_pj(enob, log_t, log_f);
            let log_e = log_e_bound
                + rng.exponential(config.energy_exceedance_decades)
                + rng.normal(0.0, config.energy_noise_decades);
            let energy_pj = pow10(log_e);

            // Raw (uncalibrated) Eq. 1 around the *achieved* energy.
            let log_area_raw = config.truth.log_area_raw_um2(log_t, log_f, log_e);
            let area_um2 = pow10(log_area_raw + rng.normal(0.0, config.area_sigma_decades));

            AdcRecord {
                id: format!("adc-{i:04}"),
                year,
                architecture,
                tech_nm,
                enob,
                throughput: pow10(log_f),
                energy_pj,
                area_um2,
            }
        })
        .collect();

    SurveyDataset { records, seed: config.seed }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn survey() -> SurveyDataset {
        generate_survey(&SurveyConfig::default())
    }

    #[test]
    fn generates_requested_count_deterministically() {
        let a = survey();
        let b = survey();
        assert_eq!(a.len(), 700);
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.energy_pj, rb.energy_pj);
            assert_eq!(ra.area_um2, rb.area_um2);
        }
    }

    #[test]
    fn different_seeds_give_different_surveys() {
        let a = survey();
        let b = generate_survey(&SurveyConfig { seed: 2024, ..SurveyConfig::default() });
        assert!(a.records[0].energy_pj != b.records[0].energy_pj);
    }

    #[test]
    fn all_quantities_positive_and_finite() {
        for r in &survey().records {
            assert!(r.tech_nm > 0.0);
            assert!(r.enob > 0.0);
            assert!(r.throughput > 0.0 && r.throughput.is_finite());
            assert!(r.energy_pj > 0.0 && r.energy_pj.is_finite());
            assert!(r.area_um2 > 0.0 && r.area_um2.is_finite());
            assert!((1997..=2023).contains(&r.year));
        }
    }

    #[test]
    fn energy_sits_above_the_truth_envelope() {
        let cfg = SurveyConfig::default();
        let sv = generate_survey(&cfg);
        let below = sv
            .records
            .iter()
            .filter(|r| {
                let log_e = log10(r.energy_pj);
                log_e < cfg.truth.log_energy_pj(r.enob, r.log_tech_ratio(), log10(r.throughput))
                    - 0.25
            })
            .count();
        // Only the symmetric noise tail can dip below; must be rare.
        assert!(below < sv.len() / 50, "{below} records far below envelope");
    }

    #[test]
    fn energy_scatter_spans_orders_of_magnitude() {
        // Paper: "area and energy of published ADCs can vary by orders of
        // magnitude even for ADCs with the same architecture-level params".
        let cfg = SurveyConfig::default();
        let sv = generate_survey(&cfg);
        let max_exceed = sv
            .records
            .iter()
            .map(|r| {
                log10(r.energy_pj)
                    - cfg.truth.log_energy_pj(r.enob, r.log_tech_ratio(), log10(r.throughput))
            })
            .fold(f64::MIN, f64::max);
        assert!(max_exceed > 1.5, "max exceedance only {max_exceed} decades");
    }

    #[test]
    fn architecture_marginals_are_respected() {
        let sv = survey();
        for r in &sv.records {
            let (_, enob_range, logf_range) = arch_profile(r.architecture);
            assert!(r.enob >= enob_range.0 && r.enob <= enob_range.1);
            let lf = log10(r.throughput);
            assert!(lf >= logf_range.0 - 1e-9 && lf <= logf_range.1 + 1e-9);
        }
        // All five classes present.
        for arch in AdcArchitecture::ALL {
            assert!(sv.records.iter().any(|r| r.architecture == arch), "{arch:?} missing");
        }
    }

    #[test]
    fn csv_roundtrip_has_header_and_rows() {
        let sv = survey();
        let csv = sv.to_csv();
        assert!(csv.starts_with("id,year,architecture"));
        assert_eq!(csv.lines().count(), sv.len() + 1);
    }
}
