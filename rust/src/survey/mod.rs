//! Synthetic ADC survey — the published-ADC dataset substrate.
//!
//! The paper fits its model to the Murmann ADC Performance Survey
//! (1997–2023, ~700 published converters). That dataset is not available
//! here, so [`generator`] synthesizes a survey with the same *envelope
//! structure* the paper's fit consumes: per-architecture ENOB/throughput
//! marginals, energy scattered one-sidedly above the two-bound best-case
//! envelope, and area scattered log-normally around the Eq. 1 power law
//! (DESIGN.md §2 documents why this preserves the pipeline's behaviour).
//!
//! [`filters`] provides the Fig. 2/3 presentation transforms: scaling
//! published points to a common 32 nm node and keeping only
//! near-Pareto-optimal converters.

pub mod csv;
pub mod filters;
pub mod generator;
pub mod stats;

pub use csv::{load_survey_csv, parse_survey_csv};
pub use filters::{pareto_near_filter, scale_to_tech};
pub use generator::{SurveyConfig, generate_survey};

use crate::util::logspace::log10;

/// ADC circuit architecture classes in the survey.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AdcArchitecture {
    /// Successive approximation — the bulk of modern low/mid-speed designs.
    Sar,
    /// Flash — low resolution, very high speed.
    Flash,
    /// Pipelined — mid/high resolution, high speed.
    Pipeline,
    /// Delta-sigma — high resolution, low bandwidth.
    DeltaSigma,
    /// Time-interleaved (SAR backends) — highest aggregate throughput.
    TimeInterleaved,
}

impl AdcArchitecture {
    /// All architecture classes.
    pub const ALL: [AdcArchitecture; 5] = [
        AdcArchitecture::Sar,
        AdcArchitecture::Flash,
        AdcArchitecture::Pipeline,
        AdcArchitecture::DeltaSigma,
        AdcArchitecture::TimeInterleaved,
    ];

    /// Short display name.
    pub fn name(&self) -> &'static str {
        match self {
            AdcArchitecture::Sar => "SAR",
            AdcArchitecture::Flash => "flash",
            AdcArchitecture::Pipeline => "pipeline",
            AdcArchitecture::DeltaSigma => "delta-sigma",
            AdcArchitecture::TimeInterleaved => "time-interleaved",
        }
    }
}

/// One published-ADC record (one dot in the paper's Figs. 2–3).
#[derive(Clone, Debug)]
pub struct AdcRecord {
    /// Identifier (synthetic: `adc-<n>`).
    pub id: String,
    /// Publication year.
    pub year: u32,
    /// Circuit architecture class.
    pub architecture: AdcArchitecture,
    /// Technology node in nanometers.
    pub tech_nm: f64,
    /// Effective number of bits.
    pub enob: f64,
    /// Nyquist throughput in converts per second.
    pub throughput: f64,
    /// Energy per convert in picojoules.
    pub energy_pj: f64,
    /// Die area in square micrometers.
    pub area_um2: f64,
}

impl AdcRecord {
    /// log10(tech_nm / 32) — the model's normalized tech covariate.
    pub fn log_tech_ratio(&self) -> f64 {
        log10(self.tech_nm / 32.0)
    }

    /// Walden figure of merit in femtojoules per conversion-step.
    pub fn walden_fom_fj(&self) -> f64 {
        self.energy_pj * 1e3 / 2f64.powf(self.enob)
    }
}

/// A survey dataset plus its provenance.
#[derive(Clone, Debug)]
pub struct SurveyDataset {
    /// The records.
    pub records: Vec<AdcRecord>,
    /// RNG seed the dataset was generated from (reproducibility).
    pub seed: u64,
}

impl SurveyDataset {
    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Write the dataset as CSV (one row per record).
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("id,year,architecture,tech_nm,enob,throughput,energy_pj,area_um2\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{},{:.3},{:.6e},{:.6e},{:.6e}\n",
                r.id,
                r.year,
                r.architecture.name(),
                r.tech_nm,
                r.enob,
                r.throughput,
                r.energy_pj,
                r.area_um2
            ));
        }
        out
    }
}
