//! CSV survey loader — drop-in support for real survey data.
//!
//! The paper fits against the Murmann ADC survey; users with access to it
//! (or any other characterization set) can export a CSV and fit this
//! crate's model to their data instead of the synthetic survey:
//!
//! ```text
//! cimdse fit --survey-csv my_adcs.csv
//! ```
//!
//! Expected columns (header names are matched case-insensitively, order
//! free): `tech_nm`, `enob`, `throughput`, `energy_pj`, `area_um2`, and
//! optionally `id`, `year`, `architecture`. Unknown columns are ignored.
//! This parser handles quoted fields and both `\n` / `\r\n` line endings.

use std::collections::HashMap;

use super::{AdcArchitecture, AdcRecord, SurveyDataset};
use crate::error::{Error, Result};

/// Split one CSV line into fields, honoring double-quote escaping.
fn split_csv_line(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

fn parse_architecture(s: &str) -> AdcArchitecture {
    match s.to_lowercase().as_str() {
        "flash" => AdcArchitecture::Flash,
        "pipeline" | "pipelined" => AdcArchitecture::Pipeline,
        "delta-sigma" | "sigma-delta" | "dsm" => AdcArchitecture::DeltaSigma,
        "time-interleaved" | "ti" => AdcArchitecture::TimeInterleaved,
        _ => AdcArchitecture::Sar,
    }
}

/// Parse a survey CSV document.
pub fn parse_survey_csv(text: &str) -> Result<SurveyDataset> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, header) = lines
        .next()
        .ok_or_else(|| Error::Config("survey csv: empty document".into()))?;
    let columns: HashMap<String, usize> = split_csv_line(header)
        .iter()
        .enumerate()
        .map(|(i, name)| (name.trim().to_lowercase(), i))
        .collect();

    let required = ["tech_nm", "enob", "throughput", "energy_pj", "area_um2"];
    for name in required {
        if !columns.contains_key(name) {
            return Err(Error::Config(format!("survey csv: missing column `{name}`")));
        }
    }

    let get = |fields: &[String], name: &str| -> Option<String> {
        columns.get(name).and_then(|&i| fields.get(i)).map(|s| s.trim().to_string())
    };
    let get_f64 = |fields: &[String], name: &str, lineno: usize| -> Result<f64> {
        let raw = get(fields, name)
            .ok_or_else(|| Error::Config(format!("survey csv line {lineno}: short row")))?;
        raw.parse().map_err(|_| {
            Error::Config(format!("survey csv line {lineno}: bad {name} `{raw}`"))
        })
    };

    let mut records = Vec::new();
    for (lineno, line) in lines {
        let fields = split_csv_line(line);
        let record = AdcRecord {
            id: get(&fields, "id").unwrap_or_else(|| format!("csv-{lineno}")),
            year: get(&fields, "year")
                .and_then(|y| y.parse().ok())
                .unwrap_or(2023),
            architecture: get(&fields, "architecture")
                .map(|a| parse_architecture(&a))
                .unwrap_or(AdcArchitecture::Sar),
            tech_nm: get_f64(&fields, "tech_nm", lineno + 1)?,
            enob: get_f64(&fields, "enob", lineno + 1)?,
            throughput: get_f64(&fields, "throughput", lineno + 1)?,
            energy_pj: get_f64(&fields, "energy_pj", lineno + 1)?,
            area_um2: get_f64(&fields, "area_um2", lineno + 1)?,
        };
        for (name, v) in [
            ("tech_nm", record.tech_nm),
            ("enob", record.enob),
            ("throughput", record.throughput),
            ("energy_pj", record.energy_pj),
            ("area_um2", record.area_um2),
        ] {
            if !(v > 0.0 && v.is_finite()) {
                return Err(Error::Config(format!(
                    "survey csv line {}: non-positive {name} ({v})",
                    lineno + 1
                )));
            }
        }
        records.push(record);
    }
    if records.is_empty() {
        return Err(Error::Config("survey csv: no data rows".into()));
    }
    Ok(SurveyDataset { records, seed: 0 })
}

/// Load a survey CSV from disk.
pub fn load_survey_csv(path: &str) -> Result<SurveyDataset> {
    parse_survey_csv(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::survey::generator::{SurveyConfig, generate_survey};

    #[test]
    fn roundtrips_generated_survey() {
        let original = generate_survey(&SurveyConfig::default());
        let parsed = parse_survey_csv(&original.to_csv()).unwrap();
        assert_eq!(parsed.len(), original.len());
        for (a, b) in original.records.iter().zip(&parsed.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.year, b.year);
            assert_eq!(a.architecture, b.architecture);
            assert!((a.enob - b.enob).abs() < 1e-3);
            assert!((a.energy_pj - b.energy_pj).abs() / a.energy_pj < 1e-5);
            assert!((a.area_um2 - b.area_um2).abs() / a.area_um2 < 1e-5);
        }
    }

    #[test]
    fn column_order_is_free_and_extra_columns_ignored() {
        let doc = "enob,notes,area_um2,energy_pj,tech_nm,throughput\n\
                   8.0,\"hello, world\",5e4,2.5,32,1e9\n";
        let sv = parse_survey_csv(doc).unwrap();
        assert_eq!(sv.len(), 1);
        let r = &sv.records[0];
        assert_eq!(r.enob, 8.0);
        assert_eq!(r.tech_nm, 32.0);
        assert_eq!(r.area_um2, 5e4);
    }

    #[test]
    fn missing_required_column_errors() {
        let doc = "enob,tech_nm,throughput,energy_pj\n8,32,1e9,2.5\n";
        let err = parse_survey_csv(doc).unwrap_err().to_string();
        assert!(err.contains("area_um2"), "{err}");
    }

    #[test]
    fn bad_and_nonpositive_values_error_with_line_numbers() {
        let doc = "tech_nm,enob,throughput,energy_pj,area_um2\n32,8,1e9,abc,5e4\n";
        let err = parse_survey_csv(doc).unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("energy_pj"), "{err}");

        let doc = "tech_nm,enob,throughput,energy_pj,area_um2\n32,8,-1e9,2.5,5e4\n";
        let err = parse_survey_csv(doc).unwrap_err().to_string();
        assert!(err.contains("non-positive"), "{err}");
    }

    #[test]
    fn quoted_fields_with_commas_and_escapes() {
        let fields = split_csv_line(r#"a,"b,c","d""e",f"#);
        assert_eq!(fields, vec!["a", "b,c", "d\"e", "f"]);
    }

    #[test]
    fn architecture_names_parse() {
        for (s, a) in [
            ("flash", AdcArchitecture::Flash),
            ("Pipeline", AdcArchitecture::Pipeline),
            ("sigma-delta", AdcArchitecture::DeltaSigma),
            ("TI", AdcArchitecture::TimeInterleaved),
            ("whatever", AdcArchitecture::Sar),
        ] {
            assert_eq!(parse_architecture(s), a);
        }
    }

    #[test]
    fn fitting_a_csv_survey_works_end_to_end() {
        let sv = parse_survey_csv(&generate_survey(&SurveyConfig::default()).to_csv()).unwrap();
        let report = crate::adc::fit_model(&sv).unwrap();
        assert!(report.area_r_energy > report.area_r_enob);
    }
}
