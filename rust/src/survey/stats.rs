//! Survey analytics: the FoM-evolution views the model is built on.
//!
//! The paper's §II derives its trends from the survey literature
//! (Jonsson's and Murmann's performance-evolution studies,
//! refs 12–17 of the paper). This module reproduces those summary views over a
//! [`SurveyDataset`]: Walden figure-of-merit evolution by year,
//! per-architecture-class composition, and best-in-class tables — used
//! by the `cimdse survey` subcommand and as sanity checks that the
//! synthetic survey has realistic structure.

use std::collections::BTreeMap;

use crate::report::Table;
use crate::stats::quantile::{median, quantile};
use crate::util::logspace::log10;

use super::{AdcArchitecture, SurveyDataset};

/// One year-bucket of FoM evolution.
#[derive(Clone, Copy, Debug)]
pub struct FomTrendRow {
    /// Bucket start year (inclusive).
    pub year_start: u32,
    /// Records in the bucket.
    pub count: usize,
    /// Median Walden FoM (fJ/conversion-step).
    pub median_fom_fj: f64,
    /// Best (lowest) Walden FoM in the bucket.
    pub best_fom_fj: f64,
}

/// Walden FoM evolution in `bucket_years` buckets (paper refs 12–17: FoM
/// improves over time as process and architectures advance).
pub fn fom_trend(survey: &SurveyDataset, bucket_years: u32) -> Vec<FomTrendRow> {
    assert!(bucket_years >= 1);
    let mut buckets: BTreeMap<u32, Vec<f64>> = BTreeMap::new();
    for r in &survey.records {
        let bucket = r.year - (r.year - 1997) % bucket_years;
        buckets.entry(bucket).or_default().push(r.walden_fom_fj());
    }
    buckets
        .into_iter()
        .map(|(year_start, foms)| FomTrendRow {
            year_start,
            count: foms.len(),
            median_fom_fj: median(&foms),
            best_fom_fj: foms.iter().copied().fold(f64::MAX, f64::min),
        })
        .collect()
}

/// Per-architecture-class composition summary.
#[derive(Clone, Debug)]
pub struct ClassSummary {
    /// The class.
    pub architecture: AdcArchitecture,
    /// Number of records.
    pub count: usize,
    /// Median ENOB.
    pub median_enob: f64,
    /// Median throughput (converts/s).
    pub median_throughput: f64,
    /// 10th-percentile (best-case-ish) energy/convert (pJ).
    pub p10_energy_pj: f64,
}

/// Summarize the survey per architecture class.
pub fn class_summary(survey: &SurveyDataset) -> Vec<ClassSummary> {
    AdcArchitecture::ALL
        .iter()
        .filter_map(|&architecture| {
            let rs: Vec<_> = survey
                .records
                .iter()
                .filter(|r| r.architecture == architecture)
                .collect();
            if rs.is_empty() {
                return None;
            }
            let enobs: Vec<f64> = rs.iter().map(|r| r.enob).collect();
            let thpts: Vec<f64> = rs.iter().map(|r| log10(r.throughput)).collect();
            let energies: Vec<f64> = rs.iter().map(|r| r.energy_pj).collect();
            Some(ClassSummary {
                architecture,
                count: rs.len(),
                median_enob: median(&enobs),
                median_throughput: 10f64.powf(median(&thpts)),
                p10_energy_pj: quantile(&energies, 0.10),
            })
        })
        .collect()
}

/// Render both views as tables (the `cimdse survey` subcommand's output).
pub fn render_summary(survey: &SurveyDataset) -> String {
    let mut out = String::new();
    let mut t = Table::new(vec!["years", "n", "median FoM (fJ/step)", "best FoM"]);
    for row in fom_trend(survey, 5) {
        t.row(vec![
            format!("{}-{}", row.year_start, row.year_start + 4),
            row.count.to_string(),
            format!("{:.1}", row.median_fom_fj),
            format!("{:.2}", row.best_fom_fj),
        ]);
    }
    out.push_str("Walden FoM evolution:\n");
    out.push_str(&t.render());
    out.push('\n');

    let mut t = Table::new(vec!["class", "n", "median ENOB", "median thpt", "p10 E/conv (pJ)"]);
    for s in class_summary(survey) {
        t.row(vec![
            s.architecture.name().to_string(),
            s.count.to_string(),
            format!("{:.1}", s.median_enob),
            crate::util::units::fmt_throughput(s.median_throughput),
            format!("{:.3}", s.p10_energy_pj),
        ]);
    }
    out.push_str("architecture classes:\n");
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::survey::generator::{SurveyConfig, generate_survey};

    fn survey() -> SurveyDataset {
        generate_survey(&SurveyConfig::default())
    }

    #[test]
    fn fom_improves_over_time() {
        // Newer buckets use smaller nodes -> lower median FoM (the
        // Jonsson/Murmann evolution the survey literature documents).
        let trend = fom_trend(&survey(), 9);
        assert!(trend.len() >= 2);
        let first = trend.first().unwrap();
        let last = trend.last().unwrap();
        assert!(
            last.median_fom_fj < first.median_fom_fj,
            "median FoM did not improve: {:?} -> {:?}",
            first,
            last
        );
        for row in &trend {
            assert!(row.best_fom_fj <= row.median_fom_fj);
            assert!(row.count > 0);
        }
    }

    #[test]
    fn class_profiles_match_reality() {
        let summary = class_summary(&survey());
        assert_eq!(summary.len(), 5);
        let get = |a: AdcArchitecture| summary.iter().find(|s| s.architecture == a).unwrap();
        // Flash: fast and low resolution; delta-sigma: slow and high res.
        let flash = get(AdcArchitecture::Flash);
        let dsm = get(AdcArchitecture::DeltaSigma);
        assert!(flash.median_throughput > 100.0 * dsm.median_throughput);
        assert!(dsm.median_enob > flash.median_enob + 3.0);
        // SAR is the biggest population (as in the real survey).
        let sar = get(AdcArchitecture::Sar);
        assert!(summary.iter().all(|s| s.count <= sar.count));
    }

    #[test]
    fn render_contains_both_tables() {
        let s = render_summary(&survey());
        assert!(s.contains("Walden FoM evolution"));
        assert!(s.contains("architecture classes"));
        assert!(s.contains("SAR"));
    }
}
