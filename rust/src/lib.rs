//! # cimdse — ADC energy/area modeling for compute-in-memory design space exploration
//!
//! Reproduction of *Modeling Analog-Digital-Converter Energy and Area for
//! Compute-In-Memory Accelerator Design* (Andrulis, Chen, Lee, Emer, Sze, 2024).
//!
//! The crate is organized as the paper's pipeline (Fig. 1):
//!
//! * [`survey`] — a synthetic Murmann-style ADC survey (the published-ADC
//!   dataset substrate; see DESIGN.md §2 for the substitution rationale).
//! * [`stats`] — the regression substrate: log-space OLS, quantile/envelope
//!   fitting, piecewise two-bound fitting, bootstrap confidence intervals.
//! * [`adc`] — the paper's contribution: the architecture-level ADC energy
//!   (two-bound, §II-A) and area (Eq. 1, §II-B) model, the survey-fit
//!   pipeline, and user tuning to known ADC design points.
//! * [`components`] — an Accelergy-like component energy/area library for
//!   every non-ADC accelerator component (DACs, crossbars, buffers, ...).
//! * [`arch`] / [`workload`] / [`mapper`] / [`energy`] — the CiMLoop-like
//!   full-accelerator modeling stack: architecture specs (RAELLA S/M/L/XL),
//!   DNN layer descriptors (ResNet18), layer-to-crossbar mapping with
//!   action counts, and the energy/area/EAP rollup.
//! * [`dse`] — the design-space exploration engine: sweeps, Pareto fronts,
//!   and threaded evaluation over the native model or the AOT-compiled
//!   PJRT artifact.
//! * [`service`] — the persistent serving daemon (`cimdse serve`): a
//!   newline-delimited JSON protocol over `std::net`, a prepared-model
//!   LRU cache, request metrics, and the `cimdse query` client — so
//!   eval/sweep-heavy studies amortize model prep and pool spin-up
//!   across thousands of requests instead of paying a process launch
//!   each (see rust/docs/protocol.md).
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt` (lowered
//!   once from JAX/Pallas by `make artifacts`) and executes them on the
//!   CPU PJRT client; Python is never on this path. The real backend is
//!   gated behind the `pjrt` cargo feature; the default build ships a
//!   stub that keeps the API compiling and errors at runtime (see
//!   rust/README.md).
//! * [`exec`], [`cli`], [`config`], [`report`], [`testing`], [`util`] —
//!   substrates (thread pool, argument parser, TOML-subset/JSON parsers,
//!   tables/CSV/ASCII plots, property testing, RNG/log-space helpers)
//!   hand-rolled because the offline registry carries no tokio / clap /
//!   serde / criterion / proptest.
//! * [`obs`] — structured observability: lock-cheap tracing spans with
//!   cross-process trace-context propagation (the protocol's optional
//!   `trace` field), NDJSON trace sinks (`--trace-out`), mergeable
//!   log2 latency histograms, and the `cimdse trace` analyzer (see
//!   rust/docs/observability.md).
//! * [`lint`] — `cimdse lint`, the zero-dependency static checker that
//!   machine-enforces the crate's hand-maintained contracts (SAFETY
//!   audits, error-code registry, float display, mutex-hold, determinism
//!   and dependency hygiene; see rust/docs/lints.md).
//!
//! See DESIGN.md for the experiment index mapping every figure of the paper
//! to a bench target, and EXPERIMENTS.md for measured results.

pub mod adc;
pub mod arch;
pub mod bench_util;
pub mod cli;
pub mod components;
pub mod config;
pub mod dse;
pub mod energy;
pub mod error;
pub mod exec;
pub mod lint;
pub mod mapper;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod service;
pub mod stats;
pub mod survey;
pub mod testing;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
