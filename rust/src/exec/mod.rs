//! Threaded execution substrate.
//!
//! The offline registry has no tokio; the DSE engine's needs are
//! embarrassingly parallel batch evaluation, which scoped threads plus an
//! atomic work index cover with less machinery and no unsafe code.

use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (logical CPUs, capped).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Apply `f` to every item in parallel, preserving input order in the
/// output. `workers = 1` degrades to a plain serial map (no threads).
pub fn parallel_map<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    assert!(workers >= 1);
    if workers == 1 || items.len() <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<U>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers.min(items.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let out = f(&items[i]);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker left a hole"))
        .collect()
}

/// Apply `f` to contiguous chunks of `items` in parallel (one call per
/// chunk), concatenating per-chunk outputs in order. Lower dispatch
/// overhead than [`parallel_map`] when per-item work is tiny — this is the
/// DSE sweep's hot-path shape.
pub fn parallel_chunks<T, U, F>(items: &[T], chunk: usize, workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&[T]) -> Vec<U> + Sync,
{
    assert!(chunk >= 1);
    let chunks: Vec<&[T]> = items.chunks(chunk).collect();
    let nested = parallel_map(&chunks, workers, |c| f(c));
    nested.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_path_matches_parallel() {
        let items: Vec<u64> = (0..100).collect();
        let a = parallel_map(&items, 1, |x| x + 7);
        let b = parallel_map(&items, 4, |x| x + 7);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u64> = Vec::new();
        let out: Vec<u64> = parallel_map(&items, 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![1u64, 2, 3];
        let out = parallel_map(&items, 16, |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn chunked_matches_flat() {
        let items: Vec<u64> = (0..517).collect();
        let flat = parallel_map(&items, 4, |x| x + 1);
        let chunked = parallel_chunks(&items, 64, 4, |c| c.iter().map(|x| x + 1).collect());
        assert_eq!(flat, chunked);
    }

    #[test]
    fn default_workers_reasonable() {
        let w = default_workers();
        assert!((1..=32).contains(&w));
    }
}
