//! Threaded execution substrate.
//!
//! The offline registry has no tokio/rayon; the DSE engine's needs are
//! embarrassingly parallel batch evaluation. The substrate is a single
//! persistent [`Pool`] of worker threads (created once, reused across
//! sweep calls — no per-call thread spawn) with per-worker chunk deques
//! and work-stealing for uneven items.
//!
//! ## Result path is lock-free
//!
//! [`Pool::fill_with`] pre-splits the output buffer into disjoint
//! `&mut` chunk slices (safe `split_at_mut`) that travel *with* the work
//! items through the steal deques, so workers write results in place:
//! no per-chunk mutex on the result path, no post-hoc sort/stitch copy.
//! The only locks are on the *claim* path (one uncontended per-worker
//! deque lock per chunk claim) and a once-per-worker push in
//! [`Pool::fold_chunks`].
//!
//! ## One `unsafe`
//!
//! Dispatching a borrowed closure to persistent (`'static`) worker
//! threads requires erasing its lifetime — the same technique every
//! scoped thread-pool uses. The erasure lives in [`Pool::broadcast`],
//! which does not return until every worker has finished running the
//! closure, so the erased borrow can never dangle. Everything layered on
//! top (chunking, stealing, output splitting) is safe code.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::{JoinHandle, ThreadId};

/// Number of worker threads to use by default (logical CPUs, capped).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Work-claim chunks per worker: enough granularity for stealing to
/// load-balance uneven items without a deque transaction per item.
const CLAIMS_PER_WORKER: usize = 4;

thread_local! {
    /// True on threads owned by a [`Pool`]. Public entry points degrade
    /// to serial execution when called from a worker, so nested
    /// parallelism cannot deadlock the pool against itself.
    static IS_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// The closure currently being broadcast to the workers, with its borrow
/// lifetime erased (see [`Pool::broadcast`] for the safety argument).
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are fine)
// and `broadcast` keeps it alive for the whole time workers can reach it.
unsafe impl Send for Job {}

struct PoolState {
    /// Bumped once per broadcast so each worker runs each job exactly once.
    epoch: u64,
    job: Option<Job>,
    /// Workers still running the current job.
    active: usize,
    /// First panic payload observed while running the current job.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

/// Lifetime profiling counters for one worker (relaxed atomics: they are
/// monotonic tallies read out of band by [`Pool::stats`], never used for
/// synchronization).
#[derive(Default)]
struct WorkerCounters {
    /// Chunks this worker executed (own-deque claims plus steals).
    chunks: AtomicU64,
    /// Chunks claimed from *another* worker's deque.
    steals: AtomicU64,
    /// Nanoseconds spent parked on `work_cv` waiting for an epoch.
    idle_ns: AtomicU64,
}

/// Snapshot of one worker's lifetime counters (see [`Pool::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Chunks executed by this worker (own claims + steals).
    pub chunks: u64,
    /// Chunks stolen from other workers' deques.
    pub steals: u64,
    /// Nanoseconds spent idle waiting for work.
    pub idle_ns: u64,
}

/// Snapshot of every worker's lifetime counters, indexed by worker.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// One entry per worker thread, in worker-index order.
    pub workers: Vec<WorkerStats>,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new epoch.
    work_cv: Condvar,
    /// The submitter waits here for `active` to reach zero.
    done_cv: Condvar,
    /// Per-worker profiling tallies (chunks/steals/idle).
    counters: Vec<WorkerCounters>,
}

/// A persistent worker pool: threads are spawned once and reused across
/// calls (asserted by the thread-id stability test below). Construct your
/// own for an isolated width, or share the process-wide [`Pool::global`].
pub struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl Pool {
    /// Spawn a pool with `workers` persistent threads (`workers >= 1`).
    pub fn new(workers: usize) -> Pool {
        assert!(workers >= 1, "pool needs at least one worker");
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                active: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            counters: (0..workers).map(|_| WorkerCounters::default()).collect(),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("cimdse-pool-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { shared, handles, workers }
    }

    /// The process-wide shared pool ([`default_workers`] threads), created
    /// on first use and reused by every sweep for the rest of the process.
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(|| Pool::new(default_workers()))
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Thread ids of the persistent workers (stable for the pool's life).
    pub fn worker_ids(&self) -> Vec<ThreadId> {
        self.handles.iter().map(|h| h.thread().id()).collect()
    }

    /// Whether the calling thread is a pool worker (any pool's).
    pub fn on_worker_thread() -> bool {
        IS_POOL_WORKER.with(|f| f.get())
    }

    /// Snapshot the per-worker lifetime profiling counters: chunks
    /// executed, chunks stolen from other workers, and nanoseconds spent
    /// parked waiting for work. Counters are monotonic over the pool's
    /// life; callers diff snapshots to attribute a window.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self
                .shared
                .counters
                .iter()
                .map(|c| WorkerStats {
                    chunks: c.chunks.load(Ordering::Relaxed),
                    steals: c.steals.load(Ordering::Relaxed),
                    idle_ns: c.idle_ns.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }

    /// Run `f(worker_index)` once on every worker, returning when all have
    /// finished. Concurrent submitters queue (first-come, first-served);
    /// panics in `f` are captured and re-raised on the submitting thread.
    fn broadcast(&self, f: &(dyn Fn(usize) + Sync)) {
        // SAFETY: erase the closure borrow's lifetime so it can sit in the
        // 'static worker-visible slot. The erased pointer is cleared and
        // this function only returns after every worker has decremented
        // `active` for this epoch, i.e. after the last use of the borrow,
        // so it never outlives the data it points to.
        let job = Job(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
        });
        let mut st = self.shared.state.lock().unwrap();
        // Another submitter may own the pool right now (tests and callers
        // share `Pool::global`): wait for its job to fully drain first.
        while st.job.is_some() || st.active != 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.epoch += 1;
        st.active = self.workers;
        st.job = Some(job);
        self.shared.work_cv.notify_all();
        while st.active != 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let panic = st.panic.take();
        // Wake submitters queued on the slot (workers only notify when
        // `active` hits zero, which queued submitters may have missed).
        self.shared.done_cv.notify_all();
        drop(st);
        if let Some(payload) = panic {
            std::panic::resume_unwind(payload);
        }
    }

    /// Fill `out[i] = f(i)` for every index, in parallel, writing results
    /// in place through disjoint `split_at_mut` slices (no lock on the
    /// result path). See [`Pool::fill_chunk_ranges`] for the chunking and
    /// stealing mechanics.
    pub fn fill_with<O, F>(&self, out: &mut [O], chunk: usize, f: F)
    where
        O: Send,
        F: Fn(usize) -> O + Sync,
    {
        self.fill_chunk_ranges(out, chunk, |start, slice| {
            for (i, slot) in slice.iter_mut().enumerate() {
                *slot = f(start + i);
            }
        });
    }

    /// Fill `out` in parallel, one call of `f(start_index, chunk_slice)`
    /// per contiguous chunk of up to `chunk` elements (`f` must overwrite
    /// the whole slice). The output buffer is pre-split into disjoint
    /// `&mut` chunk slices (safe `split_at_mut`) that travel through the
    /// per-worker steal deques with their start indices, so results land
    /// in place — no lock on the result path. Worker `w` claims its own
    /// contiguous run of chunks first (locality), then steals from the
    /// back of other workers' deques to balance uneven items.
    ///
    /// Degrades to a serial loop when called from inside a pool worker
    /// (nested parallelism would otherwise deadlock the pool).
    pub fn fill_chunk_ranges<O, F>(&self, out: &mut [O], chunk: usize, f: F)
    where
        O: Send,
        F: Fn(usize, &mut [O]) + Sync,
    {
        if out.is_empty() {
            return;
        }
        let chunk = chunk.clamp(1, out.len());
        if Pool::on_worker_thread() {
            let len = out.len();
            let mut start = 0usize;
            for slice in out.chunks_mut(chunk) {
                f(start, slice);
                start += slice.len();
            }
            debug_assert_eq!(start, len);
            return;
        }
        // Deal contiguous (start, slice) chunks across the worker deques:
        // worker w gets a contiguous run of chunks, preserving locality.
        let n_chunks = out.len().div_ceil(chunk);
        let mut deques: Vec<VecDeque<(usize, &mut [O])>> =
            (0..self.workers).map(|_| VecDeque::new()).collect();
        let mut rest = out;
        let mut start = 0usize;
        let mut ci = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            deques[ci * self.workers / n_chunks].push_back((start, head));
            start += take;
            rest = tail;
            ci += 1;
        }
        let queues: Vec<Mutex<VecDeque<(usize, &mut [O])>>> =
            deques.into_iter().map(Mutex::new).collect();
        let f = &f;
        let queues = &queues;
        let counters = &self.shared.counters;
        self.broadcast(&move |w| {
            while let Some(((start, slice), stolen)) = claim(queues, w) {
                counters[w].chunks.fetch_add(1, Ordering::Relaxed);
                if stolen {
                    counters[w].steals.fetch_add(1, Ordering::Relaxed);
                }
                f(start, slice);
            }
        });
    }

    /// Fold the index range `0..n` in parallel: each worker builds a local
    /// accumulator with `init` and folds every chunk range it claims (own
    /// deque first, then stolen) with `fold`; the per-worker accumulators
    /// are returned for the caller to merge. Claim order is
    /// non-deterministic under stealing, so `fold`/merging must be
    /// insensitive to chunk order (min/max/count/argmin-by-index style
    /// rollups; see [`crate::dse::run_sweep_fold`]).
    pub fn fold_chunks<A, I, F>(&self, n: usize, chunk: usize, init: I, fold: F) -> Vec<A>
    where
        A: Send,
        I: Fn() -> A + Sync,
        F: Fn(&mut A, Range<usize>) + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if Pool::on_worker_thread() {
            let mut acc = init();
            fold(&mut acc, 0..n);
            return vec![acc];
        }
        let chunk = chunk.clamp(1, n);
        let n_chunks = n.div_ceil(chunk);
        let mut deques: Vec<VecDeque<Range<usize>>> =
            (0..self.workers).map(|_| VecDeque::new()).collect();
        for ci in 0..n_chunks {
            let start = ci * chunk;
            deques[ci * self.workers / n_chunks].push_back(start..(start + chunk).min(n));
        }
        let queues: Vec<Mutex<VecDeque<Range<usize>>>> =
            deques.into_iter().map(Mutex::new).collect();
        let accs: Mutex<Vec<A>> = Mutex::new(Vec::with_capacity(self.workers));
        let init = &init;
        let fold = &fold;
        let queues_ref = &queues;
        let accs_ref = &accs;
        let counters = &self.shared.counters;
        self.broadcast(&move |w| {
            let mut acc: Option<A> = None;
            while let Some((range, stolen)) = claim(queues_ref, w) {
                counters[w].chunks.fetch_add(1, Ordering::Relaxed);
                if stolen {
                    counters[w].steals.fetch_add(1, Ordering::Relaxed);
                }
                fold(acc.get_or_insert_with(init), range);
            }
            if let Some(acc) = acc {
                // One lock per worker per call, after all folding is done.
                accs_ref.lock().unwrap().push(acc);
            }
        });
        accs.into_inner().unwrap()
    }
}

/// Claim a chunk for worker `w`: front of its own deque, else steal from
/// the back of the others (back-stealing keeps the owner's front pops and
/// thieves' back pops on opposite ends of a contiguous index run). The
/// returned flag is `true` when the chunk was stolen from another worker's
/// deque (feeds the [`Pool::stats`] steal counter).
fn claim<T>(queues: &[Mutex<VecDeque<T>>], w: usize) -> Option<(T, bool)> {
    if let Some(task) = queues[w].lock().unwrap().pop_front() {
        return Some((task, false));
    }
    for off in 1..queues.len() {
        let victim = (w + off) % queues.len();
        if let Some(task) = queues[victim].lock().unwrap().pop_back() {
            return Some((task, true));
        }
    }
    None
}

fn worker_loop(shared: &Shared, worker_index: usize) {
    IS_POOL_WORKER.with(|f| f.set(true));
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    if let Some(Job(ptr)) = &st.job {
                        seen_epoch = st.epoch;
                        break *ptr;
                    }
                }
                let parked = std::time::Instant::now();
                st = shared.work_cv.wait(st).unwrap();
                shared.counters[worker_index]
                    .idle_ns
                    .fetch_add(parked.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        };
        // SAFETY: `broadcast` keeps the pointee alive (and the pointer in
        // the slot) until `active` hits zero, which happens strictly after
        // this call returns and we decrement below.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (unsafe { &*job })(worker_index);
        }));
        let mut st = shared.state.lock().unwrap();
        if let Err(payload) = result {
            st.panic.get_or_insert(payload);
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Apply `f` to every item in parallel on the shared [`Pool::global`],
/// preserving input order in the output. `workers = 1` degrades to a plain
/// serial map (no threads); any other value routes through the pool (the
/// pool's fixed width governs actual parallelism).
///
/// Results are written in place through disjoint output-chunk slices —
/// no lock, no sort, and no per-chunk buffer on the result path.
pub fn parallel_map<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    assert!(workers >= 1);
    if workers == 1 || items.len() <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let pool = Pool::global();
    let chunk = items.len().div_ceil(pool.workers() * CLAIMS_PER_WORKER).max(1);
    // `Option<U>` gives the workers initialized slots to overwrite in
    // place; the final unwrap pass is a move, not a recompute or stitch.
    let mut out: Vec<Option<U>> = std::iter::repeat_with(|| None).take(items.len()).collect();
    pool.fill_with(&mut out, chunk, |i| Some(f(&items[i])));
    out.into_iter()
        .map(|slot| slot.expect("pool worker left a hole"))
        .collect()
}

/// Apply `f` to contiguous chunks of `items` in parallel (one call per
/// chunk), concatenating per-chunk outputs in order. Lower dispatch
/// overhead than [`parallel_map`] when per-item work is tiny.
pub fn parallel_chunks<T, U, F>(items: &[T], chunk: usize, workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&[T]) -> Vec<U> + Sync,
{
    assert!(chunk >= 1);
    let chunks: Vec<&[T]> = items.chunks(chunk).collect();
    let nested = parallel_map(&chunks, workers, |c| f(c));
    nested.into_iter().flatten().collect()
}

/// A cooperative cancellation flag shared between a dispatcher and the
/// chunked work it runs on the pool.
///
/// Cancellation is *advisory*: the pool never preempts a running chunk.
/// Long-running folds check the token at chunk boundaries (see
/// [`crate::dse::run_sweep_fold_range_ctl`]) and stop claiming new work
/// once it trips, so an abandoned job stops burning worker cycles within
/// one chunk of the cancel. Clones share the same flag; a token is
/// created untripped and can only ever move to cancelled (no reset),
/// which keeps "observed cancelled" a stable fact across threads.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<std::sync::atomic::AtomicBool>,
}

impl CancelToken {
    /// A fresh, untripped token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Trip the flag. Idempotent; visible to every clone.
    pub fn cancel(&self) {
        self.flag.store(true, std::sync::atomic::Ordering::Release);
    }

    /// Has any clone of this token been cancelled?
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(std::sync::atomic::Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn cancel_token_clones_share_one_flag() {
        let token = CancelToken::new();
        let clone = token.clone();
        assert!(!token.is_cancelled() && !clone.is_cancelled());
        clone.cancel();
        assert!(token.is_cancelled() && clone.is_cancelled());
        // Idempotent.
        token.cancel();
        assert!(token.is_cancelled());
    }

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_path_matches_parallel() {
        let items: Vec<u64> = (0..100).collect();
        let a = parallel_map(&items, 1, |x| x + 7);
        let b = parallel_map(&items, 4, |x| x + 7);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u64> = Vec::new();
        let out: Vec<u64> = parallel_map(&items, 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![1u64, 2, 3];
        let out = parallel_map(&items, 16, |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn order_preserved_when_workers_exceed_len() {
        // workers > len at several awkward sizes: chunking must neither
        // drop nor reorder items when most claim slots go unused.
        for len in [2usize, 3, 5, 7, 13] {
            let items: Vec<u64> = (0..len as u64).collect();
            let out = parallel_map(&items, len * 8, |x| x * 10 + 1);
            assert_eq!(
                out,
                items.iter().map(|x| x * 10 + 1).collect::<Vec<_>>(),
                "len={len}"
            );
        }
    }

    #[test]
    fn uneven_chunk_boundaries_preserved() {
        // Lengths chosen to leave ragged tail chunks for several worker
        // counts.
        for (len, workers) in [(17usize, 2usize), (100, 3), (101, 7), (1000, 13)] {
            let items: Vec<u64> = (0..len as u64).collect();
            let out = parallel_map(&items, workers, |x| x + 1);
            assert_eq!(
                out,
                items.iter().map(|x| x + 1).collect::<Vec<_>>(),
                "len={len} workers={workers}"
            );
        }
    }

    #[test]
    fn chunked_matches_flat() {
        let items: Vec<u64> = (0..517).collect();
        let flat = parallel_map(&items, 4, |x| x + 1);
        let chunked = parallel_chunks(&items, 64, 4, |c| c.iter().map(|x| x + 1).collect());
        assert_eq!(flat, chunked);
    }

    #[test]
    fn default_workers_reasonable() {
        let w = default_workers();
        assert!((1..=32).contains(&w));
    }

    #[test]
    fn fill_with_writes_every_index() {
        let pool = Pool::new(3);
        for len in [1usize, 2, 7, 64, 1000] {
            let mut out = vec![0usize; len];
            pool.fill_with(&mut out, 5, |i| i * 3);
            assert!(out.iter().enumerate().all(|(i, &v)| v == i * 3), "len={len}");
        }
    }

    #[test]
    fn fold_chunks_covers_all_indices_exactly_once() {
        let pool = Pool::new(4);
        for n in [1usize, 10, 97, 1000] {
            let accs = pool.fold_chunks(
                n,
                7,
                Vec::new,
                |acc: &mut Vec<usize>, range| acc.extend(range),
            );
            let mut all: Vec<usize> = accs.into_iter().flatten().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n}");
        }
    }

    #[test]
    fn pool_is_reused_across_invocations() {
        // The acceptance-criterion test: two sweep-shaped invocations in
        // one process are served by the same persistent threads.
        let pool = Pool::global();
        let worker_ids: BTreeSet<ThreadId> = pool.worker_ids().into_iter().collect();
        assert_eq!(worker_ids.len(), pool.workers());

        let observe = || -> BTreeSet<ThreadId> {
            let mut out: Vec<Option<ThreadId>> = vec![None; 256];
            pool.fill_with(&mut out, 1, |_| Some(std::thread::current().id()));
            out.into_iter().map(Option::unwrap).collect()
        };
        let first = observe();
        let second = observe();
        assert!(!first.is_empty() && !second.is_empty());
        // Every observed thread is one of the persistent workers — no
        // spawn-per-call — and the pool reports the same ids afterwards.
        assert!(first.is_subset(&worker_ids), "{first:?} vs {worker_ids:?}");
        assert!(second.is_subset(&worker_ids));
        assert!(!first.contains(&std::thread::current().id()));
        let after: BTreeSet<ThreadId> = pool.worker_ids().into_iter().collect();
        assert_eq!(worker_ids, after);
    }

    #[test]
    fn stats_count_every_chunk_exactly_once() {
        let pool = Pool::new(3);
        let before: u64 = pool.stats().workers.iter().map(|w| w.chunks).sum();
        assert_eq!(before, 0, "fresh pool starts with zero chunks");
        let mut out = vec![0usize; 100];
        pool.fill_with(&mut out, 7, |i| i);
        let stats = pool.stats();
        assert_eq!(stats.workers.len(), 3);
        let chunks: u64 = stats.workers.iter().map(|w| w.chunks).sum();
        let steals: u64 = stats.workers.iter().map(|w| w.steals).sum();
        // 100 items in chunks of 7 → ceil(100/7) = 15 claims, no more.
        assert_eq!(chunks, 15, "every chunk tallied exactly once");
        assert!(steals <= chunks, "steals are a subset of claims");
        // A second job accumulates on top (counters are lifetime tallies).
        let accs = pool.fold_chunks(50, 10, || 0usize, |acc, r| *acc += r.len());
        assert_eq!(accs.iter().sum::<usize>(), 50);
        let after: u64 = pool.stats().workers.iter().map(|w| w.chunks).sum();
        assert_eq!(after, 15 + 5);
    }

    #[test]
    fn nested_use_from_worker_degrades_serially() {
        let pool = Pool::new(2);
        let mut out = vec![0u64; 32];
        // The fill closure itself calls parallel_map: must not deadlock.
        pool.fill_with(&mut out, 4, |i| {
            let items = vec![i as u64; 8];
            parallel_map(&items, 4, |x| x + 1).iter().sum()
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, 8 * (i as u64 + 1));
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::new(2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = vec![0usize; 16];
            pool.fill_with(&mut out, 1, |i| {
                if i == 7 {
                    panic!("kaboom at 7");
                }
                i
            });
        }));
        assert!(boom.is_err(), "panic must reach the submitter");
        // The pool must still be serviceable after a job panicked.
        let mut out = vec![0usize; 8];
        pool.fill_with(&mut out, 2, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn uneven_item_cost_is_balanced_by_stealing() {
        // Front-loaded cost: without stealing, worker 0 would do almost
        // all the work; the test only asserts correctness (the balancing
        // is observable in the perf bench).
        let pool = Pool::new(4);
        let mut out = vec![0u64; 400];
        pool.fill_with(&mut out, 8, |i| {
            let spin = if i < 40 { 2000 } else { 10 };
            (0..spin).fold(i as u64, |a, b| a.wrapping_add(b))
        });
        let expect: Vec<u64> = (0..400u64)
            .map(|i| {
                let spin = if i < 40 { 2000u64 } else { 10 };
                (0..spin).fold(i, |a, b| a.wrapping_add(b))
            })
            .collect();
        assert_eq!(out, expect);
    }
}
