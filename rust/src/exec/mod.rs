//! Threaded execution substrate.
//!
//! The offline registry has no tokio; the DSE engine's needs are
//! embarrassingly parallel batch evaluation, which scoped threads plus an
//! atomic work index cover with less machinery and no unsafe code.

use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (logical CPUs, capped).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(32)
}

/// Work-claim chunks per worker: enough granularity to load-balance
/// uneven items without contending on the claim counter per item.
const CLAIMS_PER_WORKER: usize = 4;

/// Apply `f` to every item in parallel, preserving input order in the
/// output. `workers = 1` degrades to a plain serial map (no threads).
///
/// Workers claim *contiguous index ranges* off one atomic counter and
/// push each finished `(start, Vec<U>)` run into a shared buffer — one
/// lock acquisition per chunk, not one `Mutex<Option<U>>` per element —
/// then the runs are stitched back in input order.
pub fn parallel_map<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    assert!(workers >= 1);
    if workers == 1 || items.len() <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let chunk = items
        .len()
        .div_ceil(workers * CLAIMS_PER_WORKER)
        .max(1);
    let n_chunks = items.len().div_ceil(chunk);

    let next_chunk = AtomicUsize::new(0);
    let runs: Mutex<Vec<(usize, Vec<U>)>> = Mutex::new(Vec::with_capacity(n_chunks));

    std::thread::scope(|scope| {
        for _ in 0..workers.min(n_chunks) {
            scope.spawn(|| loop {
                let ci = next_chunk.fetch_add(1, Ordering::Relaxed);
                if ci >= n_chunks {
                    break;
                }
                let start = ci * chunk;
                let end = (start + chunk).min(items.len());
                let out: Vec<U> = items[start..end].iter().map(|t| f(t)).collect();
                runs.lock().unwrap().push((start, out));
            });
        }
    });

    let mut runs = runs.into_inner().unwrap();
    runs.sort_unstable_by_key(|&(start, _)| start);
    debug_assert_eq!(runs.len(), n_chunks, "worker left a hole");
    let mut out = Vec::with_capacity(items.len());
    for (_, mut run) in runs {
        out.append(&mut run);
    }
    debug_assert_eq!(out.len(), items.len());
    out
}

/// Apply `f` to contiguous chunks of `items` in parallel (one call per
/// chunk), concatenating per-chunk outputs in order. Lower dispatch
/// overhead than [`parallel_map`] when per-item work is tiny — this is the
/// DSE sweep's hot-path shape.
pub fn parallel_chunks<T, U, F>(items: &[T], chunk: usize, workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&[T]) -> Vec<U> + Sync,
{
    assert!(chunk >= 1);
    let chunks: Vec<&[T]> = items.chunks(chunk).collect();
    let nested = parallel_map(&chunks, workers, |c| f(c));
    nested.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_path_matches_parallel() {
        let items: Vec<u64> = (0..100).collect();
        let a = parallel_map(&items, 1, |x| x + 7);
        let b = parallel_map(&items, 4, |x| x + 7);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u64> = Vec::new();
        let out: Vec<u64> = parallel_map(&items, 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![1u64, 2, 3];
        let out = parallel_map(&items, 16, |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn order_preserved_when_workers_exceed_len() {
        // workers > len at several awkward sizes: chunking must neither
        // drop nor reorder items when most claim slots go unused.
        for len in [2usize, 3, 5, 7, 13] {
            let items: Vec<u64> = (0..len as u64).collect();
            let out = parallel_map(&items, len * 8, |x| x * 10 + 1);
            assert_eq!(
                out,
                items.iter().map(|x| x * 10 + 1).collect::<Vec<_>>(),
                "len={len}"
            );
        }
    }

    #[test]
    fn uneven_chunk_boundaries_preserved() {
        // Lengths chosen to leave ragged tail chunks for several worker
        // counts.
        for (len, workers) in [(17usize, 2usize), (100, 3), (101, 7), (1000, 13)] {
            let items: Vec<u64> = (0..len as u64).collect();
            let out = parallel_map(&items, workers, |x| x + 1);
            assert_eq!(
                out,
                items.iter().map(|x| x + 1).collect::<Vec<_>>(),
                "len={len} workers={workers}"
            );
        }
    }

    #[test]
    fn chunked_matches_flat() {
        let items: Vec<u64> = (0..517).collect();
        let flat = parallel_map(&items, 4, |x| x + 1);
        let chunked = parallel_chunks(&items, 64, 4, |c| c.iter().map(|x| x + 1).collect());
        assert_eq!(flat, chunked);
    }

    #[test]
    fn default_workers_reasonable() {
        let w = default_workers();
        assert!((1..=32).contains(&w));
    }
}
