//! The persistent serving daemon (`cimdse serve`) and its client
//! (`cimdse query`).
//!
//! Every CLI invocation pays a full process launch, a survey fit, and a
//! fresh thread-pool spin-up before it evaluates a single point.
//! Comparative studies (ADC-less designs, collaborative digitization)
//! fire thousands of small eval/sweep queries — exactly the workload a
//! long-lived endpoint amortizes. This subsystem turns the engine into
//! that endpoint using `std::net` only (the crate stays
//! zero-dependency):
//!
//! * [`protocol`] — newline-delimited JSON frames over the
//!   [`crate::config::Value`] layer: `eval`, `sweep`, `shard`, `accel`,
//!   `metrics`, `shutdown`, plus the v2 additions (`hello` version
//!   negotiation, `cancel`, interim `progress`/`keepalive` frames);
//!   typed error frames with stable codes; floats optionally bit-hex
//!   exact per the `dse::shard` convention.
//! * [`server`] — the daemon in two selectable cores sharing one parse
//!   and dispatch funnel: the default readiness-driven event loop
//!   ([`reactor`]) and the original thread-per-connection core; both
//!   feed the one shared persistent [`crate::exec::Pool`]; graceful
//!   drain on shutdown; optional `--max-sweep-points` budget.
//! * [`reactor`] — the event loop itself: raw `epoll(7)`/`poll(2)`
//!   readiness, nonblocking per-connection state machines ([`conn`]),
//!   a runner-thread bridge for compute ops, cancel-on-disconnect,
//!   write-queue backpressure, v2 interim frames.
//! * [`conn`] — the per-connection pieces both cores share: the
//!   [`conn::FrameBuf`] framing (so both cores agree byte-for-byte on
//!   what a frame is) and the event loop's bounded write queue.
//! * [`launcher`] — the distributed half of sweep scale-out: a
//!   work-queue scheduler (`cimdse sweep --workers host:port,...`) that
//!   leases shards to daemons over the `shard` op, reassigns on worker
//!   death/timeout/corruption, resumes from on-disk artifacts, and
//!   merges bit-identically to the single-process rollup.
//! * [`cache`] — LRU of [`crate::adc::PreparedModel`] keyed by the
//!   model's canonical-JSON FNV-1a fingerprint
//!   ([`crate::dse::model_fingerprint`]), with hit/miss counters.
//! * [`metrics`] — requests served, cache hits, p50/p99 latency via
//!   [`crate::stats::quantile`], uptime — served as a frame and
//!   printable.
//! * [`client`] — the blocking client behind `cimdse query`.
//!
//! Served responses are **bit-identical** to the corresponding direct
//! library calls: `eval` goes through the prepared row kernel (exact
//! bits vs [`crate::adc::AdcModel::eval`] by construction) and `sweep`
//! returns the canonical [`crate::dse::SweepSummary`] payload —
//! asserted across a real socket by `tests/serve_roundtrip.rs`. The
//! frame grammar is specified in `rust/docs/protocol.md`.

pub mod cache;
pub mod client;
pub mod conn;
pub mod launcher;
pub mod metrics;
pub mod protocol;
#[cfg(unix)]
pub mod reactor;
pub mod server;

pub use cache::{CacheStats, PreparedCache};
pub use client::Client;
pub use launcher::{LaunchOptions, LaunchReport, WorkerReport, run_distributed_sweep};
pub use metrics::ServiceMetrics;
pub use protocol::{MAX_FRAME_BYTES, PROTOCOL_V1, PROTOCOL_V2, Reject, Request};
pub use server::{ServeCore, ServeOptions, Server, ServerHandle};
