//! Blocking client for the serving daemon — powers `cimdse query` and
//! lets tests/CI/scripts hit the daemon without hand-rolling sockets.
//!
//! One [`Client`] wraps one connection; requests are answered in order
//! (send a frame, read a line). Server-side error frames surface as
//! [`Error::Runtime`] carrying the stable protocol code.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::adc::{AdcModel, AdcQuery};
use crate::config::{Value, parse_json};
use crate::dse::{SweepSpec, SweepSummary};
use crate::error::{Error, Result};

use super::protocol;

/// A connected protocol client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a serving daemon at `addr` (e.g. `127.0.0.1:4117`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Runtime(format!("query: cannot connect to {addr}: {e}")))?;
        let reader = BufReader::new(
            stream
                .try_clone()
                .map_err(|e| Error::Runtime(format!("query: clone stream: {e}")))?,
        );
        Ok(Client { writer: stream, reader })
    }

    /// Send one raw frame line and read the response line (uninterpreted).
    pub fn request_line(&mut self, line: &str) -> Result<Value> {
        debug_assert!(!line.contains('\n'), "frames are single lines");
        let mut bytes = Vec::with_capacity(line.len() + 1);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        self.writer
            .write_all(&bytes)
            .and_then(|_| self.writer.flush())
            .map_err(|e| Error::Runtime(format!("query: send failed: {e}")))?;
        let mut response = String::new();
        let n = self
            .reader
            .read_line(&mut response)
            .map_err(|e| Error::Runtime(format!("query: read failed: {e}")))?;
        if n == 0 {
            return Err(Error::Runtime("query: server closed the connection".into()));
        }
        parse_json(response.trim_end())
            .map_err(|e| Error::Runtime(format!("query: unparsable response: {e}")))
    }

    /// Send a frame [`Value`] and return the response's `result`,
    /// converting server error frames into [`Error::Runtime`].
    pub fn call(&mut self, frame: &Value) -> Result<Value> {
        let response = self.request_line(&frame.to_json_string()?)?;
        into_result(response)
    }

    /// `eval` one design point. `bits` selects bit-hex response floats.
    /// Returns the full result table (`points`, `cache`, `count`).
    pub fn eval(
        &mut self,
        query: &AdcQuery,
        model: Option<&AdcModel>,
        bits: bool,
    ) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("eval".to_string()));
        map.insert("query".to_string(), protocol::query_to_value(query));
        map.insert("bits".to_string(), Value::Bool(bits));
        if let Some(m) = model {
            map.insert("model".to_string(), protocol::model_to_value(m));
        }
        self.call(&Value::Table(map))
    }

    /// `eval` convenience: the metrics of one design point, decoded.
    pub fn eval_metrics(
        &mut self,
        query: &AdcQuery,
        model: Option<&AdcModel>,
    ) -> Result<crate::adc::AdcMetrics> {
        let result = self.eval(query, model, true)?;
        let points = result
            .get("points")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::Runtime("query: eval result lacks `points`".into()))?;
        let point = points
            .first()
            .ok_or_else(|| Error::Runtime("query: eval result has no points".into()))?;
        let metrics = point
            .get("metrics")
            .ok_or_else(|| Error::Runtime("query: eval point lacks `metrics`".into()))?;
        protocol::metrics_from_value(metrics)
            .map_err(|r| Error::Runtime(format!("query: bad metrics payload: {}", r.message)))
    }

    /// `sweep` an inline spec; returns the full result table plus the
    /// decoded summary (bit-identical to the library rollup).
    pub fn sweep(
        &mut self,
        spec: &SweepSpec,
        model: Option<&AdcModel>,
    ) -> Result<(Value, SweepSummary)> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("sweep".to_string()));
        map.insert("spec".to_string(), spec.to_value());
        if let Some(m) = model {
            map.insert("model".to_string(), protocol::model_to_value(m));
        }
        let result = self.call(&Value::Table(map))?;
        let summary = result
            .get("summary")
            .ok_or_else(|| Error::Runtime("query: sweep result lacks `summary`".into()))?;
        let summary = SweepSummary::from_value(summary)?;
        Ok((result, summary))
    }

    /// `accel` over a zoo workload with default knobs.
    pub fn accel(&mut self, workload: &str, model: Option<&AdcModel>) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("accel".to_string()));
        map.insert("workload".to_string(), Value::String(workload.to_string()));
        if let Some(m) = model {
            map.insert("model".to_string(), protocol::model_to_value(m));
        }
        self.call(&Value::Table(map))
    }

    /// Fetch the server's `metrics` snapshot.
    pub fn metrics(&mut self) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("metrics".to_string()));
        self.call(&Value::Table(map))
    }

    /// Request a graceful drain.
    pub fn shutdown(&mut self) -> Result<()> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("shutdown".to_string()));
        self.call(&Value::Table(map)).map(|_| ())
    }
}

/// Split a response frame into its `result`, mapping error frames to
/// [`Error::Runtime`] with the stable code in the message.
pub fn into_result(response: Value) -> Result<Value> {
    match response.get("ok").and_then(Value::as_bool) {
        Some(true) => response
            .get("result")
            .cloned()
            .ok_or_else(|| Error::Runtime("query: ok response lacks `result`".into())),
        Some(false) => {
            let code = response.get("error.code").and_then(Value::as_str).unwrap_or("?");
            let message =
                response.get("error.message").and_then(Value::as_str).unwrap_or("?");
            Err(Error::Runtime(format!("server error [{code}]: {message}")))
        }
        None => Err(Error::Runtime("query: response lacks an `ok` field".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn into_result_maps_frames() {
        let ok = parse_json(r#"{"ok": true, "op": "metrics", "result": {"x": 1}}"#).unwrap();
        assert_eq!(into_result(ok).unwrap().require_f64("x").unwrap(), 1.0);
        let err = parse_json(
            r#"{"ok": false, "error": {"code": "unknown-op", "message": "nope"}}"#,
        )
        .unwrap();
        let e = into_result(err).unwrap_err().to_string();
        assert!(e.contains("unknown-op") && e.contains("nope"), "{e}");
        let junk = parse_json(r#"{"weird": 1}"#).unwrap();
        assert!(into_result(junk).is_err());
    }
}
