//! Blocking client for the serving daemon — powers `cimdse query` and
//! lets tests/CI/scripts hit the daemon without hand-rolling sockets.
//!
//! One [`Client`] wraps one connection; requests are answered in order
//! (send a frame, read a line). Server-side error frames surface as
//! [`Error::Runtime`] carrying the stable protocol code.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::adc::{AdcModel, AdcQuery};
use crate::config::{Value, parse_json};
use crate::dse::{
    ObjectiveSet, ShardArtifact, ShardSelector, SnrContext, SweepSpec, SweepSummary,
};
use crate::error::{Error, Result};

use super::protocol;

/// A connected protocol client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a serving daemon at `addr` (e.g. `127.0.0.1:4117`)
    /// with no I/O deadline (blocking reads wait forever — fine for
    /// interactive use; automation should prefer
    /// [`Client::connect_with_timeout`]).
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with_timeout(addr, None)
    }

    /// Connect with an I/O deadline. `timeout` bounds the TCP connect
    /// *and* every subsequent read/write: a worker that accepts the
    /// connection and then hangs (or stops reading) surfaces as a typed
    /// [`Error::Runtime`] after `timeout` instead of wedging the caller
    /// forever — the property the shard launcher relies on to reassign
    /// work from a stuck worker. `None` means no deadline. A timed-out
    /// client is not resynchronizable (a response may arrive later and
    /// desync the frame stream); drop it and reconnect.
    pub fn connect_with_timeout(addr: &str, timeout: Option<Duration>) -> Result<Client> {
        let stream = match timeout {
            None => TcpStream::connect(addr)
                .map_err(|e| Error::Runtime(format!("query: cannot connect to {addr}: {e}")))?,
            Some(t) => {
                let resolved = addr
                    .to_socket_addrs()
                    .map_err(|e| Error::Runtime(format!("query: cannot resolve {addr}: {e}")))?;
                let mut stream = None;
                let mut last_err = None;
                for a in resolved {
                    match TcpStream::connect_timeout(&a, t) {
                        Ok(s) => {
                            stream = Some(s);
                            break;
                        }
                        Err(e) => last_err = Some(e),
                    }
                }
                stream.ok_or_else(|| match last_err {
                    Some(e) => {
                        Error::Runtime(format!("query: cannot connect to {addr}: {e}"))
                    }
                    None => Error::Runtime(format!(
                        "query: {addr} resolved to no addresses"
                    )),
                })?
            }
        };
        stream
            .set_read_timeout(timeout)
            .and_then(|_| stream.set_write_timeout(timeout))
            .map_err(|e| Error::Runtime(format!("query: set timeout on {addr}: {e}")))?;
        let reader = BufReader::new(
            stream
                .try_clone()
                .map_err(|e| Error::Runtime(format!("query: clone stream: {e}")))?,
        );
        Ok(Client { writer: stream, reader })
    }

    /// Change the read/write deadline of an established connection
    /// (`None` removes it). See [`Client::connect_with_timeout`] for the
    /// semantics of a deadline that fires.
    pub fn set_io_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        let stream = self.reader.get_ref();
        stream
            .set_read_timeout(timeout)
            .and_then(|_| self.writer.set_write_timeout(timeout))
            .map_err(|e| Error::Runtime(format!("query: set timeout: {e}")))
    }

    /// Send one raw frame line and read the response line
    /// (uninterpreted). Interim v2 `progress`/`keepalive` frames are
    /// skipped transparently — and because each one restarts the read
    /// deadline, a configured timeout bounds the **inter-frame gap**
    /// (liveness), not total compute time: a server that streams
    /// progress on a long sweep is healthy no matter how long the sweep
    /// takes, while one that goes silent still times out.
    pub fn request_line(&mut self, line: &str) -> Result<Value> {
        debug_assert!(!line.contains('\n'), "frames are single lines");
        let mut bytes = Vec::with_capacity(line.len() + 1);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        self.writer
            .write_all(&bytes)
            .and_then(|_| self.writer.flush())
            .map_err(|e| Error::Runtime(format!("query: send failed: {e}")))?;
        loop {
            let mut response = String::new();
            let n = self.reader.read_line(&mut response).map_err(|e| {
                if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
                    Error::Runtime(
                        "query: read timed out waiting for a response (hung worker?); \
                         the connection is no longer usable"
                            .into(),
                    )
                } else {
                    Error::Runtime(format!("query: read failed: {e}"))
                }
            })?;
            if n == 0 {
                return Err(Error::Runtime("query: server closed the connection".into()));
            }
            let doc = parse_json(response.trim_end())
                .map_err(|e| Error::Runtime(format!("query: unparsable response: {e}")))?;
            if !protocol::is_interim_frame(&doc) {
                return Ok(doc);
            }
        }
    }

    /// Send a frame [`Value`] and return the response's `result`,
    /// converting server error frames into [`Error::Runtime`].
    pub fn call(&mut self, frame: &Value) -> Result<Value> {
        let response = self.request_line(&frame.to_json_string()?)?;
        into_result(response)
    }

    /// `eval` one design point. `bits` selects bit-hex response floats.
    /// Returns the full result table (`points`, `cache`, `count`).
    pub fn eval(
        &mut self,
        query: &AdcQuery,
        model: Option<&AdcModel>,
        bits: bool,
    ) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("eval".to_string()));
        map.insert("query".to_string(), protocol::query_to_value(query));
        map.insert("bits".to_string(), Value::Bool(bits));
        if let Some(m) = model {
            map.insert("model".to_string(), protocol::model_to_value(m));
        }
        self.call(&Value::Table(map))
    }

    /// `eval` convenience: the metrics of one design point, decoded.
    pub fn eval_metrics(
        &mut self,
        query: &AdcQuery,
        model: Option<&AdcModel>,
    ) -> Result<crate::adc::AdcMetrics> {
        let result = self.eval(query, model, true)?;
        let points = result
            .get("points")
            .and_then(Value::as_array)
            .ok_or_else(|| Error::Runtime("query: eval result lacks `points`".into()))?;
        let point = points
            .first()
            .ok_or_else(|| Error::Runtime("query: eval result has no points".into()))?;
        let metrics = point
            .get("metrics")
            .ok_or_else(|| Error::Runtime("query: eval point lacks `metrics`".into()))?;
        protocol::metrics_from_value(metrics)
            .map_err(|r| Error::Runtime(format!("query: bad metrics payload: {}", r.message)))
    }

    /// `sweep` an inline spec; returns the full result table plus the
    /// decoded summary (bit-identical to the library rollup).
    pub fn sweep(
        &mut self,
        spec: &SweepSpec,
        model: Option<&AdcModel>,
    ) -> Result<(Value, SweepSummary)> {
        self.sweep_with(spec, model, None)
    }

    /// [`Client::sweep`] with an optional compute-SNR objective context:
    /// `Some(ctx)` requests the `energy,area,snr` objective set (the
    /// summary then carries the tri-objective front under `ctx`), `None`
    /// sends the exact classic frame [`Client::sweep`] always has.
    pub fn sweep_with(
        &mut self,
        spec: &SweepSpec,
        model: Option<&AdcModel>,
        snr: Option<&SnrContext>,
    ) -> Result<(Value, SweepSummary)> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("sweep".to_string()));
        map.insert("spec".to_string(), spec.to_value());
        if let Some(m) = model {
            map.insert("model".to_string(), protocol::model_to_value(m));
        }
        insert_objectives(&mut map, snr);
        let result = self.call(&Value::Table(map))?;
        let summary = result
            .get("summary")
            .ok_or_else(|| Error::Runtime("query: sweep result lacks `summary`".into()))?;
        let summary = SweepSummary::from_value(summary)?;
        Ok((result, summary))
    }

    /// `shard` one index sub-range of a sweep remotely (the wire form of
    /// `cimdse sweep --shard i/N`). The returned artifact has passed the
    /// full [`ShardArtifact::from_value`] validation — fingerprint vs
    /// embedded spec/model, planned-range agreement, and the summary
    /// payload checksum — so a corrupted or tampered response (even a
    /// single flipped payload bit) surfaces as a typed error here, never
    /// as a silently skewed merge.
    pub fn shard(
        &mut self,
        spec: &SweepSpec,
        model: Option<&AdcModel>,
        selector: ShardSelector,
    ) -> Result<ShardArtifact> {
        self.shard_traced(spec, model, selector, None)
    }

    /// [`Client::shard`] with an optional trace context attached to the
    /// request frame, so the worker's serving span (and its pool chunk
    /// spans) parent under the launcher's shard span — the cross-process
    /// link that stitches a fleet run into one trace forest. `None`
    /// sends the exact frame [`Client::shard`] always has.
    pub fn shard_traced(
        &mut self,
        spec: &SweepSpec,
        model: Option<&AdcModel>,
        selector: ShardSelector,
        trace: Option<&Value>,
    ) -> Result<ShardArtifact> {
        self.shard_traced_with(spec, model, selector, trace, None)
    }

    /// [`Client::shard_traced`] with an optional compute-SNR objective
    /// context (see [`Client::sweep_with`]); the returned artifact's
    /// fingerprint then covers the context, so the launcher's resume
    /// probe distinguishes tri-objective artifacts from classic ones.
    pub fn shard_traced_with(
        &mut self,
        spec: &SweepSpec,
        model: Option<&AdcModel>,
        selector: ShardSelector,
        trace: Option<&Value>,
        snr: Option<&SnrContext>,
    ) -> Result<ShardArtifact> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("shard".to_string()));
        map.insert("spec".to_string(), spec.to_value());
        map.insert("shard".to_string(), Value::String(selector.to_string()));
        if let Some(m) = model {
            map.insert("model".to_string(), protocol::model_to_value(m));
        }
        if let Some(t) = trace {
            map.insert("trace".to_string(), t.clone());
        }
        insert_objectives(&mut map, snr);
        let result = self.call(&Value::Table(map))?;
        let artifact = result
            .get("artifact")
            .ok_or_else(|| Error::Runtime("query: shard result lacks `artifact`".into()))?;
        ShardArtifact::from_value(artifact)
            .map_err(|e| Error::Runtime(format!("query: shard artifact rejected: {e}")))
    }

    /// `accel` over a zoo workload with default knobs.
    pub fn accel(&mut self, workload: &str, model: Option<&AdcModel>) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("accel".to_string()));
        map.insert("workload".to_string(), Value::String(workload.to_string()));
        if let Some(m) = model {
            map.insert("model".to_string(), protocol::model_to_value(m));
        }
        self.call(&Value::Table(map))
    }

    /// Fetch the server's `metrics` snapshot.
    pub fn metrics(&mut self) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("metrics".to_string()));
        self.call(&Value::Table(map))
    }

    /// Request a graceful drain.
    pub fn shutdown(&mut self) -> Result<()> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("shutdown".to_string()));
        self.call(&Value::Table(map)).map(|_| ())
    }

    /// Negotiate protocol v2 on this connection. After this the server
    /// may interleave `progress`/`keepalive` frames, which
    /// [`Client::request_line`] skips and which keep the read deadline
    /// armed during long computations.
    pub fn negotiate_v2(&mut self) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("hello".to_string()));
        map.insert(
            "version".to_string(),
            Value::Number(f64::from(protocol::PROTOCOL_V2)),
        );
        self.call(&Value::Table(map))
    }

    /// Cancel a queued or in-flight request by its `id`. Only
    /// meaningful on a pipelined connection; on a lockstep one the
    /// target has always already been answered, earning `unknown-id`.
    pub fn cancel(&mut self, target: &Value) -> Result<Value> {
        let mut map = std::collections::BTreeMap::new();
        map.insert("op".to_string(), Value::String("cancel".to_string()));
        map.insert("target".to_string(), target.clone());
        self.call(&Value::Table(map))
    }
}

/// Attach the `objectives`/`snr` fields selecting the tri-objective
/// set to a request frame. `None` inserts nothing — the frame is
/// byte-identical to the pre-objectives protocol.
fn insert_objectives(map: &mut std::collections::BTreeMap<String, Value>, snr: Option<&SnrContext>) {
    if let Some(ctx) = snr {
        map.insert(
            "objectives".to_string(),
            Value::Array(
                ObjectiveSet::EnergyAreaSnr
                    .names()
                    .iter()
                    .map(|n| Value::String((*n).to_string()))
                    .collect(),
            ),
        );
        map.insert("snr".to_string(), ctx.to_value());
    }
}

/// Split a response frame into its `result`, mapping error frames to
/// [`Error::Runtime`] with the stable code in the message.
pub fn into_result(response: Value) -> Result<Value> {
    match response.get("ok").and_then(Value::as_bool) {
        Some(true) => response
            .get("result")
            .cloned()
            .ok_or_else(|| Error::Runtime("query: ok response lacks `result`".into())),
        Some(false) => {
            let code = response.get("error.code").and_then(Value::as_str).unwrap_or("?");
            let message =
                response.get("error.message").and_then(Value::as_str).unwrap_or("?");
            Err(Error::Runtime(format!("server error [{code}]: {message}")))
        }
        None => Err(Error::Runtime("query: response lacks an `ok` field".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A server that accepts and never replies must not wedge a client
    /// with a deadline: the read times out with a typed error naming the
    /// hang (the guarantee the shard launcher's reassignment rests on).
    #[test]
    fn read_timeout_unwedges_a_hung_worker() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hold = std::thread::spawn(move || {
            // Accept, read the request, never answer; keep the socket
            // open until the client has given up.
            let (mut stream, _) = listener.accept().unwrap();
            let mut buf = [0u8; 1024];
            use std::io::Read as _;
            let _ = stream.read(&mut buf);
            std::thread::sleep(Duration::from_secs(2));
        });
        let mut client =
            Client::connect_with_timeout(&addr, Some(Duration::from_millis(200))).unwrap();
        let start = std::time::Instant::now();
        let err = client.metrics().unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(
            start.elapsed() < Duration::from_secs(2),
            "timeout must fire well before the worker lets go ({:?})",
            start.elapsed()
        );
        hold.join().unwrap();
    }

    #[test]
    fn connect_timeout_surfaces_refused_connections_as_typed_errors() {
        // Bind-then-drop: the port was just free, so connecting is
        // (near-)instantly refused rather than black-holed.
        let addr = {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            listener.local_addr().unwrap().to_string()
        };
        let err = Client::connect_with_timeout(&addr, Some(Duration::from_millis(500)))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot connect"), "{err}");
    }

    #[test]
    fn into_result_maps_frames() {
        let ok = parse_json(r#"{"ok": true, "op": "metrics", "result": {"x": 1}}"#).unwrap();
        assert_eq!(into_result(ok).unwrap().require_f64("x").unwrap(), 1.0);
        let err = parse_json(
            r#"{"ok": false, "error": {"code": "unknown-op", "message": "nope"}}"#,
        )
        .unwrap();
        let e = into_result(err).unwrap_err().to_string();
        assert!(e.contains("unknown-op") && e.contains("nope"), "{e}");
        let junk = parse_json(r#"{"weird": 1}"#).unwrap();
        assert!(into_result(junk).is_err());
    }
}
