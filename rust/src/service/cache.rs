//! LRU cache of prepared models, keyed by the model's canonical-JSON
//! FNV-1a fingerprint ([`crate::dse::model_fingerprint`]).
//!
//! Every request that names (or defaults) a model resolves through
//! here. To be precise about what that buys today:
//! [`PreparedModel::new`] is currently a cheap copy (the row hoisting
//! happens per-(ENOB, tech) at eval time), so the cache's present
//! value is model-*identity* tracking — the hit/miss/collision
//! counters surfaced by the `metrics` frame (and asserted by the CI
//! smoke test), which tell a study it really is reusing one model —
//! plus one shared `Arc` per distinct model instead of a per-request
//! allocation, and the seam where heavier prepared state (e.g.
//! precomputed row tables) can land later without touching the
//! protocol. Connection threads evaluate outside the cache lock; only
//! the lookup itself (a map probe + 13-float bit compare) holds it.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::adc::{AdcModel, PreparedModel};

/// Cache counters, as reported by the `metrics` frame.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that had to prepare a model.
    pub misses: u64,
    /// Models evicted to stay within capacity.
    pub evictions: u64,
    /// Lookups whose fingerprint matched a cached entry holding
    /// *different* model bits (64-bit FNV-1a is not collision-resistant;
    /// such lookups are served uncached so a hit can never change a
    /// response).
    pub collisions: u64,
    /// Models currently cached.
    pub entries: usize,
    /// Maximum models kept.
    pub capacity: usize,
}

struct CacheEntry {
    /// Monotonic use tick; the smallest tick is the LRU victim.
    last_used: u64,
    prepared: Arc<PreparedModel>,
}

/// Exact bit equality of two models (stricter than `PartialEq`, which
/// conflates ±0.0 and never matches NaN) — the hit criterion, matching
/// how [`crate::dse::model_fingerprint`] identifies a model. Field-wise
/// on the stack (no allocation): this runs under the cache lock on
/// every request that names a cached model.
fn same_bits(a: &AdcModel, b: &AdcModel) -> bool {
    let (ca, cb) = (&a.coefs, &b.coefs);
    [
        (ca.a0, cb.a0),
        (ca.a1, cb.a1),
        (ca.a2, cb.a2),
        (ca.b0, cb.b0),
        (ca.b1, cb.b1),
        (ca.b2, cb.b2),
        (ca.b3, cb.b3),
        (ca.d0, cb.d0),
        (ca.d1, cb.d1),
        (ca.d2, cb.d2),
        (ca.d3, cb.d3),
        (a.energy_offset_decades, b.energy_offset_decades),
        (a.area_offset_decades, b.area_offset_decades),
    ]
    .iter()
    .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// An LRU map `fingerprint -> Arc<PreparedModel>`.
pub struct PreparedCache {
    capacity: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    collisions: u64,
    entries: BTreeMap<String, CacheEntry>,
}

impl PreparedCache {
    /// Cache holding at most `capacity` prepared models (`>= 1`).
    pub fn new(capacity: usize) -> PreparedCache {
        assert!(capacity >= 1, "prepared-model cache needs capacity >= 1");
        PreparedCache {
            capacity,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            collisions: 0,
            entries: BTreeMap::new(),
        }
    }

    /// Fetch the prepared model for `fingerprint`, preparing (and
    /// caching) `model` on a miss. Returns the shared prepared model
    /// and whether this lookup was a hit. The caller computes the
    /// fingerprint (it already needs it for logging/metrics), which
    /// also keeps this map oblivious to model semantics.
    ///
    /// A hit requires the cached model's *bits* to equal `model`, not
    /// just the fingerprint: models are client-supplied and 64-bit
    /// FNV-1a is not collision-resistant, so a colliding lookup is
    /// served with a freshly prepared (uncached) model rather than the
    /// wrong cached one — a hit can never change a response.
    pub fn get_or_prepare(
        &mut self,
        fingerprint: &str,
        model: &AdcModel,
    ) -> (Arc<PreparedModel>, bool) {
        self.tick += 1;
        if let Some(entry) = self.entries.get_mut(fingerprint) {
            if same_bits(entry.prepared.model(), model) {
                entry.last_used = self.tick;
                self.hits += 1;
                return (Arc::clone(&entry.prepared), true);
            }
            // Fingerprint collision: leave the resident entry alone
            // (replacing would thrash both models) and serve uncached.
            self.collisions += 1;
            self.misses += 1;
            return (Arc::new(PreparedModel::new(model)), false);
        }
        self.misses += 1;
        let prepared = Arc::new(PreparedModel::new(model));
        self.entries.insert(
            fingerprint.to_string(),
            CacheEntry { last_used: self.tick, prepared: Arc::clone(&prepared) },
        );
        while self.entries.len() > self.capacity {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty cache has an LRU victim");
            self.entries.remove(&victim);
            self.evictions += 1;
        }
        (prepared, false)
    }

    /// Current counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            collisions: self.collisions,
            entries: self.entries.len(),
            capacity: self.capacity,
        }
    }

    /// Number of cached models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::model_fingerprint;

    fn offset_model(offset: f64) -> AdcModel {
        AdcModel { energy_offset_decades: offset, ..AdcModel::default() }
    }

    #[test]
    fn hit_and_miss_counters() {
        let mut cache = PreparedCache::new(4);
        let model = AdcModel::default();
        let fp = model_fingerprint(&model);
        let (a, hit) = cache.get_or_prepare(&fp, &model);
        assert!(!hit);
        let (b, hit) = cache.get_or_prepare(&fp, &model);
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached instance");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.capacity), (1, 1, 1, 4));
    }

    #[test]
    fn evicts_least_recently_used_first() {
        let mut cache = PreparedCache::new(2);
        let m1 = offset_model(0.1);
        let m2 = offset_model(0.2);
        let m3 = offset_model(0.3);
        let (f1, f2, f3) =
            (model_fingerprint(&m1), model_fingerprint(&m2), model_fingerprint(&m3));
        cache.get_or_prepare(&f1, &m1);
        cache.get_or_prepare(&f2, &m2);
        // Touch m1 so m2 becomes the LRU victim.
        assert!(cache.get_or_prepare(&f1, &m1).1);
        cache.get_or_prepare(&f3, &m3);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get_or_prepare(&f1, &m1).1, "m1 must survive");
        assert!(!cache.get_or_prepare(&f2, &m2).1, "m2 must have been evicted");
    }

    #[test]
    fn fingerprint_collision_is_served_uncached_with_the_right_model() {
        let mut cache = PreparedCache::new(4);
        let m1 = offset_model(0.1);
        let m2 = offset_model(0.2);
        let fp = model_fingerprint(&m1);
        let (a, hit) = cache.get_or_prepare(&fp, &m1);
        assert!(!hit);
        // Same key, different bits (a forced collision): not a hit, and
        // the returned prepared model carries the *requested* bits.
        let (b, hit) = cache.get_or_prepare(&fp, &m2);
        assert!(!hit);
        assert_eq!(b.model(), &m2);
        assert!(!Arc::ptr_eq(&a, &b));
        // The resident entry is untouched and still hits for its owner.
        assert!(cache.get_or_prepare(&fp, &m1).1);
        let s = cache.stats();
        assert_eq!((s.collisions, s.entries), (1, 1));
    }

    #[test]
    fn cached_model_evaluates_bit_identically() {
        let mut cache = PreparedCache::new(1);
        let model = offset_model(0.05);
        let fp = model_fingerprint(&model);
        let (prepared, _) = cache.get_or_prepare(&fp, &model);
        let q = crate::adc::AdcQuery {
            enob: 7.0,
            total_throughput: 1.3e9,
            tech_nm: 32.0,
            n_adcs: 8,
        };
        let via_cache = prepared.row(q.enob, q.tech_nm).eval_query(&q);
        assert_eq!(via_cache.to_bits(), model.eval(&q).to_bits());
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_is_a_caller_bug() {
        let _ = PreparedCache::new(0);
    }
}
