//! Wire protocol: newline-delimited JSON frames over the
//! [`crate::config::Value`] layer.
//!
//! ## Frame grammar
//!
//! Every request is one JSON object on one line (`\n`-terminated, at
//! most [`MAX_FRAME_BYTES`] bytes). The `op` field selects the
//! operation (`hello`, `eval`, `sweep`, `shard`, `accel`, `metrics`,
//! `cancel`, `shutdown`); an
//! optional scalar `id` (string or number) is echoed back verbatim so
//! pipelining clients can match responses. Responses are one JSON
//! object per line: `{"ok": true, "op": ..., "result": {...}}` on
//! success, `{"ok": false, "error": {"code": ..., "message": ...}}` on
//! failure. Error frames use the stable codes below and never cost the
//! client its connection — the server answers and keeps reading.
//!
//! ## Protocol v2
//!
//! A connection starts in protocol v1. A `hello` frame negotiates the
//! version ([`PROTOCOL_V1`]..=[`PROTOCOL_V2`]); only a connection that
//! negotiated v2 ever receives *interim* frames — `progress` and
//! `keepalive` lines emitted while a `sweep`/`shard`/`accel` request
//! computes. Interim frames carry a `"frame"` discriminator and no
//! `"ok"` key ([`is_interim_frame`]), so final responses keep their v1
//! shape byte-for-byte and a v1 client that never says hello sees
//! exactly the v1 byte stream. The `cancel` op aborts an in-flight or
//! queued request by its `id` on the same connection; the cancelled
//! request answers with a [`CODE_CANCELLED`] error frame. See
//! `rust/docs/protocol.md` for the v2 grammar and compatibility table.
//!
//! ## Trace context
//!
//! Any request frame may carry an optional `trace` field: a table
//! `{"id": <16-hex>, "span": <16-hex>}` naming the distributed trace
//! the request belongs to and the caller-side span to parent
//! server-side work under ([`frame_trace`]). The validated table is
//! echoed verbatim on every frame the request produces — the final
//! response *or* error frame and any interim `progress` frames — so a
//! launcher can stitch a fleet's frames into one trace forest
//! (`cimdse trace`; see [`crate::obs`]). A malformed `trace` is a
//! [`CODE_BAD_REQUEST`] whose error frame carries no echo. Frames
//! without the field are byte-identical to the pre-trace protocol:
//! the key is simply never inserted.
//!
//! ## Float convention
//!
//! Request floats may be JSON numbers *or* 16-hex-digit IEEE-754 bit
//! patterns per the `dse::shard` convention ([`crate::config::f64_from_bits_hex`]).
//! Two exceptions share their shape verbatim with shard artifacts so
//! the wire and artifact parsers are literally the same code: `model`
//! payloads are bit-hex only ([`model_to_value`]) and sweep `spec`
//! axes are numbers only ([`SweepSpec::to_value`], which round-trips
//! finite f64 bits losslessly). Responses use numbers by
//! default (Rust prints the shortest decimal that parses back to
//! identical bits, so finite floats round-trip exactly; non-finite
//! values fall back to bit-hex); `"bits": true` on an `eval` request
//! switches its response floats to bit-hex, and `sweep` summaries
//! always travel bit-hex (they reuse the shard artifact payload). See
//! `rust/docs/protocol.md` for the full grammar.

use std::collections::BTreeMap;

use crate::adc::{AdcMetrics, AdcModel, AdcQuery};
use crate::config::{Value, f64_from_bits_hex, f64_to_bits_hex};
use crate::dse::accel::AccelSweepSpec;
use crate::dse::{ObjectiveSet, ShardPlan, ShardSelector, SnrContext, SweepSpec, shard};

/// Hard cap on one request frame (bytes, newline excluded). A frame
/// that grows past this yields an [`CODE_OVERSIZED_FRAME`] error frame
/// and the rest of the line is discarded.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Largest `queries` batch one `eval` frame may carry (bounds response
/// size; sweeps are the tool for bigger grids).
pub const MAX_EVAL_BATCH: usize = 4096;

/// Error code: the frame was not parseable JSON (or not UTF-8).
pub const CODE_MALFORMED_JSON: &str = "malformed-json";
/// Error code: the frame parsed but is not a JSON object with an `op`.
pub const CODE_BAD_FRAME: &str = "bad-frame";
/// Error code: the `op` value is not a known operation.
pub const CODE_UNKNOWN_OP: &str = "unknown-op";
/// Error code: a field is missing, mistyped, or semantically invalid.
pub const CODE_BAD_REQUEST: &str = "bad-request";
/// Error code: the request line exceeded [`MAX_FRAME_BYTES`].
pub const CODE_OVERSIZED_FRAME: &str = "oversized-frame";
/// Error code: the request would evaluate more grid points than the
/// server's `--max-sweep-points` budget allows (`sweep` counts its full
/// grid, `shard` counts only its own index sub-range).
pub const CODE_OVER_BUDGET: &str = "over-budget";
/// Error code: the server failed internally while serving a valid
/// request (should not happen; kept for forward compatibility).
pub const CODE_INTERNAL: &str = "internal";
/// Error code: a `hello` frame asked for a protocol version outside
/// [`PROTOCOL_V1`]..=[`PROTOCOL_V2`].
pub const CODE_UNSUPPORTED_VERSION: &str = "unsupported-version";
/// Error code: a `cancel` frame named an `id` with no in-flight or
/// queued request on this connection (never started, already answered,
/// or owned by another connection).
pub const CODE_UNKNOWN_ID: &str = "unknown-id";
/// Error code: the request was cancelled before it completed — by a
/// `cancel` frame naming its `id`, by its connection disconnecting, or
/// by server shutdown discarding queued work.
pub const CODE_CANCELLED: &str = "cancelled";

/// The baseline protocol version every connection starts in.
pub const PROTOCOL_V1: u32 = 1;
/// The newest protocol version this build speaks (progress/keepalive
/// interim frames + `cancel`).
pub const PROTOCOL_V2: u32 = 2;

/// A typed protocol rejection: stable machine code + human message.
#[derive(Clone, Debug)]
pub struct Reject {
    /// One of the `CODE_*` constants.
    pub code: &'static str,
    /// Human-readable detail (not part of the stable surface).
    pub message: String,
}

impl Reject {
    /// Build a rejection.
    pub fn new(code: &'static str, message: impl Into<String>) -> Reject {
        Reject { code, message: message.into() }
    }

    fn bad(message: impl Into<String>) -> Reject {
        Reject::new(CODE_BAD_REQUEST, message)
    }
}

/// A parsed, validated request frame.
#[derive(Clone, Debug)]
pub enum Request {
    /// Negotiate the connection's protocol version (v2 entry point).
    Hello(u32),
    /// Evaluate one or more design points.
    Eval(EvalRequest),
    /// Stream a whole sweep grid to its summary rollup.
    Sweep(SweepRequest),
    /// Compute one shard of a sweep and return its artifact.
    Shard(ShardRequest),
    /// Accelerator-level DSE over a workload from the zoo.
    Accel(AccelRequest),
    /// Server counters / latency quantiles / cache stats.
    Metrics,
    /// Abort the same connection's in-flight or queued request whose
    /// `id` equals the carried target (scalar, pre-validated).
    Cancel(Value),
    /// Graceful drain: stop accepting, finish in-flight work, exit.
    Shutdown,
}

impl Request {
    /// The op name this request was parsed from.
    pub fn op(&self) -> &'static str {
        match self {
            Request::Hello(_) => "hello",
            Request::Eval(_) => "eval",
            Request::Sweep(_) => "sweep",
            Request::Shard(_) => "shard",
            Request::Accel(_) => "accel",
            Request::Metrics => "metrics",
            Request::Cancel(_) => "cancel",
            Request::Shutdown => "shutdown",
        }
    }
}

/// `op: "eval"` payload.
#[derive(Clone, Debug)]
pub struct EvalRequest {
    /// The design points to evaluate (singular `query` arrives as one).
    pub queries: Vec<AdcQuery>,
    /// Model override; `None` uses the server's default model.
    pub model: Option<AdcModel>,
    /// Encode response floats as IEEE-754 bit-hex strings.
    pub bits: bool,
}

/// `op: "sweep"` payload.
#[derive(Clone, Debug)]
pub struct SweepRequest {
    /// The inline sweep grid.
    pub spec: SweepSpec,
    /// Model override; `None` uses the server's default model.
    pub model: Option<AdcModel>,
    /// Compute-SNR objective context, iff the frame selected the
    /// `energy,area,snr` objective set via its `objectives` field.
    /// `None` is the classic power/area sweep, byte-identical responses.
    pub snr: Option<SnrContext>,
}

/// `op: "shard"` payload — the remote form of `cimdse sweep --shard i/N`:
/// the server runs [`crate::dse::ShardArtifact::compute`] over the
/// selector's index sub-range and streams the whole artifact back
/// (bit-hex payload, the exact document `--shard` writes to disk).
#[derive(Clone, Debug)]
pub struct ShardRequest {
    /// The full sweep grid the shard is planned over.
    pub spec: SweepSpec,
    /// Which `index/n_shards` sub-range to compute.
    pub selector: ShardSelector,
    /// Model override; `None` uses the server's default model.
    pub model: Option<AdcModel>,
    /// Compute-SNR objective context, iff the frame selected the
    /// `energy,area,snr` objective set (see [`SweepRequest::snr`]).
    pub snr: Option<SnrContext>,
}

/// `op: "accel"` payload.
#[derive(Clone, Debug)]
pub struct AccelRequest {
    /// Workload name resolved through [`crate::workload::zoo::by_name`].
    pub workload: String,
    /// The architecture knob grid (defaults filled per axis).
    pub spec: AccelSweepSpec,
    /// Model override; `None` uses the server's default model.
    pub model: Option<AdcModel>,
}

/// Encode one response float per the frame's convention. Non-finite
/// values are always bit-hex regardless of `bits`: JSON has no
/// inf/NaN literal, and degrading a valid request's response over an
/// overflowed metric (e.g. an extreme client-supplied model) would
/// cost the client its `id` echo.
pub fn fnum(x: f64, bits: bool) -> Value {
    if bits || !x.is_finite() { Value::String(f64_to_bits_hex(x)) } else { Value::Number(x) }
}

/// Decode a request float: JSON number or 16-hex-digit bit pattern.
pub fn flex_f64(v: &Value, what: &str) -> Result<f64, Reject> {
    match v {
        Value::Number(n) => Ok(*n),
        Value::String(s) => f64_from_bits_hex(s)
            .map_err(|_| Reject::bad(format!("`{what}` is not a number or f64 bit-hex string"))),
        _ => Err(Reject::bad(format!("`{what}` is not a number or f64 bit-hex string"))),
    }
}

fn flex_field(table: &Value, key: &str) -> Result<Option<f64>, Reject> {
    match table.get(key) {
        None | Some(Value::Null) => Ok(None),
        Some(v) => flex_f64(v, key).map(Some),
    }
}

fn require_flex(table: &Value, key: &str) -> Result<f64, Reject> {
    flex_field(table, key)?.ok_or_else(|| Reject::bad(format!("missing field `{key}`")))
}

/// The model payload as a [`Value`] (bit-hex floats — the same shape
/// shard artifacts embed, and what [`model_from_value`] parses).
pub fn model_to_value(model: &AdcModel) -> Value {
    shard::model_to_value(model)
}

/// Parse a model payload — a thin wrapper over the one canonical
/// parser ([`shard::model_from_value`], the same code that loads shard
/// artifacts), so the wire and artifact model shapes can never drift.
/// Model floats therefore travel as bit-hex strings (the
/// [`model_to_value`] shape) — the only encoding that transmits the
/// exact bits the fingerprint is computed over.
pub fn model_from_value(v: &Value) -> Result<AdcModel, Reject> {
    shard::model_from_value(v).map_err(|e| Reject::bad(e.to_string()))
}

/// Encode a metric record per the frame's float convention. Field
/// names and order come from the one canonical list shard artifacts
/// use ([`shard::METRIC_NAMES`] / `metric_values`), so the wire and
/// artifact metric shapes cannot drift.
pub fn metrics_to_value(m: &AdcMetrics, bits: bool) -> Value {
    let mut map = BTreeMap::new();
    for (name, val) in shard::METRIC_NAMES.iter().zip(shard::metric_values(m)) {
        map.insert(name.to_string(), fnum(val, bits));
    }
    Value::Table(map)
}

/// Decode a metric record (numbers or bit-hex).
pub fn metrics_from_value(v: &Value) -> Result<AdcMetrics, Reject> {
    Ok(AdcMetrics {
        energy_pj_per_convert: require_flex(v, shard::METRIC_NAMES[0])?,
        area_um2_per_adc: require_flex(v, shard::METRIC_NAMES[1])?,
        total_power_w: require_flex(v, shard::METRIC_NAMES[2])?,
        total_area_um2: require_flex(v, shard::METRIC_NAMES[3])?,
    })
}

/// Encode a query echo (plain numbers for humans; [`fnum`] falls back
/// to bit-hex for non-finite fields — `validate` bounds every field
/// except `total_throughput`, which admits +inf — so the echo can
/// never make a response unserializable).
pub fn query_to_value(q: &AdcQuery) -> Value {
    let mut map = BTreeMap::new();
    map.insert("enob".to_string(), fnum(q.enob, false));
    map.insert("total_throughput".to_string(), fnum(q.total_throughput, false));
    map.insert("tech_nm".to_string(), fnum(q.tech_nm, false));
    map.insert("n_adcs".to_string(), Value::Number(q.n_adcs as f64));
    Value::Table(map)
}

fn query_from_value(v: &Value) -> Result<AdcQuery, Reject> {
    if !matches!(v, Value::Table(_)) {
        return Err(Reject::bad("query must be a JSON object"));
    }
    let n_adcs = match v.get("n_adcs") {
        None | Some(Value::Null) => 1u32,
        Some(n) => n
            .as_usize()
            .filter(|&n| n >= 1 && n <= u32::MAX as usize)
            .ok_or_else(|| Reject::bad("`n_adcs` is not a positive u32 integer"))?
            as u32,
    };
    let q = AdcQuery {
        enob: require_flex(v, "enob")?,
        total_throughput: require_flex(v, "total_throughput")?,
        tech_nm: flex_field(v, "tech_nm")?.unwrap_or(32.0),
        n_adcs,
    };
    q.validate().map_err(|e| Reject::bad(e.to_string()))?;
    Ok(q)
}

fn model_field(v: &Value) -> Result<Option<AdcModel>, Reject> {
    match v.get("model") {
        None | Some(Value::Null) => Ok(None),
        Some(m) => model_from_value(m).map(Some),
    }
}

/// The optional `objectives` / `snr` fields of a `sweep` or `shard`
/// frame, reduced to the server-side representation: `None` for the
/// classic `power,area` set (whether requested explicitly or by
/// omission — same bytes either way), `Some(context)` for
/// `energy,area,snr`. An `snr` context table is only legal alongside
/// the SNR objective set; the context defaults to
/// [`SnrContext::default`] (RAELLA-M) when the set is selected without
/// one. Unknown names, partial/reordered sets, non-string entries, and
/// malformed contexts are all [`CODE_BAD_REQUEST`] — no new error code.
fn objectives_field(v: &Value) -> Result<Option<SnrContext>, Reject> {
    let set = match v.get("objectives") {
        None | Some(Value::Null) => ObjectiveSet::PowerArea,
        Some(Value::Array(items)) => {
            let names = items
                .iter()
                .enumerate()
                .map(|(i, item)| {
                    item.as_str()
                        .ok_or_else(|| Reject::bad(format!("`objectives[{i}]` is not a string")))
                })
                .collect::<Result<Vec<&str>, Reject>>()?;
            ObjectiveSet::parse_names(&names).map_err(|e| Reject::bad(e.to_string()))?
        }
        Some(_) => {
            return Err(Reject::bad("`objectives` is not an array of objective names"));
        }
    };
    match (set, v.get("snr")) {
        (ObjectiveSet::PowerArea, None | Some(Value::Null)) => Ok(None),
        (ObjectiveSet::PowerArea, Some(_)) => Err(Reject::bad(
            "`snr` context is only valid with the `energy,area,snr` objective set",
        )),
        (ObjectiveSet::EnergyAreaSnr, None | Some(Value::Null)) => {
            Ok(Some(SnrContext::default()))
        }
        (ObjectiveSet::EnergyAreaSnr, Some(s)) => {
            SnrContext::from_value(s).map(Some).map_err(|e| Reject::bad(e.to_string()))
        }
    }
}

/// The scalar `id` of a frame, if it carries one (string or number;
/// anything else is ignored rather than rejected).
pub fn frame_id(v: &Value) -> Option<Value> {
    match v.get("id") {
        Some(id @ (Value::String(_) | Value::Number(_))) => Some(id.clone()),
        _ => None,
    }
}

/// The optional `trace` context of a request frame, validated.
///
/// Absent or null is the common untraced case (`Ok(None)`); otherwise
/// the field must be a table holding exactly `id` and `span`, each 16
/// lowercase hex digits, or the frame is rejected with
/// [`CODE_BAD_REQUEST`]. The validated table is echoed verbatim on
/// every frame the request produces (see the module docs).
pub fn frame_trace(v: &Value) -> Result<Option<Value>, Reject> {
    let t = match v.get("trace") {
        None | Some(Value::Null) => return Ok(None),
        Some(t) => t,
    };
    let Value::Table(map) = t else {
        return Err(Reject::bad("`trace` is not a table"));
    };
    if map.len() != 2 || !map.contains_key("id") || !map.contains_key("span") {
        return Err(Reject::bad("`trace` must hold exactly `id` and `span`"));
    }
    for key in ["id", "span"] {
        let ok = map
            .get(key)
            .and_then(Value::as_str)
            // lint:allow(determinism) — parse_hex16 is a pure string
            // validator; no obs clock or id source is reachable here.
            .and_then(crate::obs::parse_hex16)
            .is_some();
        if !ok {
            return Err(Reject::bad(format!(
                "`trace.{key}` is not 16 lowercase hex digits"
            )));
        }
    }
    Ok(Some(t.clone()))
}

/// Parse a decoded frame into a typed [`Request`].
///
/// The caller has already parsed the JSON; this validates shape and
/// semantics. Returns `(op_if_known, result)` so error frames can still
/// echo the op the client asked for.
pub fn parse_request(v: &Value) -> (Option<String>, Result<Request, Reject>) {
    if !matches!(v, Value::Table(_)) {
        return (
            None,
            Err(Reject::new(CODE_BAD_FRAME, "frame is not a JSON object")),
        );
    }
    let op = match v.get("op").and_then(Value::as_str) {
        Some(op) => op.to_string(),
        None => {
            return (
                None,
                Err(Reject::new(CODE_BAD_FRAME, "frame lacks a string `op` field")),
            );
        }
    };
    let parsed = match op.as_str() {
        "hello" => parse_hello(v),
        "eval" => parse_eval(v),
        "sweep" => parse_sweep(v),
        "shard" => parse_shard(v),
        "accel" => parse_accel(v),
        "metrics" => Ok(Request::Metrics),
        "cancel" => parse_cancel(v),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(Reject::new(
            CODE_UNKNOWN_OP,
            format!("unknown op `{other}` (hello|eval|sweep|shard|accel|metrics|cancel|shutdown)"),
        )),
    };
    (Some(op), parsed)
}

fn parse_hello(v: &Value) -> Result<Request, Reject> {
    let version = match v.get("version") {
        None | Some(Value::Null) => {
            return Err(Reject::bad("hello needs an integer `version` field"));
        }
        Some(x) => x
            .as_usize()
            .ok_or_else(|| Reject::bad("`version` is not a non-negative integer"))?,
    };
    if !(PROTOCOL_V1 as usize..=PROTOCOL_V2 as usize).contains(&version) {
        return Err(Reject::new(
            CODE_UNSUPPORTED_VERSION,
            format!(
                "protocol version {version} is not supported \
                 (this server speaks {PROTOCOL_V1}..={PROTOCOL_V2})"
            ),
        ));
    }
    Ok(Request::Hello(version as u32))
}

fn parse_cancel(v: &Value) -> Result<Request, Reject> {
    match v.get("target") {
        Some(t @ (Value::String(_) | Value::Number(_))) => Ok(Request::Cancel(t.clone())),
        None | Some(Value::Null) => {
            Err(Reject::bad("cancel needs a scalar `target` request id"))
        }
        Some(_) => Err(Reject::bad("`target` is not a scalar (string or number) request id")),
    }
}

fn parse_eval(v: &Value) -> Result<Request, Reject> {
    let queries = match (v.get("query"), v.get("queries")) {
        (Some(_), Some(_)) => {
            return Err(Reject::bad("give either `query` or `queries`, not both"));
        }
        (Some(q), None) => vec![query_from_value(q)?],
        (None, Some(Value::Array(items))) => {
            if items.is_empty() {
                return Err(Reject::bad("`queries` is empty"));
            }
            if items.len() > MAX_EVAL_BATCH {
                return Err(Reject::bad(format!(
                    "`queries` has {} entries (max {MAX_EVAL_BATCH}); use `sweep` for grids",
                    items.len()
                )));
            }
            items
                .iter()
                .map(query_from_value)
                .collect::<Result<Vec<_>, Reject>>()?
        }
        (None, Some(_)) => return Err(Reject::bad("`queries` is not an array")),
        (None, None) => return Err(Reject::bad("eval needs a `query` or `queries` field")),
    };
    let bits = match v.get("bits") {
        None | Some(Value::Null) => false,
        Some(b) => b.as_bool().ok_or_else(|| Reject::bad("`bits` is not a boolean"))?,
    };
    Ok(Request::Eval(EvalRequest { queries, model: model_field(v)?, bits }))
}

fn parse_sweep(v: &Value) -> Result<Request, Reject> {
    let spec_value = v
        .get("spec")
        .ok_or_else(|| Reject::bad("sweep needs an inline `spec` object"))?;
    let spec = SweepSpec::from_value(spec_value).map_err(|e| Reject::bad(e.to_string()))?;
    if spec.checked_len().is_none() {
        return Err(Reject::bad(
            "sweep grid length overflows usize; split the spec into sub-range specs",
        ));
    }
    Ok(Request::Sweep(SweepRequest {
        spec,
        model: model_field(v)?,
        snr: objectives_field(v)?,
    }))
}

fn parse_shard(v: &Value) -> Result<Request, Reject> {
    let spec_value = v
        .get("spec")
        .ok_or_else(|| Reject::bad("shard needs an inline `spec` object"))?;
    let spec = SweepSpec::from_value(spec_value).map_err(|e| Reject::bad(e.to_string()))?;
    let selector = match v.get("shard") {
        None | Some(Value::Null) => {
            return Err(Reject::bad(
                "shard needs a `shard` selector string of the form `index/n_shards`",
            ));
        }
        Some(Value::String(s)) => {
            ShardSelector::parse(s).map_err(|e| Reject::bad(e.to_string()))?
        }
        Some(_) => {
            return Err(Reject::bad("`shard` is not an `index/n_shards` selector string"));
        }
    };
    // Plan up front so grid problems (axis-product overflow, > 2^53
    // points) are typed rejections here, not dispatch-time surprises.
    ShardPlan::new(&spec, selector.n_shards()).map_err(|e| Reject::bad(e.to_string()))?;
    Ok(Request::Shard(ShardRequest {
        spec,
        selector,
        model: model_field(v)?,
        snr: objectives_field(v)?,
    }))
}

fn parse_accel(v: &Value) -> Result<Request, Reject> {
    let workload = match v.get("workload") {
        None | Some(Value::Null) => "resnet18".to_string(),
        Some(w) => w
            .as_str()
            .ok_or_else(|| Reject::bad("`workload` is not a string"))?
            .to_string(),
    };
    let mut spec = AccelSweepSpec::default();
    if let Some(xs) = v.get("sum_sizes") {
        spec.sum_sizes = usize_axis(xs, "sum_sizes")?;
    }
    if let Some(xs) = v.get("enobs") {
        spec.enobs = f64_axis(xs, "enobs")?;
    }
    if let Some(xs) = v.get("n_adcs") {
        spec.n_adcs = usize_axis(xs, "n_adcs")?
            .into_iter()
            .map(|n| {
                u32::try_from(n).map_err(|_| Reject::bad("`n_adcs` entry exceeds u32"))
            })
            .collect::<Result<Vec<u32>, Reject>>()?;
    }
    if let Some(xs) = v.get("total_throughputs") {
        spec.total_throughputs = f64_axis(xs, "total_throughputs")?;
    }
    if let Some(x) = flex_field(v, "max_clipped_bits")? {
        spec.max_clipped_bits = x;
    }
    Ok(Request::Accel(AccelRequest { workload, spec, model: model_field(v)? }))
}

fn f64_axis(v: &Value, what: &str) -> Result<Vec<f64>, Reject> {
    v.as_array()
        .ok_or_else(|| Reject::bad(format!("`{what}` is not an array")))?
        .iter()
        .enumerate()
        .map(|(i, item)| flex_f64(item, &format!("{what}[{i}]")))
        .collect()
}

fn usize_axis(v: &Value, what: &str) -> Result<Vec<usize>, Reject> {
    v.as_array()
        .ok_or_else(|| Reject::bad(format!("`{what}` is not an array")))?
        .iter()
        .enumerate()
        .map(|(i, item)| {
            item.as_usize()
                .ok_or_else(|| Reject::bad(format!("`{what}[{i}]` is not a non-negative integer")))
        })
        .collect()
}

/// Serialize a success frame (one line, no trailing newline).
pub fn ok_frame(op: &str, id: Option<&Value>, result: Value) -> String {
    ok_frame_traced(op, id, None, result)
}

/// [`ok_frame`] with a validated `trace` table to echo. `None` emits a
/// frame byte-identical to the untraced builder (the key is never
/// inserted, not inserted-as-null).
pub fn ok_frame_traced(
    op: &str,
    id: Option<&Value>,
    trace: Option<&Value>,
    result: Value,
) -> String {
    let mut map = BTreeMap::new();
    map.insert("ok".to_string(), Value::Bool(true));
    map.insert("op".to_string(), Value::String(op.to_string()));
    if let Some(id) = id {
        map.insert("id".to_string(), id.clone());
    }
    if let Some(trace) = trace {
        map.insert("trace".to_string(), trace.clone());
    }
    map.insert("result".to_string(), result);
    frame_text(Value::Table(map))
}

/// Serialize a typed error frame (one line, no trailing newline).
pub fn error_frame(op: Option<&str>, id: Option<&Value>, reject: &Reject) -> String {
    error_frame_traced(op, id, None, reject)
}

/// [`error_frame`] with a validated `trace` table to echo (rejected
/// requests that *carried* a valid trace still echo it; an invalid
/// trace itself is rejected without one).
pub fn error_frame_traced(
    op: Option<&str>,
    id: Option<&Value>,
    trace: Option<&Value>,
    reject: &Reject,
) -> String {
    let mut err = BTreeMap::new();
    err.insert("code".to_string(), Value::String(reject.code.to_string()));
    err.insert("message".to_string(), Value::String(reject.message.clone()));
    let mut map = BTreeMap::new();
    map.insert("ok".to_string(), Value::Bool(false));
    if let Some(op) = op {
        map.insert("op".to_string(), Value::String(op.to_string()));
    }
    if let Some(id) = id {
        map.insert("id".to_string(), id.clone());
    }
    if let Some(trace) = trace {
        map.insert("trace".to_string(), trace.clone());
    }
    map.insert("error".to_string(), Value::Table(err));
    frame_text(Value::Table(map))
}

/// The `hello` result payload for a freshly negotiated version: the
/// version the connection will speak from now on plus the static frame
/// cap (so clients can size requests without a probe).
pub fn hello_result(version: u32) -> Value {
    let mut map = BTreeMap::new();
    map.insert("version".to_string(), Value::Number(version as f64));
    map.insert("max_frame_bytes".to_string(), Value::Number(MAX_FRAME_BYTES as f64));
    Value::Table(map)
}

/// Serialize a v2 `progress` interim frame (one line, no trailing
/// newline): `done` of `total` points of the identified request have
/// been folded. Interim frames carry a `"frame"` discriminator and no
/// `"ok"` key, so they can never be mistaken for a final response.
/// Only v2-negotiated connections ever receive one.
pub fn progress_frame(op: &str, id: Option<&Value>, done: usize, total: usize) -> String {
    progress_frame_traced(op, id, None, done, total)
}

/// [`progress_frame`] with a validated `trace` table to echo, so a
/// traced request's interim frames correlate like its final one.
pub fn progress_frame_traced(
    op: &str,
    id: Option<&Value>,
    trace: Option<&Value>,
    done: usize,
    total: usize,
) -> String {
    let mut map = BTreeMap::new();
    map.insert("frame".to_string(), Value::String("progress".to_string()));
    map.insert("op".to_string(), Value::String(op.to_string()));
    if let Some(id) = id {
        map.insert("id".to_string(), id.clone());
    }
    if let Some(trace) = trace {
        map.insert("trace".to_string(), trace.clone());
    }
    map.insert("done".to_string(), Value::Number(done as f64));
    map.insert("total".to_string(), Value::Number(total as f64));
    frame_text(Value::Table(map))
}

/// Serialize a v2 `keepalive` interim frame: a bare liveness pulse sent
/// while a request computes but no progress boundary has been crossed.
/// Only v2-negotiated connections ever receive one.
pub fn keepalive_frame() -> String {
    let mut map = BTreeMap::new();
    map.insert("frame".to_string(), Value::String("keepalive".to_string()));
    frame_text(Value::Table(map))
}

/// Is this decoded line a v2 interim frame (`progress`/`keepalive`)
/// rather than a final response? Clients awaiting a response skip
/// interim frames (each one proves the server is alive and re-arms
/// read-timeout liveness); v1 code never sees one.
pub fn is_interim_frame(v: &Value) -> bool {
    matches!(v.get("frame"), Some(Value::String(_)))
}

/// Canonical single-line text of a frame. Serialization of a response
/// is total in practice (strings escape `\n`, response floats are
/// bit-hex whenever non-finite); if it ever fails anyway, degrade to a
/// minimal internal-error frame — built through the [`Value`] layer,
/// whose string escaping is total, so even the fallback is valid JSON —
/// rather than panicking the connection thread.
fn frame_text(v: Value) -> String {
    v.to_json_string().unwrap_or_else(|e| {
        let mut err = BTreeMap::new();
        err.insert("code".to_string(), Value::String(CODE_INTERNAL.to_string()));
        err.insert(
            "message".to_string(),
            Value::String(format!("response serialization failed: {e}")),
        );
        let mut map = BTreeMap::new();
        map.insert("ok".to_string(), Value::Bool(false));
        map.insert("error".to_string(), Value::Table(err));
        Value::Table(map)
            .to_json_string()
            .expect("bool/string-only frame always serializes")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_json;

    fn req(text: &str) -> (Option<String>, Result<Request, Reject>) {
        parse_request(&parse_json(text).unwrap())
    }

    #[test]
    fn eval_single_query_with_defaults() {
        let (op, r) = req(r#"{"op": "eval", "query": {"enob": 7, "total_throughput": 1.3e9}}"#);
        assert_eq!(op.as_deref(), Some("eval"));
        match r.unwrap() {
            Request::Eval(e) => {
                assert_eq!(e.queries.len(), 1);
                assert_eq!(e.queries[0].tech_nm, 32.0);
                assert_eq!(e.queries[0].n_adcs, 1);
                assert!(!e.bits && e.model.is_none());
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn eval_accepts_bit_hex_floats() {
        let hex = f64_to_bits_hex(7.0);
        let text = format!(
            r#"{{"op": "eval", "bits": true, "query": {{"enob": "{hex}", "total_throughput": 1e9}}}}"#
        );
        match req(&text).1.unwrap() {
            Request::Eval(e) => {
                assert_eq!(e.queries[0].enob.to_bits(), 7.0f64.to_bits());
                assert!(e.bits);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn eval_rejections_are_typed() {
        for (text, needle) in [
            (r#"{"op": "eval"}"#, "query"),
            (r#"{"op": "eval", "queries": []}"#, "empty"),
            (r#"{"op": "eval", "query": {"enob": 7}}"#, "total_throughput"),
            (r#"{"op": "eval", "query": {"enob": -1, "total_throughput": 1e9}}"#, "ENOB"),
            (
                r#"{"op": "eval", "query": {"enob": 7, "total_throughput": 1e9, "n_adcs": 0}}"#,
                "n_adcs",
            ),
            (
                r#"{"op": "eval", "query": {"enob": 7, "total_throughput": 1e9}, "queries": []}"#,
                "not both",
            ),
            (r#"{"op": "eval", "query": {"enob": 7, "total_throughput": 1e9}, "bits": 3}"#, "bits"),
        ] {
            let (_, r) = req(text);
            let e = r.expect_err(text);
            assert_eq!(e.code, CODE_BAD_REQUEST, "{text}");
            assert!(e.message.contains(needle), "{text}: {}", e.message);
        }
    }

    #[test]
    fn frame_level_rejections_have_stable_codes() {
        let (op, r) = parse_request(&Value::Array(vec![]));
        assert!(op.is_none());
        assert_eq!(r.unwrap_err().code, CODE_BAD_FRAME);
        let (op, r) = req(r#"{"no_op": 1}"#);
        assert!(op.is_none());
        assert_eq!(r.unwrap_err().code, CODE_BAD_FRAME);
        let (op, r) = req(r#"{"op": "divide"}"#);
        assert_eq!(op.as_deref(), Some("divide"));
        let e = r.unwrap_err();
        assert_eq!(e.code, CODE_UNKNOWN_OP);
        assert!(e.message.contains("divide"), "{}", e.message);
    }

    #[test]
    fn sweep_parses_inline_spec_and_rejects_bad_ones() {
        let (_, r) = req(
            r#"{"op": "sweep", "spec": {"enobs": [4, 8], "total_throughputs": [1e9],
                "tech_nms": [32], "n_adcs": [1, 2]}}"#,
        );
        match r.unwrap() {
            Request::Sweep(s) => assert_eq!(s.spec.len(), 4),
            other => panic!("wrong request: {other:?}"),
        }
        let (_, r) = req(r#"{"op": "sweep"}"#);
        assert_eq!(r.unwrap_err().code, CODE_BAD_REQUEST);
        let (_, r) = req(r#"{"op": "sweep", "spec": {"enobs": [4]}}"#);
        assert_eq!(r.unwrap_err().code, CODE_BAD_REQUEST);
    }

    #[test]
    fn shard_parses_selector_spec_and_model() {
        let (op, r) = req(
            r#"{"op": "shard", "shard": "1/3", "spec": {"enobs": [4, 8], "total_throughputs":
                [1e9], "tech_nms": [32], "n_adcs": [1, 2]}}"#,
        );
        assert_eq!(op.as_deref(), Some("shard"));
        match r.unwrap() {
            Request::Shard(s) => {
                assert_eq!((s.selector.index(), s.selector.n_shards()), (1, 3));
                assert_eq!(s.spec.len(), 4);
                assert!(s.model.is_none());
            }
            other => panic!("wrong request: {other:?}"),
        }
        // An explicit model rides along in the canonical bit-hex shape.
        let model = AdcModel { area_offset_decades: 0.5, ..AdcModel::default() };
        let frame = format!(
            r#"{{"op": "shard", "shard": "0/1", "spec": {{"enobs": [4], "total_throughputs":
                [1e9], "tech_nms": [32], "n_adcs": [1]}}, "model": {}}}"#,
            model_to_value(&model).to_json_string().unwrap()
        );
        match req(&frame).1.unwrap() {
            Request::Shard(s) => {
                let got = s.model.expect("model field parses");
                assert_eq!(
                    crate::dse::model_fingerprint(&got),
                    crate::dse::model_fingerprint(&model)
                );
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn shard_rejections_are_typed() {
        let spec = r#""spec": {"enobs": [4], "total_throughputs": [1e9], "tech_nms": [32],
            "n_adcs": [1]}"#;
        for (text, needle) in [
            (format!(r#"{{"op": "shard", {spec}}}"#), "selector"),
            (r#"{"op": "shard", "shard": "0/2"}"#.to_string(), "spec"),
            (format!(r#"{{"op": "shard", "shard": 3, {spec}}}"#), "selector"),
            (format!(r#"{{"op": "shard", "shard": "junk", {spec}}}"#), "junk"),
            (format!(r#"{{"op": "shard", "shard": "0/0", {spec}}}"#), "shard count"),
            (format!(r#"{{"op": "shard", "shard": "3/2", {spec}}}"#), "out of range"),
            (
                format!(r#"{{"op": "shard", "shard": "0/2", {spec}, "model": {{"coefs": [1]}}}}"#),
                "11",
            ),
            (
                r#"{"op": "shard", "shard": "0/2", "spec": {"enobs": [4]}}"#.to_string(),
                "n_adcs",
            ),
        ] {
            let (op, r) = req(&text);
            assert_eq!(op.as_deref(), Some("shard"), "{text}");
            let e = r.expect_err(&text);
            assert_eq!(e.code, CODE_BAD_REQUEST, "{text}");
            assert!(e.message.contains(needle), "{text}: {}", e.message);
        }
    }

    #[test]
    fn objectives_select_the_snr_context_or_reject() {
        let spec = r#""spec": {"enobs": [4, 8], "total_throughputs": [1e9], "tech_nms": [32],
            "n_adcs": [1, 2]}"#;
        // Absent objectives and the explicit classic set are the same
        // classic request (no snr context).
        for text in [
            format!(r#"{{"op": "sweep", {spec}}}"#),
            format!(r#"{{"op": "sweep", {spec}, "objectives": ["power", "area"]}}"#),
            format!(r#"{{"op": "sweep", {spec}, "objectives": null}}"#),
        ] {
            match req(&text).1.unwrap() {
                Request::Sweep(s) => assert!(s.snr.is_none(), "{text}"),
                other => panic!("wrong request: {other:?}"),
            }
        }
        // The tri set without a context defaults to RAELLA-M.
        let text = format!(r#"{{"op": "sweep", {spec}, "objectives": ["energy", "area", "snr"]}}"#);
        match req(&text).1.unwrap() {
            Request::Sweep(s) => assert_eq!(s.snr, Some(SnrContext::default())),
            other => panic!("wrong request: {other:?}"),
        }
        // An explicit context rides along, on shard frames too.
        let text = format!(
            r#"{{"op": "shard", "shard": "1/2", {spec}, "objectives": ["energy", "area", "snr"],
                "snr": {{"n_sum": 2048, "cell_bits": 3}}}}"#
        );
        match req(&text).1.unwrap() {
            Request::Shard(s) => {
                assert_eq!(s.snr, Some(SnrContext { n_sum: 2048, cell_bits: 3 }));
            }
            other => panic!("wrong request: {other:?}"),
        }
        for (text, needle) in [
            (format!(r#"{{"op": "sweep", {spec}, "objectives": "snr"}}"#), "not an array"),
            (format!(r#"{{"op": "sweep", {spec}, "objectives": [7]}}"#), "objectives[0]"),
            (
                format!(r#"{{"op": "sweep", {spec}, "objectives": ["energy", "snr"]}}"#),
                "unsupported objective set",
            ),
            (
                format!(r#"{{"op": "sweep", {spec}, "objectives": ["snr", "area", "energy"]}}"#),
                "unsupported objective set",
            ),
            (
                format!(r#"{{"op": "sweep", {spec}, "snr": {{"n_sum": 512, "cell_bits": 2}}}}"#),
                "only valid with",
            ),
            (
                format!(
                    r#"{{"op": "shard", "shard": "0/2", {spec},
                        "objectives": ["power", "area"], "snr": {{"n_sum": 512, "cell_bits": 2}}}}"#
                ),
                "only valid with",
            ),
            (
                format!(
                    r#"{{"op": "sweep", {spec}, "objectives": ["energy", "area", "snr"],
                        "snr": {{"n_sum": 0, "cell_bits": 2}}}}"#
                ),
                "n_sum",
            ),
            (
                format!(
                    r#"{{"op": "sweep", {spec}, "objectives": ["energy", "area", "snr"],
                        "snr": {{"n_sum": 512, "cell_bits": 2, "extra": 1}}}}"#
                ),
                "unknown key",
            ),
            (
                format!(
                    r#"{{"op": "sweep", {spec}, "objectives": ["energy", "area", "snr"],
                        "snr": [512, 2]}}"#
                ),
                "not a table",
            ),
        ] {
            let (_, r) = req(&text);
            let e = r.expect_err(&text);
            assert_eq!(e.code, CODE_BAD_REQUEST, "{text}");
            assert!(e.message.contains(needle), "{text}: {}", e.message);
        }
    }

    #[test]
    fn accel_defaults_and_overrides() {
        let (_, r) = req(r#"{"op": "accel"}"#);
        match r.unwrap() {
            Request::Accel(a) => {
                assert_eq!(a.workload, "resnet18");
                assert_eq!(a.spec.sum_sizes, AccelSweepSpec::default().sum_sizes);
            }
            other => panic!("wrong request: {other:?}"),
        }
        let (_, r) = req(
            r#"{"op": "accel", "workload": "lenet", "sum_sizes": [128, 512],
                "enobs": [6, 8], "n_adcs": [2], "max_clipped_bits": 4.5}"#,
        );
        match r.unwrap() {
            Request::Accel(a) => {
                assert_eq!(a.workload, "lenet");
                assert_eq!(a.spec.sum_sizes, vec![128, 512]);
                assert_eq!(a.spec.n_adcs, vec![2]);
                assert_eq!(a.spec.max_clipped_bits, 4.5);
            }
            other => panic!("wrong request: {other:?}"),
        }
        let (_, r) = req(r#"{"op": "accel", "sum_sizes": [-1]}"#);
        assert_eq!(r.unwrap_err().code, CODE_BAD_REQUEST);
    }

    #[test]
    fn model_roundtrips_and_fingerprint_survives_the_wire() {
        let model = AdcModel { energy_offset_decades: 0.25, ..AdcModel::default() };
        let back = model_from_value(&model_to_value(&model)).unwrap();
        assert_eq!(
            crate::dse::model_fingerprint(&back),
            crate::dse::model_fingerprint(&model)
        );
        let e = model_from_value(&parse_json(r#"{"coefs": [1, 2]}"#).unwrap()).unwrap_err();
        assert!(e.message.contains("11"), "{}", e.message);
    }

    #[test]
    fn frames_are_single_lines_and_echo_ids() {
        let id = Value::Number(7.0);
        let ok = ok_frame("eval", Some(&id), Value::Table(BTreeMap::new()));
        assert!(!ok.contains('\n'), "{ok}");
        let doc = parse_json(&ok).unwrap();
        assert_eq!(doc.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(doc.get("id").and_then(Value::as_f64), Some(7.0));
        assert_eq!(doc.require_str("op").unwrap(), "eval");

        let err = error_frame(None, None, &Reject::new(CODE_UNKNOWN_OP, "nope\nnl"));
        assert!(!err.contains('\n'), "{err}");
        let doc = parse_json(&err).unwrap();
        assert_eq!(doc.get("ok").and_then(Value::as_bool), Some(false));
        assert_eq!(doc.require_str("error.code").unwrap(), CODE_UNKNOWN_OP);

        // id echo only for scalar ids.
        let frame = parse_json(r#"{"op": "metrics", "id": "abc"}"#).unwrap();
        assert_eq!(frame_id(&frame), Some(Value::String("abc".into())));
        let frame = parse_json(r#"{"op": "metrics", "id": [1]}"#).unwrap();
        assert_eq!(frame_id(&frame), None);
    }

    #[test]
    fn hello_negotiates_supported_versions_and_rejects_others() {
        for v in [1usize, 2] {
            match req(&format!(r#"{{"op": "hello", "version": {v}}}"#)).1.unwrap() {
                Request::Hello(got) => assert_eq!(got as usize, v),
                other => panic!("wrong request: {other:?}"),
            }
        }
        for (text, code, needle) in [
            (r#"{"op": "hello"}"#, CODE_BAD_REQUEST, "version"),
            (r#"{"op": "hello", "version": null}"#, CODE_BAD_REQUEST, "version"),
            (r#"{"op": "hello", "version": "2"}"#, CODE_BAD_REQUEST, "integer"),
            (r#"{"op": "hello", "version": 2.5}"#, CODE_BAD_REQUEST, "integer"),
            (r#"{"op": "hello", "version": 0}"#, CODE_UNSUPPORTED_VERSION, "not supported"),
            (r#"{"op": "hello", "version": 3}"#, CODE_UNSUPPORTED_VERSION, "1..=2"),
        ] {
            let (op, r) = req(text);
            assert_eq!(op.as_deref(), Some("hello"), "{text}");
            let e = r.expect_err(text);
            assert_eq!(e.code, code, "{text}");
            assert!(e.message.contains(needle), "{text}: {}", e.message);
        }
        let result = hello_result(PROTOCOL_V2);
        assert_eq!(result.get("version").and_then(Value::as_usize), Some(2));
        assert_eq!(
            result.get("max_frame_bytes").and_then(Value::as_usize),
            Some(MAX_FRAME_BYTES)
        );
    }

    #[test]
    fn cancel_parses_scalar_targets_and_rejects_others() {
        match req(r#"{"op": "cancel", "target": 7}"#).1.unwrap() {
            Request::Cancel(t) => assert_eq!(t.as_f64(), Some(7.0)),
            other => panic!("wrong request: {other:?}"),
        }
        match req(r#"{"op": "cancel", "target": "job-1", "id": 9}"#).1.unwrap() {
            Request::Cancel(t) => assert_eq!(t.as_str(), Some("job-1")),
            other => panic!("wrong request: {other:?}"),
        }
        for (text, needle) in [
            (r#"{"op": "cancel"}"#, "target"),
            (r#"{"op": "cancel", "target": null}"#, "target"),
            (r#"{"op": "cancel", "target": [1]}"#, "scalar"),
            (r#"{"op": "cancel", "target": {"id": 1}}"#, "scalar"),
        ] {
            let (_, r) = req(text);
            let e = r.expect_err(text);
            assert_eq!(e.code, CODE_BAD_REQUEST, "{text}");
            assert!(e.message.contains(needle), "{text}: {}", e.message);
        }
    }

    #[test]
    fn interim_frames_are_single_lines_and_discriminated() {
        let id = Value::String("s1".into());
        let p = progress_frame("sweep", Some(&id), 2048, 81920);
        assert!(!p.contains('\n'), "{p}");
        let doc = parse_json(&p).unwrap();
        assert!(is_interim_frame(&doc), "{p}");
        assert_eq!(doc.require_str("frame").unwrap(), "progress");
        assert_eq!(doc.require_str("op").unwrap(), "sweep");
        assert_eq!(doc.require_str("id").unwrap(), "s1");
        assert_eq!(doc.get("done").and_then(Value::as_usize), Some(2048));
        assert_eq!(doc.get("total").and_then(Value::as_usize), Some(81920));
        assert!(doc.get("ok").is_none(), "interim frames carry no `ok` key: {p}");

        let k = keepalive_frame();
        let doc = parse_json(&k).unwrap();
        assert!(is_interim_frame(&doc), "{k}");
        assert_eq!(doc.require_str("frame").unwrap(), "keepalive");

        // Final responses are never mistaken for interim frames.
        let ok = ok_frame("eval", None, Value::Table(BTreeMap::new()));
        assert!(!is_interim_frame(&parse_json(&ok).unwrap()));
        let err = error_frame(Some("sweep"), None, &Reject::new(CODE_CANCELLED, "x"));
        assert!(!is_interim_frame(&parse_json(&err).unwrap()));
    }

    #[test]
    fn trace_field_is_validated_and_optional() {
        // Absent and null are the untraced case.
        for text in [r#"{"op": "metrics"}"#, r#"{"op": "metrics", "trace": null}"#] {
            let v = parse_json(text).unwrap();
            assert_eq!(frame_trace(&v).unwrap(), None, "{text}");
        }
        // A well-formed context passes through verbatim.
        let good = r#"{"op": "metrics",
            "trace": {"id": "00000000deadbeef", "span": "0123456789abcdef"}}"#;
        let v = parse_json(good).unwrap();
        let t = frame_trace(&v).unwrap().expect("valid trace");
        assert_eq!(t.require_str("id").unwrap(), "00000000deadbeef");
        assert_eq!(t.require_str("span").unwrap(), "0123456789abcdef");
        // Everything else is a typed bad-request.
        for (text, needle) in [
            (r#"{"trace": "deadbeef"}"#, "not a table"),
            (r#"{"trace": [1]}"#, "not a table"),
            (r#"{"trace": {"id": "00000000deadbeef"}}"#, "exactly"),
            (
                r#"{"trace": {"id": "00000000deadbeef", "span": "0123456789abcdef", "x": 1}}"#,
                "exactly",
            ),
            (r#"{"trace": {"id": "deadbeef", "span": "0123456789abcdef"}}"#, "trace.id"),
            (
                r#"{"trace": {"id": "00000000DEADBEEF", "span": "0123456789abcdef"}}"#,
                "lowercase hex",
            ),
            (r#"{"trace": {"id": "00000000deadbeef", "span": 7}}"#, "trace.span"),
        ] {
            let v = parse_json(text).unwrap();
            let e = frame_trace(&v).expect_err(text);
            assert_eq!(e.code, CODE_BAD_REQUEST, "{text}");
            assert!(e.message.contains(needle), "{text}: {}", e.message);
        }
    }

    #[test]
    fn traced_builders_echo_and_untraced_are_byte_identical() {
        let id = Value::Number(3.0);
        let trace = parse_json(r#"{"id": "00000000deadbeef", "span": "0123456789abcdef"}"#).unwrap();
        // With no trace, the traced builders emit the exact same bytes
        // as the plain ones (the key is never inserted).
        assert_eq!(
            ok_frame("eval", Some(&id), Value::Table(BTreeMap::new())),
            ok_frame_traced("eval", Some(&id), None, Value::Table(BTreeMap::new()))
        );
        let rej = Reject::bad("nope");
        assert_eq!(
            error_frame(Some("eval"), Some(&id), &rej),
            error_frame_traced(Some("eval"), Some(&id), None, &rej)
        );
        assert_eq!(
            progress_frame("sweep", Some(&id), 1, 10),
            progress_frame_traced("sweep", Some(&id), None, 1, 10)
        );
        // With a trace, every frame kind echoes the table verbatim.
        for line in [
            ok_frame_traced("eval", Some(&id), Some(&trace), Value::Table(BTreeMap::new())),
            error_frame_traced(Some("eval"), Some(&id), Some(&trace), &rej),
            progress_frame_traced("sweep", Some(&id), Some(&trace), 1, 10),
        ] {
            assert!(!line.contains('\n'), "{line}");
            let doc = parse_json(&line).unwrap();
            assert_eq!(doc.require_str("trace.id").unwrap(), "00000000deadbeef", "{line}");
            assert_eq!(doc.require_str("trace.span").unwrap(), "0123456789abcdef", "{line}");
        }
    }

    #[test]
    fn metrics_value_roundtrip_both_conventions() {
        let m = AdcMetrics {
            energy_pj_per_convert: 3.3,
            area_um2_per_adc: 5e4,
            total_power_w: 1.2e-3,
            total_area_um2: 4e5,
        };
        for bits in [false, true] {
            let v = metrics_to_value(&m, bits);
            let text = v.to_json_string().unwrap();
            let back = metrics_from_value(&parse_json(&text).unwrap()).unwrap();
            assert_eq!(back.to_bits(), m.to_bits(), "bits={bits}");
        }
    }
}
