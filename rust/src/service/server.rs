//! The serving daemon: connection handling and request dispatch onto
//! the shared persistent [`crate::exec::Pool`], in two selectable
//! cores (see [`ServeCore`]):
//!
//! * **Event loop** (the default): one reactor thread multiplexes
//!   every connection over `epoll`/`poll` readiness
//!   ([`super::reactor`]), with a small runner pool bridging compute
//!   jobs to the shared pool. Scales to hundreds of connections,
//!   speaks protocol v2 (`hello`/`progress`/`keepalive`/`cancel`), and
//!   bounds per-connection memory through write-queue backpressure.
//! * **Threads** (the original core, kept for byte-identity testing
//!   and the `bench_serve` comparison): one reader thread per
//!   connection. `eval` answers on the connection thread (the work is
//!   tiny), while `sweep`/`shard`/`accel` route through the
//!   process-wide [`crate::exec::Pool::global`] — concurrent sweeps
//!   queue on the pool's broadcast slot first-come first-served, so
//!   the daemon never oversubscribes the machine.
//!
//! Both cores funnel every frame through the same parse
//! ([`parse_or_reply`]) and dispatch ([`dispatch`]) functions, so every
//! v1 frame is answered byte-identically regardless of core — the
//! property `tests/async_core.rs` pins over real sockets.
//!
//! ## Shutdown
//!
//! A `shutdown` frame answers, then flips the shared drain flag. The
//! accept path stops accepting; in-flight requests always finish
//! computing; pipelined-but-unprocessed frames are dropped. In the
//! threaded core, reader threads notice the flag at their next frame
//! boundary (both reads and writes time out every [`READ_TIMEOUT`], so
//! even a thread mid-write to a client that stopped reading re-checks
//! the flag and abandons the stalled connection). In the event-loop
//! core, the reactor additionally force-drops any connection whose
//! write queue stops making progress, so stuck clients delay drain by
//! a fixed grace period at most.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::adc::{AdcModel, PreparedModel};
use crate::config::{Value, parse_json};
use crate::dse::{FoldCtl, ShardArtifact, ShardPlan, SweepSummary, model_fingerprint};
use crate::error::{Error, Result};
use crate::exec::default_workers;

use super::cache::PreparedCache;
use super::conn::{FrameBuf, FrameEvent};
use super::metrics::ServiceMetrics;
use super::protocol::{
    AccelRequest, CODE_BAD_REQUEST, CODE_CANCELLED, CODE_INTERNAL, CODE_MALFORMED_JSON,
    CODE_OVER_BUDGET, CODE_OVERSIZED_FRAME, CODE_UNKNOWN_ID, EvalRequest, MAX_FRAME_BYTES,
    Reject, Request, ShardRequest, SweepRequest, error_frame, error_frame_traced, fnum, frame_id,
    frame_trace, hello_result, metrics_to_value, ok_frame, ok_frame_traced, parse_request,
};

/// Read timeout of connection sockets — the upper bound on how stale
/// the drain flag can go unnoticed by a blocked reader thread.
const READ_TIMEOUT: Duration = Duration::from_millis(100);

/// Poll interval of the nonblocking accept loop (bounds connect
/// latency and drain-flag staleness for the acceptor).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Which serving core [`Server::serve`] runs. Both answer every v1
/// frame byte-identically (they share parse and dispatch); only the
/// event loop speaks protocol v2.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ServeCore {
    /// Readiness-driven event loop ([`super::reactor`]): the default.
    /// Scales to hundreds of connections, supports v2
    /// progress/keepalive/cancel frames and write-queue backpressure.
    /// (Off unix targets this falls back to [`ServeCore::Threads`].)
    #[default]
    EventLoop,
    /// One reader thread per connection — the original core, kept for
    /// cross-core byte-identity tests and `bench_serve` comparisons.
    Threads,
}

impl std::str::FromStr for ServeCore {
    type Err = Error;

    fn from_str(s: &str) -> Result<ServeCore> {
        match s {
            "event-loop" => Ok(ServeCore::EventLoop),
            "threads" => Ok(ServeCore::Threads),
            other => Err(Error::Parse(format!(
                "unknown serve core `{other}` (expected `event-loop` or `threads`)"
            ))),
        }
    }
}

/// Configuration for [`Server::bind`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// The default model used by requests that carry no `model` field.
    pub model: AdcModel,
    /// Prepared-model cache capacity.
    pub cache_capacity: usize,
    /// Worker hint for sweep/accel evaluation (`1` = serial; anything
    /// else routes through the shared pool, whose fixed width governs
    /// actual parallelism).
    pub workers: usize,
    /// Per-request evaluation budget (`cimdse serve --max-sweep-points`):
    /// a `sweep` whose grid, or a `shard` whose index sub-range, exceeds
    /// this many points is answered with a typed
    /// [`CODE_OVER_BUDGET`] error frame before any evaluation happens.
    /// `None` accepts any size (the trusted-operator default).
    pub max_sweep_points: Option<usize>,
    /// Which serving core to run.
    pub core: ServeCore,
    /// Emit a v2 `progress` frame roughly every this many completed
    /// grid points of an in-flight `sweep`/`shard` (`cimdse serve
    /// --progress-every`). `None` disables progress frames; `keepalive`
    /// frames flow to v2 connections either way. Only the event-loop
    /// core emits interim frames.
    pub progress_every: Option<usize>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:0".to_string(),
            model: AdcModel::default(),
            cache_capacity: 32,
            workers: default_workers(),
            max_sweep_points: None,
            core: ServeCore::default(),
            progress_every: None,
        }
    }
}

pub(super) struct ServerShared {
    pub(super) default_model: AdcModel,
    pub(super) default_fingerprint: String,
    pub(super) workers: usize,
    pub(super) max_sweep_points: Option<usize>,
    pub(super) progress_every: Option<usize>,
    pub(super) cache: std::sync::Mutex<PreparedCache>,
    pub(super) metrics: ServiceMetrics,
    pub(super) shutdown: AtomicBool,
}

/// A bound (but not yet serving) daemon. [`Server::serve`] consumes it
/// and blocks until a graceful shutdown completes.
pub struct Server {
    listener: TcpListener,
    local_addr: SocketAddr,
    core: ServeCore,
    shared: Arc<ServerShared>,
}

/// A cloneable handle for triggering shutdown from another thread
/// (tests, signal handlers) without a socket round-trip.
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<ServerShared>,
}

impl ServerHandle {
    /// Flip the drain flag; the server finishes in-flight work and
    /// [`Server::serve`] returns.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Whether drain has been requested.
    pub fn is_shutting_down(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }
}

impl Server {
    /// Bind the listener and precompute the default model fingerprint.
    pub fn bind(options: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(&options.addr).map_err(|e| {
            Error::Runtime(format!("serve: cannot bind {}: {e}", options.addr))
        })?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Runtime(format!("serve: set_nonblocking: {e}")))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| Error::Runtime(format!("serve: local_addr: {e}")))?;
        let shared = Arc::new(ServerShared {
            default_fingerprint: model_fingerprint(&options.model),
            default_model: options.model,
            workers: options.workers.max(1),
            max_sweep_points: options.max_sweep_points,
            progress_every: options.progress_every,
            cache: std::sync::Mutex::new(PreparedCache::new(options.cache_capacity)),
            metrics: ServiceMetrics::new(),
            shutdown: AtomicBool::new(false),
        });
        Ok(Server { listener, local_addr, core: options.core, shared })
    }

    /// The address actually bound (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// A shutdown handle usable from other threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared) }
    }

    /// Accept connections until a shutdown is requested, then drain
    /// (letting in-flight requests finish) and return.
    pub fn serve(self) -> Result<()> {
        match self.core {
            #[cfg(unix)]
            ServeCore::EventLoop => super::reactor::serve_event_loop(self.listener, self.shared),
            #[cfg(not(unix))]
            ServeCore::EventLoop => serve_threads(self.listener, self.shared),
            ServeCore::Threads => serve_threads(self.listener, self.shared),
        }
    }
}

/// The thread-per-connection core: accept, spawn, join on drain.
fn serve_threads(listener: TcpListener, shared: Arc<ServerShared>) -> Result<()> {
    let mut handles: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.metrics.connection_opened();
                let shared = Arc::clone(&shared);
                handles.push(std::thread::spawn(move || handle_connection(stream, &shared)));
                // Reap finished threads so a long-lived daemon's
                // handle list stays bounded by live connections.
                handles.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                // Transient accept failures (EMFILE/ENFILE under fd
                // pressure, ECONNABORTED races) must not kill a
                // long-lived daemon that still has healthy
                // connections: note it, back off, keep serving.
                // The sleep bounds the retry rate while the
                // condition (e.g. fd exhaustion) clears.
                eprintln!("cimdse serve: accept failed (retrying): {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
    drop(listener);
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// What the bounded line reader hands back per call.
enum FrameRead {
    /// One complete frame (without its newline).
    Frame(Vec<u8>),
    /// The line exceeded [`MAX_FRAME_BYTES`]; its remainder has been /
    /// will be discarded up to the next newline.
    Oversized,
    /// Peer closed (possibly mid-frame) or drain was requested.
    Closed,
}

/// Reads `\n`-delimited frames with a hard size cap, surviving read
/// timeouts (used to poll the drain flag). The framing itself —
/// newline split, `\r` strip, oversized discard-and-resync — lives in
/// [`FrameBuf`], shared byte-for-byte with the event-loop core so both
/// cores agree on what a frame is.
struct LineReader {
    stream: TcpStream,
    frames: FrameBuf,
}

impl LineReader {
    fn new(stream: TcpStream) -> LineReader {
        LineReader { stream, frames: FrameBuf::new() }
    }

    fn next_frame(&mut self, shutdown: &AtomicBool) -> FrameRead {
        let mut chunk = [0u8; 8192];
        loop {
            // Serve whatever is already buffered first.
            match self.frames.next_event() {
                Some(FrameEvent::Frame(line)) => return FrameRead::Frame(line),
                Some(FrameEvent::Oversized) => return FrameRead::Oversized,
                None => {}
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => return FrameRead::Closed,
                Ok(n) => self.frames.push(&chunk[..n]),
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    if shutdown.load(Ordering::SeqCst) {
                        return FrameRead::Closed;
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return FrameRead::Closed,
            }
        }
    }
}

fn handle_connection(stream: TcpStream, shared: &ServerShared) {
    // Accepted sockets can inherit the listener's nonblocking mode;
    // force blocking with timeouts so both reads and writes poll the
    // drain flag (a client that stops *reading* must not wedge drain
    // by blocking a response write forever).
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_write_timeout(Some(READ_TIMEOUT));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = LineReader::new(stream);
    loop {
        let line = match reader.next_frame(&shared.shutdown) {
            FrameRead::Frame(line) => line,
            FrameRead::Oversized => {
                // No request was timed: the reject is immediate, so it
                // lands in the histogram's sub-ns bucket (what matters
                // is that reject storms are *counted* in the latency
                // distribution at all).
                shared.metrics.record_error_frame(None, 0.0);
                let frame = error_frame(None, None, &oversized_reject());
                if write_reply(&mut writer, &frame, shared).is_err() {
                    return;
                }
                continue;
            }
            FrameRead::Closed => return,
        };
        if line.iter().all(|b| b.is_ascii_whitespace()) {
            continue; // blank keep-alive lines are not frames
        }
        let response = process_frame(&line, shared);
        if write_reply(&mut writer, &response, shared).is_err() {
            return;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

/// Write one response line, timing the write stage and tracking the
/// write-queue high-water mark. The threads core writes synchronously,
/// so its "queue" is at most the one serialized line (+ newline) in
/// flight — reported so cross-core `metrics` frames stay
/// shape-identical with the event loop's backpressure gauge.
fn write_reply(
    writer: &mut TcpStream,
    line: &str,
    shared: &ServerShared,
) -> std::io::Result<()> {
    shared.metrics.note_write_queue_peak(line.len() + 1);
    // lint:allow(determinism) — write-stage observability only; the
    // reading feeds the metrics op, never a fingerprinted payload.
    let start = Instant::now();
    let out = write_line(writer, line, &shared.shutdown);
    shared.metrics.record_stage("write", start.elapsed().as_secs_f64());
    out
}

fn write_line(
    writer: &mut TcpStream,
    line: &str,
    shutdown: &AtomicBool,
) -> std::io::Result<()> {
    // One buffer per response: line + newline in a single chunk. The
    // manual offset loop (rather than `write_all`) is what keeps drain
    // graceful against a client that stops reading: each write-timeout
    // wakeup re-checks the drain flag, and a requested shutdown
    // abandons the stalled connection instead of blocking
    // [`Server::serve`]'s join forever. A merely *slow* reader is
    // retried indefinitely while the server is up.
    let mut bytes = Vec::with_capacity(line.len() + 1);
    bytes.extend_from_slice(line.as_bytes());
    bytes.push(b'\n');
    let mut off = 0usize;
    while off < bytes.len() {
        match writer.write(&bytes[off..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "connection closed mid-response",
                ));
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if shutdown.load(Ordering::SeqCst) {
                    return Err(e);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    writer.flush()
}

/// The [`super::protocol::CODE_OVERSIZED_FRAME`] rejection both cores
/// answer an over-cap line with.
pub(super) fn oversized_reject() -> Reject {
    Reject::new(
        CODE_OVERSIZED_FRAME,
        format!("request frame exceeds {MAX_FRAME_BYTES} bytes"),
    )
}

/// The [`CODE_CANCELLED`] rejection a cancelled `sweep`/`shard` is
/// answered with (at its FIFO turn, so ordering is preserved).
pub(super) fn cancelled_reject() -> Reject {
    Reject::new(CODE_CANCELLED, "request was cancelled before completing")
}

/// The [`CODE_UNKNOWN_ID`] rejection for a `cancel` naming no in-flight
/// or queued request. `key` is the target id in its JSON spelling.
pub(super) fn unknown_id_reject(key: &str) -> Reject {
    Reject::new(
        CODE_UNKNOWN_ID,
        format!("no in-flight or queued request with id {key} on this connection"),
    )
}

/// Parse one raw frame into `(id, trace, request)`, or the complete
/// error-frame line answering it (metrics already recorded). Both cores
/// funnel every frame through here, so parse-level negative paths
/// answer byte-identically no matter which core serves them. `trace` is
/// the request's validated trace context, to echo on every frame the
/// request produces; an *invalid* trace is itself a rejection, answered
/// without an echo.
pub(super) fn parse_or_reply(
    line: &[u8],
    shared: &ServerShared,
) -> std::result::Result<(Option<Value>, Option<Value>, Request), String> {
    // lint:allow(determinism) — parse-stage/latency observability only;
    // the readings feed the metrics op, never a fingerprinted payload.
    let start = Instant::now();
    let text = match std::str::from_utf8(line) {
        Ok(t) => t,
        Err(_) => {
            let dt = start.elapsed().as_secs_f64();
            shared.metrics.record_stage("parse", dt);
            shared.metrics.record_error_frame(None, dt);
            return Err(error_frame(
                None,
                None,
                &Reject::new(CODE_MALFORMED_JSON, "frame is not valid UTF-8"),
            ));
        }
    };
    let doc = match parse_json(text) {
        Ok(v) => v,
        Err(e) => {
            let dt = start.elapsed().as_secs_f64();
            shared.metrics.record_stage("parse", dt);
            shared.metrics.record_error_frame(None, dt);
            return Err(error_frame(
                None,
                None,
                &Reject::new(CODE_MALFORMED_JSON, e.to_string()),
            ));
        }
    };
    let id = frame_id(&doc);
    let (op, request) = parse_request(&doc);
    let trace = match frame_trace(&doc) {
        Ok(trace) => trace,
        Err(reject) => {
            let dt = start.elapsed().as_secs_f64();
            shared.metrics.record_stage("parse", dt);
            shared.metrics.record_error_frame(op.as_deref(), dt);
            return Err(error_frame(op.as_deref(), id.as_ref(), &reject));
        }
    };
    match request {
        Ok(request) => {
            shared.metrics.record_stage("parse", start.elapsed().as_secs_f64());
            Ok((id, trace, request))
        }
        Err(reject) => {
            let dt = start.elapsed().as_secs_f64();
            shared.metrics.record_stage("parse", dt);
            shared.metrics.record_error_frame(op.as_deref(), dt);
            Err(error_frame_traced(op.as_deref(), id.as_ref(), trace.as_ref(), &reject))
        }
    }
}

/// Parse + dispatch one frame; always returns a response line (success
/// or typed error — a malformed frame never costs the connection).
fn process_frame(line: &[u8], shared: &ServerShared) -> String {
    let (id, trace, request) = match parse_or_reply(line, shared) {
        Ok(parsed) => parsed,
        Err(reply) => return reply,
    };
    let op = request.op();
    // Server-side span, parented under the client's span when the frame
    // carried a trace context. A no-op unless `--trace-out` enabled the
    // tracer; span data flows only to the trace sink, never the frame.
    // lint:allow(determinism) — observability only; the span flows to
    // the trace sink, never into the serialized response.
    let span = crate::obs::server_span(op, trace.as_ref());
    let mut ctl = FoldCtl::default();
    if span.is_recording() {
        ctl.trace = Some(span.ctx());
    }
    // lint:allow(determinism) — request-latency observability only; the
    // reading feeds the metrics op, never a fingerprinted payload.
    let start = Instant::now();
    let result = dispatch(&request, shared, ctl);
    let dispatch_s = start.elapsed().as_secs_f64();
    shared.metrics.record_stage("dispatch", dispatch_s);
    if matches!(&request, Request::Sweep(_) | Request::Shard(_) | Request::Accel(_)) {
        shared.metrics.record_stage("compute", dispatch_s);
    }
    drop(span);
    match result {
        Ok(result) => {
            shared.metrics.record_request(op, start.elapsed().as_secs_f64());
            ok_frame_traced(op, id.as_ref(), trace.as_ref(), result)
        }
        Err(reject) => {
            shared.metrics.record_error_frame(Some(op), start.elapsed().as_secs_f64());
            error_frame_traced(Some(op), id.as_ref(), trace.as_ref(), &reject)
        }
    }
}

/// Resolve the request's model through the prepared cache. Returns the
/// shared prepared model, its fingerprint, and whether it was a hit.
fn lookup_model(
    shared: &ServerShared,
    model: Option<&AdcModel>,
) -> (Arc<PreparedModel>, String, bool) {
    let (fingerprint, model) = match model {
        Some(m) => (model_fingerprint(m), *m),
        None => (shared.default_fingerprint.clone(), shared.default_model),
    };
    let (prepared, hit) =
        shared.cache.lock().unwrap().get_or_prepare(&fingerprint, &model);
    (prepared, fingerprint, hit)
}

fn cache_value(fingerprint: &str, hit: bool) -> Value {
    let mut map = std::collections::BTreeMap::new();
    map.insert("fingerprint".to_string(), Value::String(fingerprint.to_string()));
    map.insert("hit".to_string(), Value::Bool(hit));
    Value::Table(map)
}

/// Enforce `--max-sweep-points`: `points` is what this request would
/// actually evaluate (a `sweep`'s full grid; a `shard`'s own sub-range,
/// so a sharded fleet can stay under per-worker budgets even when the
/// full grid is over). Exactly-at-budget is accepted; one point over is
/// a typed [`CODE_OVER_BUDGET`] rejection.
fn check_budget(
    shared: &ServerShared,
    points: usize,
    what: &str,
) -> std::result::Result<(), Reject> {
    match shared.max_sweep_points {
        Some(budget) if points > budget => Err(Reject::new(
            CODE_OVER_BUDGET,
            format!(
                "{what} would evaluate {points} grid points, over this server's \
                 --max-sweep-points budget of {budget}"
            ),
        )),
        _ => Ok(()),
    }
}

/// Answer one parsed request. `ctl` carries the cancellation token and
/// progress hook of the serving core (the threaded core passes
/// [`FoldCtl::default`]: uncancellable, no progress — which the fold
/// layer guarantees is byte-identical to the uncontrolled path).
pub(super) fn dispatch(
    request: &Request,
    shared: &ServerShared,
    ctl: FoldCtl<'_>,
) -> std::result::Result<Value, Reject> {
    match request {
        Request::Hello(version) => Ok(hello_result(*version)),
        Request::Eval(req) => dispatch_eval(req, shared),
        Request::Sweep(req) => dispatch_sweep(req, shared, ctl),
        Request::Shard(req) => dispatch_shard(req, shared, ctl),
        Request::Accel(req) => dispatch_accel(req, shared),
        Request::Cancel(target) => {
            // Only the event-loop core can ever hit a live target; it
            // answers `cancel` on the reactor without reaching dispatch.
            // The threaded core parses a frame only after fully
            // answering the previous one, so nothing is in flight here
            // and every cancel misses.
            let key = target.to_json_string().unwrap_or_default();
            Err(unknown_id_reject(&key))
        }
        Request::Metrics => {
            let cache = shared.cache.lock().unwrap().stats();
            Ok(shared.metrics.snapshot(&cache))
        }
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            let mut map = std::collections::BTreeMap::new();
            map.insert("draining".to_string(), Value::Bool(true));
            Ok(Value::Table(map))
        }
    }
}

fn dispatch_eval(req: &EvalRequest, shared: &ServerShared) -> std::result::Result<Value, Reject> {
    let (prepared, fingerprint, hit) = lookup_model(shared, req.model.as_ref());
    let model = prepared.model();
    let points: Vec<Value> = req
        .queries
        .iter()
        .map(|q| {
            // The prepared row is bit-identical to `AdcModel::eval` by
            // construction (adc::prepared's exact-bits contract), so a
            // served response equals the direct library call.
            let metrics = prepared.row(q.enob, q.tech_nm).eval_query(q);
            let mut map = std::collections::BTreeMap::new();
            map.insert("query".to_string(), super::protocol::query_to_value(q));
            map.insert("metrics".to_string(), metrics_to_value(&metrics, req.bits));
            map.insert(
                "crossover_throughput".to_string(),
                fnum(model.crossover_throughput(q.enob, q.tech_nm), req.bits),
            );
            Value::Table(map)
        })
        .collect();
    let mut map = std::collections::BTreeMap::new();
    map.insert("count".to_string(), Value::Number(points.len() as f64));
    map.insert("points".to_string(), Value::Array(points));
    map.insert("cache".to_string(), cache_value(&fingerprint, hit));
    Ok(Value::Table(map))
}

fn dispatch_sweep(
    req: &SweepRequest,
    shared: &ServerShared,
    ctl: FoldCtl<'_>,
) -> std::result::Result<Value, Reject> {
    check_budget(shared, req.spec.len(), "sweep")?;
    let (prepared, fingerprint, hit) = lookup_model(shared, req.model.as_ref());
    // The streamed rollup over the shared pool — the identical fold the
    // CLI's `sweep --summary-json` runs, so the summary payload (bit-hex
    // floats) is byte-identical to the direct library call. `ctl` only
    // adds observation points and an early exit; a fold that completes
    // produces the same bytes with or without it. (checked_len rather
    // than a panic: a length-overflowed grid must not take down a
    // shared runner thread.)
    let range = 0..req.spec.checked_len().ok_or_else(|| {
        Reject::new(
            CODE_BAD_REQUEST,
            "sweep grid length overflows usize; split the spec into sub-range specs",
        )
    })?;
    let summary = SweepSummary::compute_range_ctl_with(
        &req.spec,
        prepared.model(),
        shared.workers,
        range,
        ctl,
        req.snr,
    )
    .ok_or_else(cancelled_reject)?;
    let mut map = std::collections::BTreeMap::new();
    map.insert("points".to_string(), Value::Number(summary.count() as f64));
    map.insert("summary".to_string(), summary.to_value());
    map.insert("cache".to_string(), cache_value(&fingerprint, hit));
    Ok(Value::Table(map))
}

fn dispatch_shard(
    req: &ShardRequest,
    shared: &ServerShared,
    ctl: FoldCtl<'_>,
) -> std::result::Result<Value, Reject> {
    // The plan was validated at parse time; re-deriving it here is cheap
    // (two divisions) and keeps dispatch self-contained.
    let plan = ShardPlan::new(&req.spec, req.selector.n_shards())
        .map_err(|e| Reject::new(CODE_BAD_REQUEST, e.to_string()))?;
    check_budget(shared, plan.range(req.selector.index()).len(), "shard")?;
    let (prepared, fingerprint, hit) = lookup_model(shared, req.model.as_ref());
    // The identical computation `cimdse sweep --shard i/N` runs locally,
    // over the shared pool — the artifact payload (bit-hex floats,
    // summary checksum, embedded spec+model) is byte-identical to what
    // that subcommand writes to disk, so a launcher can persist it
    // verbatim and `merge_shards` cannot tell the difference.
    let artifact = ShardArtifact::compute_ctl_with(
        &req.spec,
        prepared.model(),
        req.selector,
        shared.workers,
        ctl,
        req.snr,
    )
    .map_err(|e| Reject::new(CODE_INTERNAL, e.to_string()))?
    .ok_or_else(cancelled_reject)?;
    let mut map = std::collections::BTreeMap::new();
    map.insert(
        "points".to_string(),
        Value::Number(artifact.summary().count() as f64),
    );
    map.insert("artifact".to_string(), artifact.to_value());
    map.insert("cache".to_string(), cache_value(&fingerprint, hit));
    Ok(Value::Table(map))
}

fn dispatch_accel(req: &AccelRequest, shared: &ServerShared) -> std::result::Result<Value, Reject> {
    use crate::dse::accel::{accel_pareto, run_accel_sweep};
    let workload = crate::workload::zoo::by_name(&req.workload)
        .map_err(|e| Reject::new(CODE_BAD_REQUEST, e.to_string()))?;
    let (prepared, fingerprint, hit) = lookup_model(shared, req.model.as_ref());
    let points = run_accel_sweep(&req.spec, prepared.model(), &workload, shared.workers)
        .map_err(|e| Reject::new(CODE_BAD_REQUEST, e.to_string()))?;
    let mut front: Vec<&crate::dse::AccelPoint> =
        accel_pareto(&points).iter().map(|&i| &points[i]).collect();
    front.sort_by(|a, b| a.eap.total_cmp(&b.eap));
    // fnum (not raw Number): an extreme client-supplied model can
    // overflow these to ±inf, which must degrade to bit-hex, not to an
    // unserializable response that loses the id echo.
    let front: Vec<Value> = front
        .iter()
        .map(|p| {
            let mut map = std::collections::BTreeMap::new();
            map.insert("config".to_string(), Value::String(p.arch.name.clone()));
            map.insert("energy_pj".to_string(), fnum(p.energy_pj, false));
            map.insert("area_um2".to_string(), fnum(p.area_um2, false));
            map.insert(
                "adc_energy_fraction".to_string(),
                fnum(p.adc_energy_fraction, false),
            );
            map.insert("latency_s".to_string(), fnum(p.latency_s, false));
            map.insert("eap".to_string(), fnum(p.eap, false));
            Value::Table(map)
        })
        .collect();
    let mut map = std::collections::BTreeMap::new();
    map.insert("workload".to_string(), Value::String(workload.name.clone()));
    map.insert("candidates".to_string(), Value::Number(points.len() as f64));
    map.insert("front".to_string(), Value::Array(front));
    map.insert("cache".to_string(), cache_value(&fingerprint, hit));
    Ok(Value::Table(map))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared_for_test() -> ServerShared {
        shared_with_budget(None)
    }

    fn shared_with_budget(max_sweep_points: Option<usize>) -> ServerShared {
        let model = AdcModel::default();
        ServerShared {
            default_fingerprint: model_fingerprint(&model),
            default_model: model,
            workers: 2,
            max_sweep_points,
            progress_every: None,
            cache: std::sync::Mutex::new(PreparedCache::new(4)),
            metrics: ServiceMetrics::new(),
            shutdown: AtomicBool::new(false),
        }
    }

    fn ok_result(shared: &ServerShared, line: &str) -> Value {
        let resp = parse_json(&process_frame(line.as_bytes(), shared)).unwrap();
        assert_eq!(
            resp.get("ok").and_then(Value::as_bool),
            Some(true),
            "{line} -> {resp:?}"
        );
        resp.get("result").unwrap().clone()
    }

    fn err_code(shared: &ServerShared, line: &str) -> String {
        let resp = parse_json(&process_frame(line.as_bytes(), shared)).unwrap();
        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(false), "{line}");
        resp.require_str("error.code").unwrap().to_string()
    }

    #[test]
    fn eval_frame_is_bit_identical_to_direct_eval() {
        let shared = shared_for_test();
        let q = crate::adc::AdcQuery {
            enob: 7.5,
            total_throughput: 1.3e9,
            tech_nm: 32.0,
            n_adcs: 8,
        };
        let result = ok_result(
            &shared,
            &format!(
                r#"{{"op": "eval", "bits": true, "query": {{"enob": 7.5,
                   "total_throughput": 1.3e9, "tech_nm": 32, "n_adcs": 8}}}}"#
            ),
        );
        let point = &result.get("points").and_then(Value::as_array).unwrap()[0];
        let metrics =
            super::super::protocol::metrics_from_value(point.get("metrics").unwrap()).unwrap();
        assert_eq!(metrics.to_bits(), shared.default_model.eval(&q).to_bits());
        // Second identical call: cache hit.
        let result = ok_result(
            &shared,
            r#"{"op": "eval", "query": {"enob": 7.5, "total_throughput": 1.3e9, "n_adcs": 8}}"#,
        );
        assert_eq!(result.get("cache.hit").and_then(Value::as_bool), Some(true));
    }

    #[test]
    fn sweep_frame_summary_matches_direct_compute_bytes() {
        let shared = shared_for_test();
        let spec = crate::dse::SweepSpec {
            enobs: vec![4.0, 8.0],
            total_throughputs: vec![1e8, 1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1, 4],
        };
        let frame = format!(
            r#"{{"op": "sweep", "spec": {}}}"#,
            spec.to_value().to_json_string().unwrap()
        );
        let result = ok_result(&shared, &frame);
        let served = result.get("summary").unwrap().to_json_string().unwrap();
        let direct = SweepSummary::compute(&spec, &shared.default_model, 2)
            .to_value()
            .to_json_string()
            .unwrap();
        assert_eq!(served, direct, "served sweep summary must be byte-identical");
    }

    #[test]
    fn shard_frame_artifact_is_byte_identical_to_local_compute() {
        let shared = shared_for_test();
        let spec = crate::dse::SweepSpec {
            enobs: vec![4.0, 8.0, 12.0],
            total_throughputs: vec![1e8, 1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1, 4],
        };
        let spec_json = spec.to_value().to_json_string().unwrap();
        for i in 0..3usize {
            let frame = format!(r#"{{"op": "shard", "shard": "{i}/3", "spec": {spec_json}}}"#);
            let result = ok_result(&shared, &frame);
            let served = result.get("artifact").unwrap().to_json_string().unwrap();
            let direct = ShardArtifact::compute(
                &spec,
                &shared.default_model,
                crate::dse::ShardSelector::new(i, 3).unwrap(),
                2,
            )
            .unwrap()
            .to_value()
            .to_json_string()
            .unwrap();
            assert_eq!(served, direct, "shard {i}/3 must serialize byte-identically");
            // And the served payload survives the full artifact validator
            // (fingerprint, planned range, summary checksum).
            let back = ShardArtifact::from_value(result.get("artifact").unwrap()).unwrap();
            assert_eq!(back.summary().count(), result.require_usize("points").unwrap());
        }
    }

    #[test]
    fn tri_objective_frames_are_byte_identical_to_local_compute() {
        let shared = shared_for_test();
        let spec = crate::dse::SweepSpec {
            enobs: vec![4.0, 8.0],
            total_throughputs: vec![1e8, 1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1, 4],
        };
        let spec_json = spec.to_value().to_json_string().unwrap();
        let ctx = crate::dse::SnrContext { n_sum: 2048, cell_bits: 3 };
        let objectives = r#""objectives": ["energy", "area", "snr"]"#;
        let snr = r#""snr": {"n_sum": 2048, "cell_bits": 3}"#;

        let frame = format!(r#"{{"op": "sweep", "spec": {spec_json}, {objectives}, {snr}}}"#);
        let result = ok_result(&shared, &frame);
        let served = result.get("summary").unwrap().to_json_string().unwrap();
        let direct = SweepSummary::compute_with(&spec, &shared.default_model, 2, Some(ctx))
            .to_value()
            .to_json_string()
            .unwrap();
        assert_eq!(served, direct, "served tri-objective summary must be byte-identical");
        assert!(served.contains("snr_front"), "{served}");

        for i in 0..2usize {
            let frame = format!(
                r#"{{"op": "shard", "shard": "{i}/2", "spec": {spec_json}, {objectives}, {snr}}}"#
            );
            let result = ok_result(&shared, &frame);
            let served = result.get("artifact").unwrap().to_json_string().unwrap();
            let direct = ShardArtifact::compute_with(
                &spec,
                &shared.default_model,
                crate::dse::ShardSelector::new(i, 2).unwrap(),
                2,
                Some(ctx),
            )
            .unwrap()
            .to_value()
            .to_json_string()
            .unwrap();
            assert_eq!(served, direct, "tri shard {i}/2 must serialize byte-identically");
            let back = ShardArtifact::from_value(result.get("artifact").unwrap()).unwrap();
            assert_eq!(back.summary().snr_context(), Some(ctx));
        }

        // Explicitly requesting the classic set changes nothing: same
        // bytes as a frame with no objectives at all.
        let classic = format!(r#"{{"op": "sweep", "spec": {spec_json}}}"#);
        let explicit =
            format!(r#"{{"op": "sweep", "spec": {spec_json}, "objectives": ["power", "area"]}}"#);
        assert_eq!(
            ok_result(&shared, &classic).to_json_string().unwrap(),
            ok_result(&shared, &explicit).to_json_string().unwrap()
        );
    }

    #[test]
    fn sweep_and_shard_budget_boundary_is_exact() {
        // dense-ish spec: 2 x 2 x 1 x 2 = 8 points; shards of 8/2 = 4.
        let spec = crate::dse::SweepSpec {
            enobs: vec![4.0, 8.0],
            total_throughputs: vec![1e8, 1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1, 4],
        };
        let spec_json = spec.to_value().to_json_string().unwrap();
        let sweep = format!(r#"{{"op": "sweep", "spec": {spec_json}}}"#);
        let half = format!(r#"{{"op": "shard", "shard": "0/2", "spec": {spec_json}}}"#);
        let whole = format!(r#"{{"op": "shard", "shard": "0/1", "spec": {spec_json}}}"#);

        // Budget == the evaluated size: accepted, bit for bit.
        let shared = shared_with_budget(Some(8));
        ok_result(&shared, &sweep);
        ok_result(&shared, &whole);
        ok_result(&shared, &half);

        // One point under the grid: the whole sweep (and the whole-grid
        // shard) is rejected with the stable code, but a shard whose own
        // sub-range fits is still served — budgets bound what a request
        // evaluates, not the grid it is planned over.
        let shared = shared_with_budget(Some(7));
        assert_eq!(err_code(&shared, &sweep), CODE_OVER_BUDGET);
        assert_eq!(err_code(&shared, &whole), CODE_OVER_BUDGET);
        ok_result(&shared, &half);

        // Budget below the half-shard too: everything sweep-shaped is
        // rejected, eval is untouched.
        let shared = shared_with_budget(Some(3));
        assert_eq!(err_code(&shared, &half), CODE_OVER_BUDGET);
        ok_result(
            &shared,
            r#"{"op": "eval", "query": {"enob": 7, "total_throughput": 1e9}}"#,
        );
    }

    #[test]
    fn typed_error_frames_for_every_negative_path() {
        let shared = shared_for_test();
        assert_eq!(err_code(&shared, "{ not json"), CODE_MALFORMED_JSON);
        assert_eq!(err_code(&shared, "[1, 2]"), super::super::protocol::CODE_BAD_FRAME);
        assert_eq!(err_code(&shared, r#"{"op": "nope"}"#), super::super::protocol::CODE_UNKNOWN_OP);
        assert_eq!(err_code(&shared, r#"{"op": "eval"}"#), CODE_BAD_REQUEST);
        assert_eq!(
            err_code(&shared, r#"{"op": "accel", "workload": "alexnet"}"#),
            CODE_BAD_REQUEST
        );
        assert_eq!(
            process_frame(&[0xff, 0xfe, b'{'], &shared),
            error_frame(
                None,
                None,
                &Reject::new(CODE_MALFORMED_JSON, "frame is not valid UTF-8")
            )
        );
        let snapshot = ok_result(&shared, r#"{"op": "metrics"}"#);
        assert_eq!(snapshot.require_f64("error_frames").unwrap(), 6.0);
    }

    #[test]
    fn shutdown_frame_answers_then_flips_the_flag() {
        let shared = shared_for_test();
        assert!(!shared.shutdown.load(Ordering::SeqCst));
        let result = ok_result(&shared, r#"{"op": "shutdown"}"#);
        assert_eq!(result.get("draining").and_then(Value::as_bool), Some(true));
        assert!(shared.shutdown.load(Ordering::SeqCst));
    }

    #[test]
    fn hello_negotiates_and_bad_versions_get_exact_codes() {
        let shared = shared_for_test();
        for v in [1u32, 2] {
            let result = ok_result(&shared, &format!(r#"{{"op": "hello", "version": {v}}}"#));
            assert_eq!(result.require_usize("version").unwrap(), v as usize);
        }
        assert_eq!(
            err_code(&shared, r#"{"op": "hello", "version": 3}"#),
            super::super::protocol::CODE_UNSUPPORTED_VERSION
        );
        assert_eq!(err_code(&shared, r#"{"op": "hello"}"#), CODE_BAD_REQUEST);
        assert_eq!(
            err_code(&shared, r#"{"op": "hello", "version": 1.5}"#),
            CODE_BAD_REQUEST
        );
    }

    #[test]
    fn threaded_core_cancel_always_misses_with_unknown_id() {
        // The threaded core answers each frame before parsing the next,
        // so a `cancel` can never name a live request: every one is a
        // typed unknown-id rejection (live-target hits are event-loop
        // behavior, exercised by the v2 corpus).
        let shared = shared_for_test();
        assert_eq!(
            err_code(&shared, r#"{"op": "cancel", "target": "job-9"}"#),
            CODE_UNKNOWN_ID
        );
        let resp = parse_json(&process_frame(
            br#"{"op": "cancel", "target": 7, "id": "c-1"}"#,
            &shared,
        ))
        .unwrap();
        assert_eq!(resp.require_str("id").unwrap(), "c-1");
        assert_eq!(resp.require_str("error.code").unwrap(), CODE_UNKNOWN_ID);
        // Malformed cancels are bad requests, not unknown ids.
        assert_eq!(err_code(&shared, r#"{"op": "cancel"}"#), CODE_BAD_REQUEST);
        assert_eq!(
            err_code(&shared, r#"{"op": "cancel", "target": [1]}"#),
            CODE_BAD_REQUEST
        );
    }

    #[test]
    fn id_is_echoed_on_success_and_error() {
        let shared = shared_for_test();
        let resp = parse_json(&process_frame(
            br#"{"op": "metrics", "id": "req-1"}"#,
            &shared,
        ))
        .unwrap();
        assert_eq!(resp.require_str("id").unwrap(), "req-1");
        let resp =
            parse_json(&process_frame(br#"{"op": "nope", "id": 42}"#, &shared)).unwrap();
        assert_eq!(resp.get("id").and_then(Value::as_f64), Some(42.0));
    }
}
