//! Service observability: request counters, latency quantiles, cache
//! stats, uptime — served as a `metrics` frame and printable.
//!
//! Latencies are kept in a fixed-size ring (the most recent
//! [`LATENCY_WINDOW`] requests); p50/p99 come from
//! [`crate::stats::quantile`] over a snapshot of the ring, so the cost
//! of a `metrics` request is O(window), never O(history).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::config::Value;
use crate::error::Result;
use crate::stats::quantile;

use super::cache::CacheStats;

/// Number of recent request latencies retained for the quantiles.
pub const LATENCY_WINDOW: usize = 4096;

#[derive(Default)]
struct Inner {
    /// Successful requests per op.
    requests: BTreeMap<String, u64>,
    /// Error frames sent (malformed/unknown/rejected requests).
    error_frames: u64,
    /// Connections accepted over the server's lifetime.
    connections: u64,
    /// Ring buffer of request latencies (seconds).
    latencies: Vec<f64>,
    /// Next ring slot to overwrite once the ring is full.
    next_slot: usize,
    /// Total latencies ever recorded (>= ring occupancy).
    recorded: u64,
    /// Sweep/shard fold chunks completed (each one a cancellation
    /// checkpoint — a stalling counter is how tests prove an abandoned
    /// shard stopped burning pool cycles).
    work_chunks: u64,
    /// Grid points evaluated across those chunks.
    work_points: u64,
    /// Requests answered with a `cancelled` error frame.
    cancelled: u64,
    /// High-water mark of any connection's response write queue (bytes)
    /// — event-loop core only; bounded by its backpressure cap.
    write_queue_peak_bytes: u64,
}

/// Shared, thread-safe service counters.
pub struct ServiceMetrics {
    start: Instant,
    inner: Mutex<Inner>,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        ServiceMetrics::new()
    }
}

impl ServiceMetrics {
    /// Fresh counters; uptime starts now.
    pub fn new() -> ServiceMetrics {
        ServiceMetrics { start: Instant::now(), inner: Mutex::new(Inner::default()) }
    }

    /// Record an accepted connection.
    pub fn connection_opened(&self) {
        self.inner.lock().unwrap().connections += 1;
    }

    /// Record one successfully served request and its latency.
    pub fn record_request(&self, op: &str, latency_s: f64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.requests.entry(op.to_string()).or_insert(0) += 1;
        inner.recorded += 1;
        if inner.latencies.len() < LATENCY_WINDOW {
            inner.latencies.push(latency_s);
        } else {
            let slot = inner.next_slot;
            inner.latencies[slot] = latency_s;
            inner.next_slot = (slot + 1) % LATENCY_WINDOW;
        }
    }

    /// Record an error frame sent to a client.
    pub fn record_error_frame(&self) {
        self.inner.lock().unwrap().error_frames += 1;
    }

    /// Record one completed sweep/shard fold chunk of `points` points.
    pub fn record_chunk(&self, points: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.work_chunks += 1;
        inner.work_points += points as u64;
    }

    /// Record a request answered with a `cancelled` error frame.
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// Raise the write-queue high-water mark to `bytes` if it is higher
    /// than anything seen so far.
    pub fn note_write_queue_peak(&self, bytes: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.write_queue_peak_bytes = inner.write_queue_peak_bytes.max(bytes as u64);
    }

    /// Snapshot everything as the `metrics` frame payload.
    pub fn snapshot(&self, cache: &CacheStats) -> Value {
        // Copy what we need and release the lock before the O(n log n)
        // quantile sorts, so connection threads recording latencies are
        // never stalled behind a metrics request.
        let (requests_counts, error_frames, connections, latencies, recorded, work, peak) = {
            let inner = self.inner.lock().unwrap();
            (
                inner.requests.clone(),
                inner.error_frames,
                inner.connections,
                inner.latencies.clone(),
                inner.recorded,
                (inner.work_chunks, inner.work_points, inner.cancelled),
                inner.write_queue_peak_bytes,
            )
        };
        let mut requests = BTreeMap::new();
        let mut total = 0u64;
        for (op, n) in &requests_counts {
            requests.insert(op.clone(), Value::Number(*n as f64));
            total += n;
        }
        let mut latency = BTreeMap::new();
        latency.insert("samples".to_string(), Value::Number(latencies.len() as f64));
        latency.insert("recorded".to_string(), Value::Number(recorded as f64));
        if !latencies.is_empty() {
            latency.insert("p50_s".to_string(), Value::Number(quantile(&latencies, 0.50)));
            latency.insert("p99_s".to_string(), Value::Number(quantile(&latencies, 0.99)));
        }
        let mut cache_map = BTreeMap::new();
        cache_map.insert("hits".to_string(), Value::Number(cache.hits as f64));
        cache_map.insert("misses".to_string(), Value::Number(cache.misses as f64));
        cache_map.insert("evictions".to_string(), Value::Number(cache.evictions as f64));
        cache_map.insert("collisions".to_string(), Value::Number(cache.collisions as f64));
        cache_map.insert("entries".to_string(), Value::Number(cache.entries as f64));
        cache_map.insert("capacity".to_string(), Value::Number(cache.capacity as f64));
        let mut map = BTreeMap::new();
        map.insert("uptime_s".to_string(), Value::Number(self.start.elapsed().as_secs_f64()));
        map.insert("connections".to_string(), Value::Number(connections as f64));
        map.insert("requests_total".to_string(), Value::Number(total as f64));
        map.insert("requests".to_string(), Value::Table(requests));
        map.insert("error_frames".to_string(), Value::Number(error_frames as f64));
        map.insert("latency".to_string(), Value::Table(latency));
        map.insert("cache".to_string(), Value::Table(cache_map));
        let (work_chunks, work_points, cancelled) = work;
        let mut work_map = BTreeMap::new();
        work_map.insert("chunks".to_string(), Value::Number(work_chunks as f64));
        work_map.insert("points".to_string(), Value::Number(work_points as f64));
        work_map.insert("cancelled".to_string(), Value::Number(cancelled as f64));
        map.insert("work".to_string(), Value::Table(work_map));
        map.insert("write_queue_peak_bytes".to_string(), Value::Number(peak as f64));
        Value::Table(map)
    }

    /// Render a `metrics` frame payload for humans. A static function
    /// over the [`Value`] so `cimdse query --op metrics` prints exactly
    /// what the server would.
    pub fn render(v: &Value) -> Result<String> {
        let num = |path: &str| -> Result<f64> { v.require_f64(path) };
        let mut out = String::from("cimdse service metrics:\n");
        out.push_str(&format!("  uptime          {:.1} s\n", num("uptime_s")?));
        out.push_str(&format!("  connections     {:.0}\n", num("connections")?));
        let mut per_op = Vec::new();
        if let Some(Value::Table(requests)) = v.get("requests") {
            for (op, n) in requests {
                if let Some(n) = n.as_f64() {
                    per_op.push(format!("{op} {n:.0}"));
                }
            }
        }
        out.push_str(&format!(
            "  requests        {:.0} total ({})\n",
            num("requests_total")?,
            if per_op.is_empty() { "none".to_string() } else { per_op.join(", ") }
        ));
        out.push_str(&format!("  error frames    {:.0}\n", num("error_frames")?));
        match (v.get("latency.p50_s").and_then(Value::as_f64),
               v.get("latency.p99_s").and_then(Value::as_f64)) {
            (Some(p50), Some(p99)) => out.push_str(&format!(
                "  latency         p50 {}  p99 {}  ({:.0} samples)\n",
                crate::bench_util::fmt_secs(p50),
                crate::bench_util::fmt_secs(p99),
                num("latency.samples")?
            )),
            _ => out.push_str("  latency         (no samples yet)\n"),
        }
        out.push_str(&format!(
            "  cache           {:.0} hits, {:.0} misses, {:.0} evictions, {:.0}/{:.0} entries\n",
            num("cache.hits")?,
            num("cache.misses")?,
            num("cache.evictions")?,
            num("cache.entries")?,
            num("cache.capacity")?
        ));
        // Tolerate snapshots from servers predating these counters.
        if let Some(chunks) = v.get("work.chunks").and_then(Value::as_f64) {
            let points = v.get("work.points").and_then(Value::as_f64).unwrap_or(0.0);
            let cancelled = v.get("work.cancelled").and_then(Value::as_f64).unwrap_or(0.0);
            out.push_str(&format!(
                "  work            {chunks:.0} chunks, {points:.0} points, {cancelled:.0} cancelled\n"
            ));
        }
        if let Some(peak) = v.get("write_queue_peak_bytes").and_then(Value::as_f64) {
            out.push_str(&format!("  write queue     {peak:.0} bytes peak\n"));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> CacheStats {
        CacheStats { hits: 3, misses: 2, evictions: 1, collisions: 0, entries: 2, capacity: 8 }
    }

    #[test]
    fn snapshot_counts_and_quantiles() {
        let m = ServiceMetrics::new();
        m.connection_opened();
        m.connection_opened();
        for i in 0..100 {
            m.record_request("eval", (i + 1) as f64 * 1e-3);
        }
        m.record_request("sweep", 0.5);
        m.record_error_frame();
        let v = m.snapshot(&stats());
        assert_eq!(v.require_f64("requests_total").unwrap(), 101.0);
        assert_eq!(v.require_f64("requests.eval").unwrap(), 100.0);
        assert_eq!(v.require_f64("requests.sweep").unwrap(), 1.0);
        assert_eq!(v.require_f64("connections").unwrap(), 2.0);
        assert_eq!(v.require_f64("error_frames").unwrap(), 1.0);
        assert_eq!(v.require_f64("cache.hits").unwrap(), 3.0);
        let p50 = v.require_f64("latency.p50_s").unwrap();
        let p99 = v.require_f64("latency.p99_s").unwrap();
        assert!(p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}");
        assert!(v.require_f64("uptime_s").unwrap() >= 0.0);
    }

    #[test]
    fn latency_ring_is_bounded() {
        let m = ServiceMetrics::new();
        for i in 0..(LATENCY_WINDOW + 100) {
            m.record_request("eval", i as f64);
        }
        let v = m.snapshot(&stats());
        assert_eq!(v.require_f64("latency.samples").unwrap(), LATENCY_WINDOW as f64);
        assert_eq!(
            v.require_f64("latency.recorded").unwrap(),
            (LATENCY_WINDOW + 100) as f64
        );
        // The oldest 100 samples were overwritten, so the minimum
        // surviving latency is >= 100.
        assert!(v.require_f64("latency.p50_s").unwrap() >= 100.0);
    }

    #[test]
    fn work_and_backpressure_counters_accumulate() {
        let m = ServiceMetrics::new();
        m.record_chunk(64);
        m.record_chunk(64);
        m.record_chunk(8);
        m.record_cancelled();
        m.note_write_queue_peak(1024);
        m.note_write_queue_peak(512); // lower: peak must not regress
        let v = m.snapshot(&stats());
        assert_eq!(v.require_f64("work.chunks").unwrap(), 3.0);
        assert_eq!(v.require_f64("work.points").unwrap(), 136.0);
        assert_eq!(v.require_f64("work.cancelled").unwrap(), 1.0);
        assert_eq!(v.require_f64("write_queue_peak_bytes").unwrap(), 1024.0);
        let text = ServiceMetrics::render(&v).unwrap();
        assert!(text.contains("work            3 chunks, 136 points, 1 cancelled"), "{text}");
        assert!(text.contains("write queue     1024 bytes peak"), "{text}");
    }

    #[test]
    fn render_is_stable_and_complete() {
        let m = ServiceMetrics::new();
        m.record_request("eval", 1e-3);
        m.record_request("eval", 2e-3);
        let text = ServiceMetrics::render(&m.snapshot(&stats())).unwrap();
        assert!(text.contains("cimdse service metrics"), "{text}");
        assert!(text.contains("requests        2 total (eval 2)"), "{text}");
        assert!(text.contains("cache           3 hits, 2 misses"), "{text}");
        assert!(text.contains("latency         p50"), "{text}");
        // Renders an empty snapshot too (no latency samples).
        let empty = ServiceMetrics::new();
        let text =
            ServiceMetrics::render(&empty.snapshot(&CacheStats::default())).unwrap();
        assert!(text.contains("(no samples yet)"), "{text}");
    }
}
