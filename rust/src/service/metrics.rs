//! Service observability: request counters, log2 latency histograms,
//! per-op/outcome and per-stage breakdowns, pool profiling, cache stats,
//! uptime — served as a `metrics` frame, printable for humans, and
//! exportable in the Prometheus text exposition format.
//!
//! Latencies live in fixed-bucket log2 histograms
//! ([`crate::obs::hist::Hist`]): constant memory, exact counts over the
//! server's whole life (no sliding window), and mergeable across
//! servers. Every frame feeds the histograms — successes, typed error
//! frames, and cancellations, each under an `outcome` label — so a
//! daemon drowning in rejects can no longer report healthy quantiles
//! (the old 4096-sample ring counted successes only).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::config::Value;
use crate::error::Result;
use crate::obs::hist::Hist;

use super::cache::CacheStats;

/// Outcome labels used in the per-op histogram table.
pub const OUTCOMES: &[&str] = &["ok", "error", "cancelled"];

/// Request pipeline stages timed by both serving cores.
pub const STAGES: &[&str] = &["parse", "dispatch", "compute", "write"];

#[derive(Default)]
struct Inner {
    /// Successful requests per op.
    requests: BTreeMap<String, u64>,
    /// Error frames sent (malformed/unknown/rejected/cancelled requests).
    error_frames: u64,
    /// Connections accepted over the server's lifetime.
    connections: u64,
    /// Latency of every frame served, any outcome.
    overall: Hist,
    /// Latency by `(op, outcome)`; outcome is one of [`OUTCOMES`]. Frames
    /// rejected before an op could be parsed land under op `"unknown"`.
    by_op: BTreeMap<(String, &'static str), Hist>,
    /// Time spent per pipeline stage (one of [`STAGES`]).
    stages: BTreeMap<&'static str, Hist>,
    /// Sweep/shard fold chunks completed (each one a cancellation
    /// checkpoint — a stalling counter is how tests prove an abandoned
    /// shard stopped burning pool cycles).
    work_chunks: u64,
    /// Grid points evaluated across those chunks.
    work_points: u64,
    /// Requests answered with a `cancelled` error frame.
    cancelled: u64,
    /// High-water mark of any connection's response write queue (bytes).
    /// Tracked on both cores: the event loop measures its backpressure
    /// queue, the threads core the serialized line it writes.
    write_queue_peak_bytes: u64,
}

/// Shared, thread-safe service counters.
pub struct ServiceMetrics {
    start: Instant,
    inner: Mutex<Inner>,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        ServiceMetrics::new()
    }
}

impl ServiceMetrics {
    /// Fresh counters; uptime starts now.
    pub fn new() -> ServiceMetrics {
        ServiceMetrics { start: Instant::now(), inner: Mutex::new(Inner::default()) }
    }

    /// Record an accepted connection.
    pub fn connection_opened(&self) {
        self.inner.lock().unwrap().connections += 1;
    }

    /// Record one successfully served request and its latency.
    pub fn record_request(&self, op: &str, latency_s: f64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.requests.entry(op.to_string()).or_insert(0) += 1;
        inner.overall.observe(latency_s);
        inner.by_op.entry((op.to_string(), "ok")).or_default().observe(latency_s);
    }

    /// Record an error frame sent to a client, with the time spent
    /// producing it. `op` is the request's op when one was parsed
    /// (`None` for malformed/oversized frames, tallied as `"unknown"`).
    pub fn record_error_frame(&self, op: Option<&str>, latency_s: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.error_frames += 1;
        inner.overall.observe(latency_s);
        inner
            .by_op
            .entry((op.unwrap_or("unknown").to_string(), "error"))
            .or_default()
            .observe(latency_s);
    }

    /// Record a request answered with a `cancelled` error frame: bumps
    /// both the cancellation counter and the error-frame tally (a
    /// cancellation *is* an error frame on the wire).
    pub fn record_cancelled_frame(&self, op: Option<&str>, latency_s: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.cancelled += 1;
        inner.error_frames += 1;
        inner.overall.observe(latency_s);
        inner
            .by_op
            .entry((op.unwrap_or("unknown").to_string(), "cancelled"))
            .or_default()
            .observe(latency_s);
    }

    /// Record time spent in one pipeline stage (one of [`STAGES`]).
    pub fn record_stage(&self, stage: &'static str, dur_s: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.stages.entry(stage).or_default().observe(dur_s);
    }

    /// Record one completed sweep/shard fold chunk of `points` points.
    pub fn record_chunk(&self, points: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.work_chunks += 1;
        inner.work_points += points as u64;
    }

    /// Raise the write-queue high-water mark to `bytes` if it is higher
    /// than anything seen so far.
    pub fn note_write_queue_peak(&self, bytes: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.write_queue_peak_bytes = inner.write_queue_peak_bytes.max(bytes as u64);
    }

    /// Snapshot everything as the `metrics` frame payload.
    pub fn snapshot(&self, cache: &CacheStats) -> Value {
        // Clone the tallies and release the lock before deriving
        // quantiles and building the payload, so connection threads
        // recording latencies are never stalled behind a metrics request.
        let (requests_counts, error_frames, connections, overall, by_op, stage_hists, work, peak) = {
            let inner = self.inner.lock().unwrap();
            (
                inner.requests.clone(),
                inner.error_frames,
                inner.connections,
                inner.overall.clone(),
                inner.by_op.clone(),
                inner.stages.clone(),
                (inner.work_chunks, inner.work_points, inner.cancelled),
                inner.write_queue_peak_bytes,
            )
        };
        let mut requests = BTreeMap::new();
        let mut total = 0u64;
        for (op, n) in &requests_counts {
            requests.insert(op.clone(), Value::Number(*n as f64));
            total += n;
        }
        // The latency table is the overall histogram payload plus the
        // legacy `samples`/`recorded` keys (both now the exact lifetime
        // count: histograms never evict).
        let mut latency = match overall.to_value() {
            Value::Table(t) => t,
            _ => BTreeMap::new(),
        };
        latency.insert("samples".to_string(), Value::Number(overall.count() as f64));
        latency.insert("recorded".to_string(), Value::Number(overall.count() as f64));
        let mut ops: BTreeMap<String, BTreeMap<String, Value>> = BTreeMap::new();
        for ((op, outcome), h) in &by_op {
            ops.entry(op.clone()).or_default().insert(outcome.to_string(), h.to_value());
        }
        let ops: BTreeMap<String, Value> =
            ops.into_iter().map(|(op, t)| (op, Value::Table(t))).collect();
        let mut stages = BTreeMap::new();
        for (stage, h) in &stage_hists {
            stages.insert(stage.to_string(), h.to_value());
        }
        let mut cache_map = BTreeMap::new();
        cache_map.insert("hits".to_string(), Value::Number(cache.hits as f64));
        cache_map.insert("misses".to_string(), Value::Number(cache.misses as f64));
        cache_map.insert("evictions".to_string(), Value::Number(cache.evictions as f64));
        cache_map.insert("collisions".to_string(), Value::Number(cache.collisions as f64));
        cache_map.insert("entries".to_string(), Value::Number(cache.entries as f64));
        cache_map.insert("capacity".to_string(), Value::Number(cache.capacity as f64));
        let mut map = BTreeMap::new();
        map.insert("uptime_s".to_string(), Value::Number(self.start.elapsed().as_secs_f64()));
        map.insert("connections".to_string(), Value::Number(connections as f64));
        map.insert("requests_total".to_string(), Value::Number(total as f64));
        map.insert("requests".to_string(), Value::Table(requests));
        map.insert("error_frames".to_string(), Value::Number(error_frames as f64));
        map.insert("latency".to_string(), Value::Table(latency));
        map.insert("ops".to_string(), Value::Table(ops));
        map.insert("stages".to_string(), Value::Table(stages));
        map.insert("cache".to_string(), Value::Table(cache_map));
        let (work_chunks, work_points, cancelled) = work;
        let mut work_map = BTreeMap::new();
        work_map.insert("chunks".to_string(), Value::Number(work_chunks as f64));
        work_map.insert("points".to_string(), Value::Number(work_points as f64));
        work_map.insert("cancelled".to_string(), Value::Number(cancelled as f64));
        map.insert("work".to_string(), Value::Table(work_map));
        map.insert("write_queue_peak_bytes".to_string(), Value::Number(peak as f64));
        map.insert("pool".to_string(), pool_stats_value());
        Value::Table(map)
    }

    /// Render a `metrics` frame payload for humans. A static function
    /// over the [`Value`] so `cimdse query --op metrics` prints exactly
    /// what the server would.
    pub fn render(v: &Value) -> Result<String> {
        let num = |path: &str| -> Result<f64> { v.require_f64(path) };
        let mut out = String::from("cimdse service metrics:\n");
        out.push_str(&format!("  uptime          {:.1} s\n", num("uptime_s")?));
        out.push_str(&format!("  connections     {:.0}\n", num("connections")?));
        let mut per_op = Vec::new();
        if let Some(Value::Table(requests)) = v.get("requests") {
            for (op, n) in requests {
                if let Some(n) = n.as_f64() {
                    per_op.push(format!("{op} {n:.0}"));
                }
            }
        }
        out.push_str(&format!(
            "  requests        {:.0} total ({})\n",
            num("requests_total")?,
            if per_op.is_empty() { "none".to_string() } else { per_op.join(", ") }
        ));
        out.push_str(&format!("  error frames    {:.0}\n", num("error_frames")?));
        match (v.get("latency.p50_s").and_then(Value::as_f64),
               v.get("latency.p99_s").and_then(Value::as_f64)) {
            (Some(p50), Some(p99)) => out.push_str(&format!(
                "  latency         p50 {}  p99 {}  ({:.0} samples)\n",
                crate::bench_util::fmt_secs(p50),
                crate::bench_util::fmt_secs(p99),
                num("latency.samples")?
            )),
            _ => out.push_str("  latency         (no samples yet)\n"),
        }
        if let Some(Value::Table(stages)) = v.get("stages") {
            let mut parts = Vec::new();
            for (stage, h) in stages {
                if let Some(p50) = h.get("p50_s").and_then(Value::as_f64) {
                    parts.push(format!("{stage} p50 {}", crate::bench_util::fmt_secs(p50)));
                }
            }
            if !parts.is_empty() {
                out.push_str(&format!("  stages          {}\n", parts.join(", ")));
            }
        }
        out.push_str(&format!(
            "  cache           {:.0} hits, {:.0} misses, {:.0} evictions, {:.0}/{:.0} entries\n",
            num("cache.hits")?,
            num("cache.misses")?,
            num("cache.evictions")?,
            num("cache.entries")?,
            num("cache.capacity")?
        ));
        // Tolerate snapshots from servers predating these counters.
        if let Some(chunks) = v.get("work.chunks").and_then(Value::as_f64) {
            let points = v.get("work.points").and_then(Value::as_f64).unwrap_or(0.0);
            let cancelled = v.get("work.cancelled").and_then(Value::as_f64).unwrap_or(0.0);
            out.push_str(&format!(
                "  work            {chunks:.0} chunks, {points:.0} points, {cancelled:.0} cancelled\n"
            ));
        }
        if let Some(peak) = v.get("write_queue_peak_bytes").and_then(Value::as_f64) {
            out.push_str(&format!("  write queue     {peak:.0} bytes peak\n"));
        }
        if let Some(workers) = v.get("pool.workers").and_then(Value::as_f64) {
            let chunks = v.get("pool.chunks").and_then(Value::as_f64).unwrap_or(0.0);
            let steals = v.get("pool.steals").and_then(Value::as_f64).unwrap_or(0.0);
            let idle = v.get("pool.idle_s").and_then(Value::as_f64).unwrap_or(0.0);
            out.push_str(&format!(
                "  pool            {workers:.0} workers, {chunks:.0} chunks ({steals:.0} stolen), {} idle\n",
                crate::bench_util::fmt_secs(idle)
            ));
        }
        Ok(out)
    }

    /// Render a `metrics` frame payload in the Prometheus text
    /// exposition format (`cimdse query --op metrics --format
    /// prometheus`). Counters and gauges come straight off the payload;
    /// histograms are re-emitted with *cumulative* `le` buckets as the
    /// format requires. Like [`ServiceMetrics::render`], a static
    /// function over the [`Value`] so the client renders exactly what
    /// the server measured.
    pub fn render_prometheus(v: &Value) -> Result<String> {
        let num = |path: &str| -> Result<f64> { v.require_f64(path) };
        let mut out = String::new();
        prom_type(&mut out, "cimdse_uptime_seconds", "gauge");
        prom_line(&mut out, "cimdse_uptime_seconds", &[], num("uptime_s")?);
        prom_type(&mut out, "cimdse_connections_total", "counter");
        prom_line(&mut out, "cimdse_connections_total", &[], num("connections")?);
        prom_type(&mut out, "cimdse_requests_total", "counter");
        if let Some(Value::Table(requests)) = v.get("requests") {
            for (op, n) in requests {
                if let Some(n) = n.as_f64() {
                    prom_line(&mut out, "cimdse_requests_total", &[("op", op)], n);
                }
            }
        }
        prom_type(&mut out, "cimdse_error_frames_total", "counter");
        prom_line(&mut out, "cimdse_error_frames_total", &[], num("error_frames")?);
        prom_type(&mut out, "cimdse_request_duration_seconds", "histogram");
        prom_hist(&mut out, "cimdse_request_duration_seconds", &[], v.get("latency"))?;
        prom_type(&mut out, "cimdse_op_duration_seconds", "histogram");
        if let Some(Value::Table(ops)) = v.get("ops") {
            for (op, outcomes) in ops {
                if let Value::Table(outcomes) = outcomes {
                    for (outcome, h) in outcomes {
                        prom_hist(
                            &mut out,
                            "cimdse_op_duration_seconds",
                            &[("op", op), ("outcome", outcome)],
                            Some(h),
                        )?;
                    }
                }
            }
        }
        prom_type(&mut out, "cimdse_stage_duration_seconds", "histogram");
        if let Some(Value::Table(stages)) = v.get("stages") {
            for (stage, h) in stages {
                prom_hist(&mut out, "cimdse_stage_duration_seconds", &[("stage", stage)], Some(h))?;
            }
        }
        for (key, name) in [
            ("cache.hits", "cimdse_cache_hits_total"),
            ("cache.misses", "cimdse_cache_misses_total"),
            ("cache.evictions", "cimdse_cache_evictions_total"),
            ("cache.entries", "cimdse_cache_entries"),
            ("work.chunks", "cimdse_work_chunks_total"),
            ("work.points", "cimdse_work_points_total"),
            ("work.cancelled", "cimdse_work_cancelled_total"),
        ] {
            if let Some(x) = v.get(key).and_then(Value::as_f64) {
                let kind = if name.ends_with("_total") { "counter" } else { "gauge" };
                prom_type(&mut out, name, kind);
                prom_line(&mut out, name, &[], x);
            }
        }
        if let Some(peak) = v.get("write_queue_peak_bytes").and_then(Value::as_f64) {
            prom_type(&mut out, "cimdse_write_queue_peak_bytes", "gauge");
            prom_line(&mut out, "cimdse_write_queue_peak_bytes", &[], peak);
        }
        if let Some(per_worker) = v.get("pool.per_worker").and_then(Value::as_array) {
            prom_type(&mut out, "cimdse_pool_chunks_total", "counter");
            prom_type(&mut out, "cimdse_pool_steals_total", "counter");
            prom_type(&mut out, "cimdse_pool_idle_seconds_total", "counter");
            for (i, w) in per_worker.iter().enumerate() {
                let worker = format!("{i}");
                for (key, name) in [
                    ("chunks", "cimdse_pool_chunks_total"),
                    ("steals", "cimdse_pool_steals_total"),
                    ("idle_s", "cimdse_pool_idle_seconds_total"),
                ] {
                    if let Some(x) = w.get(key).and_then(Value::as_f64) {
                        prom_line(&mut out, name, &[("worker", &worker)], x);
                    }
                }
            }
        }
        Ok(out)
    }
}

/// The global pool's profiling counters as a `metrics` payload table.
/// Always present (both cores use [`crate::exec::Pool::global`] for
/// sweep/shard folds), so cross-core `metrics` frames stay
/// shape-identical.
fn pool_stats_value() -> Value {
    let stats = crate::exec::Pool::global().stats();
    let (mut chunks, mut steals, mut idle_ns) = (0u64, 0u64, 0u64);
    let mut per_worker = Vec::new();
    for w in &stats.workers {
        chunks += w.chunks;
        steals += w.steals;
        idle_ns += w.idle_ns;
        let mut t = BTreeMap::new();
        t.insert("chunks".to_string(), Value::Number(w.chunks as f64));
        t.insert("steals".to_string(), Value::Number(w.steals as f64));
        t.insert("idle_s".to_string(), Value::Number(w.idle_ns as f64 / 1e9));
        per_worker.push(Value::Table(t));
    }
    let mut map = BTreeMap::new();
    map.insert("workers".to_string(), Value::Number(stats.workers.len() as f64));
    map.insert("chunks".to_string(), Value::Number(chunks as f64));
    map.insert("steals".to_string(), Value::Number(steals as f64));
    map.insert("idle_s".to_string(), Value::Number(idle_ns as f64 / 1e9));
    map.insert("per_worker".to_string(), Value::Array(per_worker));
    Value::Table(map)
}

/// One `# TYPE` comment line of the exposition.
fn prom_type(out: &mut String, name: &str, kind: &str) {
    out.push_str(&format!("# TYPE {name} {kind}\n"));
}

/// One sample line: `name{label="v",...} value`. Values are printed in
/// scientific notation (an explicit float format, which every
/// Prometheus parser accepts).
fn prom_line(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, val)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{val}\""));
        }
        out.push('}');
    }
    out.push_str(&format!(" {value:e}\n"));
}

/// Emit one histogram (from its `metrics`-payload table) as cumulative
/// `_bucket{le=...}` lines plus `_sum`/`_count`. The payload carries
/// non-empty buckets only; the mandatory `le="+Inf"` closing bucket is
/// synthesized from the exact total count.
fn prom_hist(out: &mut String, name: &str, labels: &[(&str, &str)], h: Option<&Value>) -> Result<()> {
    let Some(h) = h else {
        return Ok(());
    };
    let count = h.require_f64("count")?;
    let sum = h.require_f64("sum_s")?;
    let mut cum = 0.0;
    if let Some(buckets) = h.get("buckets").and_then(Value::as_array) {
        for b in buckets {
            // Rows without `le_s` are the overflow bucket (+inf): covered
            // by the synthesized closing bucket below.
            let Some(le) = b.get("le_s").and_then(Value::as_f64) else {
                continue;
            };
            cum += b.require_f64("count")?;
            let le = format!("{le:e}");
            let mut lab = labels.to_vec();
            lab.push(("le", le.as_str()));
            prom_line(out, &format!("{name}_bucket"), &lab, cum);
        }
    }
    let mut lab = labels.to_vec();
    lab.push(("le", "+Inf"));
    prom_line(out, &format!("{name}_bucket"), &lab, count);
    prom_line(out, &format!("{name}_sum"), labels, sum);
    prom_line(out, &format!("{name}_count"), labels, count);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> CacheStats {
        CacheStats { hits: 3, misses: 2, evictions: 1, collisions: 0, entries: 2, capacity: 8 }
    }

    #[test]
    fn snapshot_counts_and_quantiles() {
        let m = ServiceMetrics::new();
        m.connection_opened();
        m.connection_opened();
        for i in 0..100 {
            m.record_request("eval", (i + 1) as f64 * 1e-3);
        }
        m.record_request("sweep", 0.5);
        m.record_error_frame(Some("eval"), 1e-4);
        let v = m.snapshot(&stats());
        assert_eq!(v.require_f64("requests_total").unwrap(), 101.0);
        assert_eq!(v.require_f64("requests.eval").unwrap(), 100.0);
        assert_eq!(v.require_f64("requests.sweep").unwrap(), 1.0);
        assert_eq!(v.require_f64("connections").unwrap(), 2.0);
        assert_eq!(v.require_f64("error_frames").unwrap(), 1.0);
        assert_eq!(v.require_f64("cache.hits").unwrap(), 3.0);
        let p50 = v.require_f64("latency.p50_s").unwrap();
        let p99 = v.require_f64("latency.p99_s").unwrap();
        assert!(p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}");
        assert!(v.require_f64("uptime_s").unwrap() >= 0.0);
        // Histograms never evict: samples == recorded == every frame,
        // including the error frame.
        assert_eq!(v.require_f64("latency.samples").unwrap(), 102.0);
        assert_eq!(v.require_f64("latency.recorded").unwrap(), 102.0);
        // The pool table is always present and shape-stable.
        assert!(v.require_f64("pool.workers").unwrap() >= 1.0);
    }

    /// Regression test (the old ring counted successes only): error and
    /// cancelled frames feed the latency histograms under their own
    /// outcome label.
    #[test]
    fn error_and_cancelled_frames_feed_latency() {
        let m = ServiceMetrics::new();
        m.record_request("eval", 1e-3);
        m.record_error_frame(None, 2e-3);
        m.record_error_frame(Some("sweep"), 3e-3);
        m.record_cancelled_frame(Some("sweep"), 4e-3);
        let v = m.snapshot(&stats());
        // 1 ok + 2 errors + 1 cancelled, all in the overall histogram.
        assert_eq!(v.require_f64("latency.samples").unwrap(), 4.0);
        // A cancellation is an error frame on the wire.
        assert_eq!(v.require_f64("error_frames").unwrap(), 3.0);
        assert_eq!(v.require_f64("work.cancelled").unwrap(), 1.0);
        // Per-op/outcome breakdown: op-less rejects land under "unknown".
        assert_eq!(v.require_f64("ops.eval.ok.count").unwrap(), 1.0);
        assert_eq!(v.require_f64("ops.unknown.error.count").unwrap(), 1.0);
        assert_eq!(v.require_f64("ops.sweep.error.count").unwrap(), 1.0);
        assert_eq!(v.require_f64("ops.sweep.cancelled.count").unwrap(), 1.0);
        // Only successes count toward `requests`.
        assert_eq!(v.require_f64("requests_total").unwrap(), 1.0);
    }

    #[test]
    fn stage_histograms_accumulate() {
        let m = ServiceMetrics::new();
        m.record_stage("parse", 1e-6);
        m.record_stage("parse", 2e-6);
        m.record_stage("compute", 5e-3);
        let v = m.snapshot(&stats());
        assert_eq!(v.require_f64("stages.parse.count").unwrap(), 2.0);
        assert_eq!(v.require_f64("stages.compute.count").unwrap(), 1.0);
        let text = ServiceMetrics::render(&v).unwrap();
        assert!(text.contains("stages          "), "{text}");
        assert!(text.contains("parse p50"), "{text}");
    }

    #[test]
    fn work_and_backpressure_counters_accumulate() {
        let m = ServiceMetrics::new();
        m.record_chunk(64);
        m.record_chunk(64);
        m.record_chunk(8);
        m.record_cancelled_frame(Some("sweep"), 1e-3);
        m.note_write_queue_peak(1024);
        m.note_write_queue_peak(512); // lower: peak must not regress
        let v = m.snapshot(&stats());
        assert_eq!(v.require_f64("work.chunks").unwrap(), 3.0);
        assert_eq!(v.require_f64("work.points").unwrap(), 136.0);
        assert_eq!(v.require_f64("work.cancelled").unwrap(), 1.0);
        assert_eq!(v.require_f64("write_queue_peak_bytes").unwrap(), 1024.0);
        let text = ServiceMetrics::render(&v).unwrap();
        assert!(text.contains("work            3 chunks, 136 points, 1 cancelled"), "{text}");
        assert!(text.contains("write queue     1024 bytes peak"), "{text}");
    }

    #[test]
    fn render_is_stable_and_complete() {
        let m = ServiceMetrics::new();
        m.record_request("eval", 1e-3);
        m.record_request("eval", 2e-3);
        let text = ServiceMetrics::render(&m.snapshot(&stats())).unwrap();
        assert!(text.contains("cimdse service metrics"), "{text}");
        assert!(text.contains("requests        2 total (eval 2)"), "{text}");
        assert!(text.contains("cache           3 hits, 2 misses"), "{text}");
        assert!(text.contains("latency         p50"), "{text}");
        assert!(text.contains("pool            "), "{text}");
        // Renders an empty snapshot too (no latency samples).
        let empty = ServiceMetrics::new();
        let text =
            ServiceMetrics::render(&empty.snapshot(&CacheStats::default())).unwrap();
        assert!(text.contains("(no samples yet)"), "{text}");
    }

    #[test]
    fn prometheus_exposition_is_wellformed() {
        let m = ServiceMetrics::new();
        m.record_request("eval", 1e-3);
        m.record_request("eval", 8e-3);
        m.record_error_frame(None, 1e-5);
        m.record_stage("parse", 1e-6);
        m.record_chunk(16);
        let text = ServiceMetrics::render_prometheus(&m.snapshot(&stats())).unwrap();
        assert!(text.contains("# TYPE cimdse_request_duration_seconds histogram"), "{text}");
        assert!(text.contains("cimdse_requests_total{op=\"eval\"} 2e0"), "{text}");
        assert!(text.contains("le=\"+Inf\"} 3e0"), "{text}");
        assert!(text.contains("cimdse_request_duration_seconds_count 3e0"), "{text}");
        assert!(text.contains("op=\"unknown\",outcome=\"error\""), "{text}");
        assert!(text.contains("stage=\"parse\""), "{text}");
        assert!(text.contains("cimdse_work_chunks_total 1e0"), "{text}");
        assert!(text.contains("cimdse_pool_chunks_total{worker=\"0\"}"), "{text}");
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(!series.is_empty(), "{line}");
            assert!(value == "+Inf" || value.parse::<f64>().is_ok(), "{line}");
        }
        // Bucket lines are cumulative: the +Inf bucket equals _count.
        let inf: f64 = text
            .lines()
            .find(|l| l.starts_with("cimdse_request_duration_seconds_bucket") && l.contains("+Inf"))
            .and_then(|l| l.rsplit_once(' '))
            .map(|(_, v)| v.parse().unwrap())
            .unwrap();
        assert_eq!(inf, 3.0);
    }
}
