//! Cross-machine sweep scale-out: a work-queue scheduler that leases
//! shards of one sweep to `cimdse serve` workers over the `shard`
//! protocol op, survives worker failure, and merges the artifacts
//! bit-identically to the single-process streaming rollup.
//!
//! ## Scheduling
//!
//! [`run_distributed_sweep`] plans the grid into `n_shards` disjoint
//! index sub-ranges ([`ShardPlan`]) and spawns one connection thread
//! per worker address. Each thread leases shards from a shared queue
//! with *affinity first, stealing second*: a worker prefers shards
//! pre-assigned to it round-robin (`index % n_workers`), may always
//! take a shard another worker already failed (its owner is suspect)
//! or whose owner has provably started leasing (a live owner still
//! completes what it holds), and falls back to stealing anything
//! pending after a short grace period — so a healthy worker is never
//! starved of its first shard by a faster peer racing it to the
//! queue, while a dead worker's backlog drains onto the survivors
//! within milliseconds of the first failure.
//!
//! ## Fault model
//!
//! Every way a worker can disappoint — refused connection, death
//! mid-shard (EOF), a response that times out ([`LaunchOptions::read_timeout`],
//! an **inter-frame liveness** bound now that connections negotiate
//! protocol v2 and busy workers heartbeat), a typed error frame (e.g.
//! `over-budget`), or a *corrupted artifact* (the client re-validates
//! fingerprint, planned range, and the payload checksum, so even one
//! flipped bit is caught) — is handled the same way: the shard goes
//! back on the queue for someone else, the worker's failure streak
//! grows, and a worker that fails
//! [`LaunchOptions::worker_failure_limit`] times in a row is retired.
//! Abandoning a worker always drops its connection, and an event-loop
//! worker cancels that connection's in-flight shard on disconnect —
//! a retired worker's pool stops burning cycles on work nobody will
//! read.
//! A shard that fails [`LaunchOptions::max_attempts`] times, or the
//! retirement of the last worker with shards still pending, fails the
//! whole launch with a typed error — a distributed sweep either
//! produces the exact single-process bytes or says loudly why not.
//!
//! ## Resume
//!
//! With an artifact directory ([`LaunchOptions::out_dir`]), completed
//! shards are written as `shard_<i>.json` (the `cimdse sweep --shard`
//! convention, [`artifact_file_name`]) *before* they count as
//! done, and a re-run probes each path with
//! [`ShardArtifact::load_if_complete`] — same fingerprint + range ⇒
//! skipped, exactly like the single-machine resume semantics.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::adc::AdcModel;
use crate::config::Value;
use crate::dse::shard::artifact_file_name;
use crate::dse::{
    MergedSweep, ShardArtifact, ShardPlan, ShardSelector, SnrContext, SweepSpec, merge_shards,
    sweep_fingerprint_with,
};
use crate::error::{Error, Result};
use crate::stats::quantile;

use super::client::Client;

/// How long a worker with an empty affinity backlog waits before
/// stealing a pristine shard owned by a peer that has not failed yet.
/// Long enough for every healthy peer thread to lease its first shard,
/// short enough to be invisible next to real sweep work.
const STEAL_GRACE: Duration = Duration::from_millis(50);

/// Idle poll interval while other workers hold all remaining shards.
const LEASE_POLL: Duration = Duration::from_millis(10);

/// Configuration for [`run_distributed_sweep`].
#[derive(Clone, Debug)]
pub struct LaunchOptions {
    /// Worker daemon addresses (`host:port`). Duplicates are allowed —
    /// two entries for one daemon just open two connections.
    pub workers: Vec<String>,
    /// How many shards to plan the grid into. More shards than workers
    /// (the CLI defaults to 4x) keeps the fleet load-balanced and makes
    /// lost work cheap to redo.
    pub n_shards: usize,
    /// Directory for `shard_<i>.json` artifacts: written as shards
    /// complete, probed for resume on the next run. `None` keeps
    /// everything in memory.
    pub out_dir: Option<PathBuf>,
    /// Per-request I/O deadline (connect/read/write) on worker
    /// connections; a worker that goes silent past it forfeits the
    /// shard. Every fresh connection negotiates protocol v2, and
    /// event-loop workers stream `keepalive`/`progress` frames during
    /// compute — each one re-arms this deadline, so against a v2
    /// worker it is a pure **inter-frame liveness bound**: a shard may
    /// compute for minutes as long as the worker keeps heartbeating.
    /// Only against a v1-era worker (or the `threads` core, which
    /// stays silent while computing) does the deadline still bound
    /// server-side compute time. `None` trusts workers to always
    /// answer — only sensible interactively.
    pub read_timeout: Option<Duration>,
    /// A shard failing this many times (across all workers) fails the
    /// launch.
    pub max_attempts: usize,
    /// Consecutive failures after which a worker is retired for the
    /// rest of the launch.
    pub worker_failure_limit: usize,
    /// Compute-SNR objective context: `Some(ctx)` runs the whole fleet
    /// tri-objective (`energy,area,snr`), and the launch fingerprint —
    /// hence resume probing — covers the context, so a tri-objective
    /// re-run never accepts classic artifacts from a previous run (or
    /// vice versa). `None` is the classic byte-identical launch.
    pub snr: Option<SnrContext>,
}

impl LaunchOptions {
    /// Options with production-shaped defaults: a 60 s I/O deadline, a
    /// 3-strike worker retirement, and a per-shard attempt cap sized so
    /// every worker can strike out on a shard before the launch gives
    /// up.
    pub fn new(workers: Vec<String>, n_shards: usize) -> LaunchOptions {
        let worker_failure_limit = 3;
        LaunchOptions {
            max_attempts: workers.len().max(1) * worker_failure_limit + 1,
            workers,
            n_shards,
            out_dir: None,
            read_timeout: Some(Duration::from_secs(60)),
            worker_failure_limit,
            snr: None,
        }
    }
}

/// Per-worker accounting, reported by [`LaunchReport::workers`].
#[derive(Clone, Debug)]
pub struct WorkerReport {
    /// The worker's address as given in [`LaunchOptions::workers`].
    pub addr: String,
    /// Shards this worker completed successfully.
    pub shards_served: usize,
    /// Failed shard attempts charged to this worker (connect errors,
    /// EOFs, timeouts, error frames, rejected artifacts).
    pub failures: usize,
    /// Whether the worker was retired for hitting
    /// [`LaunchOptions::worker_failure_limit`].
    pub retired: bool,
    /// Wall-clock seconds per completed shard (request to validated
    /// artifact), in completion order.
    pub latencies_s: Vec<f64>,
}

impl WorkerReport {
    fn new(addr: &str) -> WorkerReport {
        WorkerReport {
            addr: addr.to_string(),
            shards_served: 0,
            failures: 0,
            retired: false,
            latencies_s: Vec::new(),
        }
    }

    /// Linear-interpolated latency quantile over this worker's completed
    /// shards (`None` if it completed none).
    pub fn latency_quantile_s(&self, q: f64) -> Option<f64> {
        (!self.latencies_s.is_empty()).then(|| quantile(&self.latencies_s, q))
    }
}

/// What [`run_distributed_sweep`] hands back: the merged sweep (its
/// summary byte-identical to [`crate::dse::SweepSummary::compute`]) plus
/// scheduler observability.
#[derive(Clone, Debug)]
pub struct LaunchReport {
    /// The complete merged sweep.
    pub merged: MergedSweep,
    /// Per-worker accounting, in [`LaunchOptions::workers`] order.
    pub workers: Vec<WorkerReport>,
    /// Shards the grid was planned into.
    pub n_shards: usize,
    /// Shards skipped because a valid artifact was already on disk.
    pub resumed: usize,
    /// Shards computed by workers this run.
    pub computed: usize,
    /// Shard attempts that failed and were requeued onto the fleet.
    pub retries: u64,
}

impl LaunchReport {
    /// The report as a JSON-serializable [`Value`] (all numbers finite),
    /// for `cimdse sweep --workers ... --launch-json`.
    pub fn to_value(&self) -> Value {
        let mut workers = Vec::with_capacity(self.workers.len());
        for w in &self.workers {
            let mut t = std::collections::BTreeMap::new();
            t.insert("addr".to_string(), Value::String(w.addr.clone()));
            t.insert("shards".to_string(), Value::Number(w.shards_served as f64));
            t.insert("failures".to_string(), Value::Number(w.failures as f64));
            t.insert("retired".to_string(), Value::Bool(w.retired));
            if let (Some(p50), Some(p99)) =
                (w.latency_quantile_s(0.50), w.latency_quantile_s(0.99))
            {
                t.insert("latency_p50_s".to_string(), Value::Number(p50));
                t.insert("latency_p99_s".to_string(), Value::Number(p99));
            }
            workers.push(Value::Table(t));
        }
        let mut map = std::collections::BTreeMap::new();
        map.insert("kind".to_string(), Value::String("cimdse-launch-report".to_string()));
        map.insert("fingerprint".to_string(), Value::String(self.merged.fingerprint.clone()));
        map.insert("points".to_string(), Value::Number(self.merged.total as f64));
        map.insert("n_shards".to_string(), Value::Number(self.n_shards as f64));
        map.insert("resumed".to_string(), Value::Number(self.resumed as f64));
        map.insert("computed".to_string(), Value::Number(self.computed as f64));
        map.insert("retries".to_string(), Value::Number(self.retries as f64));
        map.insert("workers".to_string(), Value::Array(workers));
        Value::Table(map)
    }
}

/// Where shard `index`'s artifact lives under `dir`.
pub fn artifact_path(dir: &Path, index: usize) -> PathBuf {
    dir.join(artifact_file_name(index))
}

/// Shared scheduler state. Invariant:
/// `completed + in_flight + pending.len() == n_shards` at every lock
/// release, so `pending` empty + nothing in flight ⇔ all shards done.
struct LaunchState {
    pending: VecDeque<usize>,
    attempts: Vec<usize>,
    /// `leased_once[w]`: worker `w` has taken at least one lease — it is
    /// alive, so its pristine backlog is safe to steal (a healthy owner
    /// still completes whatever it already holds).
    leased_once: Vec<bool>,
    artifacts: Vec<Option<ShardArtifact>>,
    completed: usize,
    in_flight: usize,
    active_workers: usize,
    retries: u64,
    failed: Option<String>,
}

enum Lease {
    Shard(usize),
    Wait,
    Done,
}

/// Lease the next shard for worker `w`: own (round-robin) shards first;
/// then any foreign shard that is fair game — its owner already failed
/// an attempt, or has provably started leasing (so stealing its backlog
/// cannot starve it of its first shard), or the [`STEAL_GRACE`]
/// fallback has passed.
fn lease(state: &Mutex<LaunchState>, w: usize, n_workers: usize, started: Instant) -> Lease {
    let mut s = state.lock().unwrap();
    if s.failed.is_some() || s.completed == s.artifacts.len() {
        return Lease::Done;
    }
    let grace_over = started.elapsed() >= STEAL_GRACE;
    let position = s.pending.iter().position(|&i| i % n_workers == w).or_else(|| {
        s.pending.iter().position(|&i| {
            s.attempts[i] > 0 || s.leased_once[i % n_workers] || grace_over
        })
    });
    match position {
        Some(pos) => {
            let i = s.pending.remove(pos).expect("position is in bounds");
            s.in_flight += 1;
            s.leased_once[w] = true;
            Lease::Shard(i)
        }
        None => Lease::Wait,
    }
}

fn complete(state: &Mutex<LaunchState>, index: usize, artifact: ShardArtifact) {
    let mut s = state.lock().unwrap();
    s.in_flight -= 1;
    debug_assert!(s.artifacts[index].is_none(), "shard {index} completed twice");
    s.artifacts[index] = Some(artifact);
    s.completed += 1;
}

/// Requeue a failed shard (or fail the launch once it has burned
/// `max_attempts`).
fn requeue(state: &Mutex<LaunchState>, index: usize, error: &Error, options: &LaunchOptions) {
    let mut s = state.lock().unwrap();
    s.in_flight -= 1;
    s.retries += 1;
    s.attempts[index] += 1;
    if s.attempts[index] >= options.max_attempts {
        if s.failed.is_none() {
            s.failed = Some(format!(
                "shard {index} failed {} attempts across the fleet; last error: {error}",
                s.attempts[index]
            ));
        }
    } else {
        s.pending.push_back(index);
    }
}

/// A fatal local problem (e.g. the artifact directory went read-only):
/// no point retrying on another worker.
fn fail_launch(state: &Mutex<LaunchState>, message: String) {
    let mut s = state.lock().unwrap();
    if s.failed.is_none() {
        s.failed = Some(message);
    }
}

/// Worker-thread exit bookkeeping; the last worker out with shards
/// still pending turns the stall into a typed launch failure.
fn worker_exited(state: &Mutex<LaunchState>) {
    let mut s = state.lock().unwrap();
    s.active_workers -= 1;
    if s.active_workers == 0 && s.completed < s.artifacts.len() && s.failed.is_none() {
        let remaining: Vec<String> = (0..s.artifacts.len())
            .filter(|&i| s.artifacts[i].is_none())
            .map(|i| i.to_string())
            .collect();
        s.failed = Some(format!(
            "every worker was retired with shards {} still incomplete — workers \
             dead/unreachable, the fleet kept returning bad artifacts, or workers \
             went silent past the I/O deadline (v2 workers heartbeat while busy, \
             so raise --timeout-ms only for v1/threads-core fleets, or increase \
             --shards so shards shrink)",
            remaining.join(", ")
        ));
    }
}

/// One leased shard against one worker: (re)connect, request, validate.
#[allow(clippy::too_many_arguments)]
fn run_one(
    client: &mut Option<Client>,
    addr: &str,
    spec: &SweepSpec,
    model: &AdcModel,
    plan: &ShardPlan,
    fingerprint: &str,
    index: usize,
    options: &LaunchOptions,
    trace: Option<&Value>,
) -> Result<ShardArtifact> {
    if client.is_none() {
        let mut fresh = Client::connect_with_timeout(addr, options.read_timeout)?;
        // Negotiate v2 so the worker streams keepalive/progress frames
        // while it computes: the client skips them, but every one
        // re-arms the read deadline, turning `read_timeout` into a
        // liveness bound instead of a compute bound. A v1-era worker
        // answers `hello` with a typed error frame — the connection is
        // still usable, it just stays silent-while-computing.
        let _ = fresh.negotiate_v2();
        *client = Some(fresh);
    }
    let selector = ShardSelector::new(index, plan.n_shards())?;
    let artifact = client
        .as_mut()
        .expect("connected above")
        .shard_traced_with(spec, Some(model), selector, trace, options.snr.as_ref())?;
    // `Client::shard` already validated the artifact against itself
    // (fingerprint vs embedded spec/model, range vs plan, payload
    // checksum); these two checks pin it to *this* sweep and *this*
    // shard, so a confused worker answering for some other job is a
    // typed failure, not a merge-time surprise.
    if artifact.fingerprint() != fingerprint {
        return Err(Error::Runtime(format!(
            "worker {addr} answered shard {selector} with an artifact for a different \
             sweep (fingerprint {}, want {fingerprint})",
            artifact.fingerprint()
        )));
    }
    if artifact.range() != plan.range(index) {
        return Err(Error::Runtime(format!(
            "worker {addr} answered shard {selector} with range {:?}, want {:?}",
            artifact.range(),
            plan.range(index)
        )));
    }
    Ok(artifact)
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    w: usize,
    addr: &str,
    spec: &SweepSpec,
    model: &AdcModel,
    plan: &ShardPlan,
    fingerprint: &str,
    options: &LaunchOptions,
    state: &Mutex<LaunchState>,
    report: &Mutex<WorkerReport>,
    started: Instant,
    launch_ctx: Option<crate::obs::TraceCtx>,
) {
    let n_workers = options.workers.len();
    let mut client: Option<Client> = None;
    let mut consecutive = 0usize;
    loop {
        let index = match lease(state, w, n_workers, started) {
            Lease::Done => break,
            Lease::Wait => {
                std::thread::sleep(LEASE_POLL);
                continue;
            }
            Lease::Shard(i) => i,
        };
        let shard_started = Instant::now();
        // One "shard" span per lease attempt, under the launch root.
        // Its context rides the request frame (`trace`), so the worker's
        // serving span — and that worker's pool chunk spans — parent
        // here, stitching the fleet into one cross-process forest.
        let mut shard_span = launch_ctx.map(|ctx| {
            let mut s = crate::obs::child_span("shard", ctx);
            s.attr("index", Value::Number(index as f64));
            s.attr("worker", Value::String(addr.to_string()));
            s
        });
        let trace = shard_span.as_ref().map(|s| s.ctx().to_value());
        let outcome = run_one(
            &mut client, addr, spec, model, plan, fingerprint, index, options,
            trace.as_ref(),
        );
        if let Some(s) = shard_span.as_mut() {
            s.attr("ok", Value::Bool(outcome.is_ok()));
        }
        drop(shard_span);
        match outcome {
            Ok(artifact) => {
                // Persist before counting the shard complete, so a
                // launcher killed between the two leaves a resumable
                // artifact rather than a phantom completion.
                if let Some(dir) = &options.out_dir {
                    let path = artifact_path(dir, index);
                    if let Err(e) = artifact.write(&path.to_string_lossy()) {
                        fail_launch(state, format!("cannot persist shard {index}: {e}"));
                        break;
                    }
                }
                let mut r = report.lock().unwrap();
                r.shards_served += 1;
                r.latencies_s.push(shard_started.elapsed().as_secs_f64());
                drop(r);
                consecutive = 0;
                complete(state, index, artifact);
            }
            Err(e) => {
                // Whatever went wrong, the connection's framing can no
                // longer be trusted; reconnect for the next attempt.
                client = None;
                consecutive += 1;
                report.lock().unwrap().failures += 1;
                requeue(state, index, &e, options);
                if consecutive >= options.worker_failure_limit {
                    report.lock().unwrap().retired = true;
                    break;
                }
            }
        }
    }
    worker_exited(state);
}

/// Run `spec` as a distributed sweep across the worker fleet and merge
/// the result. On success the merged summary is **byte-identical** to
/// the single-process [`crate::dse::SweepSummary::compute`] over the
/// same spec and model — shard artifacts are bit-exact and
/// [`merge_shards`] is order-independent, so neither which worker
/// computed a shard nor the order results arrived can leak into the
/// output (asserted under every injected fault by
/// `tests/launcher_faults.rs`).
pub fn run_distributed_sweep(
    spec: &SweepSpec,
    model: &AdcModel,
    options: &LaunchOptions,
) -> Result<LaunchReport> {
    if options.workers.is_empty() {
        return Err(Error::Config(
            "distributed sweep needs at least one worker address".into(),
        ));
    }
    if options.max_attempts == 0 || options.worker_failure_limit == 0 {
        return Err(Error::Config(
            "max_attempts and worker_failure_limit must be >= 1".into(),
        ));
    }
    let plan = ShardPlan::new(spec, options.n_shards)?;
    if let Some(ctx) = &options.snr {
        ctx.validate()?;
    }
    // Objective-aware fingerprint: resume probing and worker-response
    // validation both pin artifacts to this sweep *and* this objective
    // set/context.
    let fingerprint = sweep_fingerprint_with(spec, model, options.snr.as_ref());
    let mut artifacts: Vec<Option<ShardArtifact>> = vec![None; plan.n_shards()];
    let mut resumed = 0usize;
    if let Some(dir) = &options.out_dir {
        std::fs::create_dir_all(dir).map_err(|e| {
            Error::Config(format!("cannot create artifact dir {}: {e}", dir.display()))
        })?;
        for (i, slot) in artifacts.iter_mut().enumerate() {
            let path = artifact_path(dir, i);
            if let Some(artifact) = ShardArtifact::load_if_complete(
                &path.to_string_lossy(),
                &fingerprint,
                &plan.range(i),
            ) {
                *slot = Some(artifact);
                resumed += 1;
            }
        }
    }
    let pending: VecDeque<usize> =
        (0..plan.n_shards()).filter(|&i| artifacts[i].is_none()).collect();
    let computed = pending.len();
    let state = Mutex::new(LaunchState {
        pending,
        attempts: vec![0; plan.n_shards()],
        leased_once: vec![false; options.workers.len()],
        artifacts,
        completed: resumed,
        in_flight: 0,
        active_workers: options.workers.len(),
        retries: 0,
        failed: None,
    });
    let reports: Vec<Mutex<WorkerReport>> =
        options.workers.iter().map(|a| Mutex::new(WorkerReport::new(a))).collect();
    if computed > 0 {
        let started = Instant::now();
        // The root of the fleet's trace forest: held across the whole
        // scope so its duration is the launch wall time. Every worker
        // thread parents its shard spans here.
        let mut launch_span = crate::obs::span("launch");
        launch_span.attr("n_shards", Value::Number(plan.n_shards() as f64));
        launch_span.attr("workers", Value::Number(options.workers.len() as f64));
        launch_span.attr("resumed", Value::Number(resumed as f64));
        let launch_ctx = launch_span.is_recording().then(|| launch_span.ctx());
        std::thread::scope(|scope| {
            for (w, addr) in options.workers.iter().enumerate() {
                let (state, report) = (&state, &reports[w]);
                let (plan, fingerprint) = (&plan, fingerprint.as_str());
                scope.spawn(move || {
                    worker_loop(
                        w, addr, spec, model, plan, fingerprint, options, state, report,
                        started, launch_ctx,
                    );
                });
            }
        });
        drop(launch_span);
    }
    let state = state.into_inner().expect("no worker thread panicked");
    if let Some(message) = state.failed {
        return Err(Error::Runtime(format!("distributed sweep failed: {message}")));
    }
    debug_assert_eq!(state.completed, plan.n_shards());
    let all: Vec<ShardArtifact> = state
        .artifacts
        .into_iter()
        .map(|a| a.expect("completed launch has every artifact"))
        .collect();
    let merged = merge_shards(&all)?;
    debug_assert!(merged.is_complete());
    Ok(LaunchReport {
        merged,
        workers: reports
            .into_iter()
            .map(|r| r.into_inner().expect("no worker thread panicked"))
            .collect(),
        n_shards: plan.n_shards(),
        resumed,
        computed,
        retries: state.retries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_paths_follow_the_shard_convention() {
        let dir = PathBuf::from("/tmp/sweep");
        assert_eq!(artifact_path(&dir, 0), PathBuf::from("/tmp/sweep/shard_0.json"));
        assert_eq!(artifact_path(&dir, 17), PathBuf::from("/tmp/sweep/shard_17.json"));
    }

    #[test]
    fn options_default_attempt_cap_scales_with_the_fleet() {
        let o = LaunchOptions::new(vec!["a:1".into(), "b:2".into()], 8);
        assert_eq!(o.worker_failure_limit, 3);
        assert_eq!(o.max_attempts, 7, "2 workers x 3 strikes + 1");
        assert!(o.read_timeout.is_some());
    }

    #[test]
    fn empty_fleet_and_zero_limits_are_typed_errors() {
        let spec = SweepSpec {
            enobs: vec![4.0],
            total_throughputs: vec![1e9],
            tech_nms: vec![32.0],
            n_adcs: vec![1],
        };
        let model = AdcModel::default();
        let err = run_distributed_sweep(&spec, &model, &LaunchOptions::new(vec![], 2));
        assert!(matches!(err, Err(Error::Config(_))), "{err:?}");
        let mut o = LaunchOptions::new(vec!["a:1".into()], 2);
        o.max_attempts = 0;
        assert!(matches!(
            run_distributed_sweep(&spec, &model, &o),
            Err(Error::Config(_))
        ));
        // Zero shards is the shard planner's typed error.
        let o = LaunchOptions::new(vec!["a:1".into()], 0);
        assert!(matches!(
            run_distributed_sweep(&spec, &model, &o),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn lease_prefers_affinity_then_failed_or_live_owner_then_grace() {
        let state = Mutex::new(LaunchState {
            pending: VecDeque::from([0, 1, 2, 3, 4, 5]),
            attempts: vec![0; 6],
            leased_once: vec![false; 2],
            artifacts: vec![None; 6],
            completed: 0,
            in_flight: 0,
            active_workers: 2,
            retries: 0,
            failed: None,
        });
        let fresh = Instant::now();
        // Worker 1 of 2 owns odd indices.
        match lease(&state, 1, 2, fresh) {
            Lease::Shard(i) => assert_eq!(i, 1),
            _ => panic!("own shard must lease immediately"),
        }
        match lease(&state, 1, 2, fresh) {
            Lease::Shard(i) => assert_eq!(i, 3),
            _ => panic!("own shard must lease immediately"),
        }
        match lease(&state, 1, 2, fresh) {
            Lease::Shard(i) => assert_eq!(i, 5),
            _ => panic!("own shard must lease immediately"),
        }
        // Only pristine shards of the never-leased worker 0 remain:
        // inside the grace window worker 1 waits...
        assert!(matches!(lease(&state, 1, 2, fresh), Lease::Wait));
        // ...unless one of them has already failed once...
        state.lock().unwrap().attempts[2] = 1;
        match lease(&state, 1, 2, fresh) {
            Lease::Shard(i) => assert_eq!(i, 2),
            _ => panic!("failed foreign shard must be stealable at once"),
        }
        // ...or the owner is provably alive (has leased before) — then
        // its backlog is stealable without waiting out the grace.
        assert!(matches!(lease(&state, 1, 2, fresh), Lease::Wait));
        state.lock().unwrap().leased_once[0] = true;
        match lease(&state, 1, 2, fresh) {
            Lease::Shard(i) => assert_eq!(i, 0),
            _ => panic!("live owner's shard must be stealable"),
        }
        // ...and after the grace period everything pending is fair game.
        state.lock().unwrap().leased_once[0] = false;
        let old = Instant::now() - 10 * STEAL_GRACE;
        match lease(&state, 1, 2, old) {
            Lease::Shard(i) => assert_eq!(i, 4),
            _ => panic!("post-grace steal must lease"),
        }
        // Everything leased: Wait while in flight, Done once complete.
        assert!(matches!(lease(&state, 1, 2, old), Lease::Wait));
        {
            let mut s = state.lock().unwrap();
            s.completed = 6;
            s.in_flight = 0;
        }
        assert!(matches!(lease(&state, 1, 2, old), Lease::Done));
    }

    #[test]
    fn requeue_respects_the_attempt_cap() {
        let options = LaunchOptions::new(vec!["a:1".into()], 4);
        let state = Mutex::new(LaunchState {
            pending: VecDeque::new(),
            attempts: vec![0, 0],
            leased_once: vec![true],
            artifacts: vec![None; 2],
            completed: 0,
            in_flight: 1,
            active_workers: 1,
            retries: 0,
            failed: None,
        });
        let err = Error::Runtime("boom".into());
        for _ in 0..options.max_attempts - 1 {
            requeue(&state, 0, &err, &options);
            let mut s = state.lock().unwrap();
            assert_eq!(s.pending.pop_front(), Some(0), "under the cap: requeued");
            assert!(s.failed.is_none());
            s.in_flight += 1;
        }
        requeue(&state, 0, &err, &options);
        let s = state.lock().unwrap();
        assert!(s.pending.is_empty(), "at the cap: not requeued");
        let msg = s.failed.as_ref().expect("launch marked failed");
        assert!(msg.contains("shard 0") && msg.contains("boom"), "{msg}");
    }
}
