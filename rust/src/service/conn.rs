//! Per-connection state for the serving daemon: incremental newline
//! framing ([`FrameBuf`]), a bounded outgoing byte queue
//! ([`WriteQueue`]), and the connection state machine ([`Conn`]) the
//! event-loop core ([`super::reactor`]) drives.
//!
//! The framing logic here **is** the serving framing: the threaded
//! core's `LineReader` wraps the same [`FrameBuf`], so both cores
//! split, cap, and resynchronize byte streams identically by
//! construction — the property the cross-core byte-identity tests pin.
//!
//! ## Backpressure bounds
//!
//! A pipelining client is bounded two ways (both documented in
//! `docs/protocol.md`):
//!
//! * at most [`MAX_PIPELINE`] parsed requests may wait in the
//!   connection's FIFO queue, and
//! * at most [`WRITE_QUEUE_CAP`] response bytes may wait unsent.
//!
//! Past either bound the reactor stops reading the socket, so TCP flow
//! control pushes back on the client and per-connection server memory
//! stays O(cap) no matter how fast frames arrive or how slowly the
//! client drains responses.

use std::collections::VecDeque;
use std::io::{ErrorKind, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::config::Value;
use crate::exec::CancelToken;

use super::protocol::{MAX_FRAME_BYTES, PROTOCOL_V1, Request};

/// Upper bound on unsent response bytes queued per connection before
/// the reactor stops reading that socket (resumes once drained).
pub const WRITE_QUEUE_CAP: usize = 4 * 1024 * 1024;

/// Upper bound on parsed-but-unanswered requests queued per connection
/// before the reactor stops reading that socket.
pub const MAX_PIPELINE: usize = 64;

/// What [`FrameBuf::next_event`] hands back per complete line.
#[derive(Debug, PartialEq, Eq)]
pub enum FrameEvent {
    /// One complete frame (newline and any trailing `\r` stripped).
    Frame(Vec<u8>),
    /// A line exceeded [`MAX_FRAME_BYTES`]; its remainder is being
    /// discarded up to the next newline so the stream resynchronizes.
    Oversized,
}

/// Incremental `\n`-delimited frame splitter with a hard size cap.
///
/// Push bytes as they arrive (nonblocking reads), pop complete frames.
/// Oversized lines surface exactly once as [`FrameEvent::Oversized`]
/// and their tail is discarded up to the next newline — the same
/// resynchronization contract the v1 threaded core has always had.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Bytes of `buf` already scanned for a newline — only newly pushed
    /// bytes are searched, keeping per-frame cost linear in frame size
    /// instead of quadratic in the number of reads.
    scanned: usize,
    /// Discarding until the next newline after an oversized frame.
    discarding: bool,
}

impl FrameBuf {
    /// An empty buffer.
    pub fn new() -> FrameBuf {
        FrameBuf::default()
    }

    /// Append freshly read bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Whether [`FrameBuf::next_event`] could make progress without
    /// more bytes. The reactor uses this to keep re-pumping a
    /// connection whose buffered backlog outlives the read event that
    /// delivered it: once backpressure lifts, the leftover frames must
    /// be parsed *now* — no further socket event will arrive for bytes
    /// already consumed off the wire.
    pub fn has_frame(&self) -> bool {
        self.buf[self.scanned..].contains(&b'\n')
            || (!self.discarding && self.buf.len() > MAX_FRAME_BYTES)
    }

    /// Pop the next complete frame (or oversized marker) if one is
    /// buffered; `None` means more bytes are needed.
    pub fn next_event(&mut self) -> Option<FrameEvent> {
        loop {
            if let Some(rel) = self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
                let pos = self.scanned + rel;
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                self.scanned = 0;
                line.pop(); // the newline
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                if self.discarding {
                    self.discarding = false;
                    continue; // the tail of an oversized line
                }
                if line.len() > MAX_FRAME_BYTES {
                    // A whole oversized line arrived in one gulp: the
                    // newline is already consumed, nothing to discard.
                    return Some(FrameEvent::Oversized);
                }
                return Some(FrameEvent::Frame(line));
            }
            self.scanned = self.buf.len();
            if self.discarding {
                self.buf.clear();
                self.scanned = 0;
                return None;
            }
            if self.buf.len() > MAX_FRAME_BYTES {
                self.discarding = true;
                self.buf.clear();
                self.scanned = 0;
                return Some(FrameEvent::Oversized);
            }
            return None;
        }
    }
}

/// Bounded FIFO of outgoing response bytes with partial-write resume.
///
/// Frames are queued whole; [`WriteQueue::write_to`] sends as much as
/// the socket accepts and remembers the offset, so a nonblocking writer
/// never splits, reorders, or re-sends bytes.
#[derive(Default)]
pub struct WriteQueue {
    chunks: VecDeque<Vec<u8>>,
    /// Offset of the first unsent byte within `chunks[0]`.
    head: usize,
    /// Total unsent bytes across all chunks.
    queued: usize,
    /// High-water mark of `queued` over the queue's lifetime.
    peak: usize,
}

impl WriteQueue {
    /// An empty queue.
    pub fn new() -> WriteQueue {
        WriteQueue::default()
    }

    /// Queue one response line (newline appended).
    pub fn push_line(&mut self, line: &str) {
        let mut bytes = Vec::with_capacity(line.len() + 1);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        self.queued += bytes.len();
        self.peak = self.peak.max(self.queued);
        self.chunks.push_back(bytes);
    }

    /// Unsent bytes currently queued.
    pub fn queued_bytes(&self) -> usize {
        self.queued
    }

    /// Lifetime high-water mark of [`WriteQueue::queued_bytes`].
    pub fn peak_bytes(&self) -> usize {
        self.peak
    }

    /// Whether everything queued has been written.
    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Write queued bytes until the sink would block or the queue
    /// drains. Returns the number of bytes written this call; a sink
    /// that reports `Ok(0)` surfaces as [`ErrorKind::WriteZero`].
    pub fn write_to<W: Write>(&mut self, w: &mut W) -> std::io::Result<usize> {
        let mut sent = 0usize;
        while let Some(front) = self.chunks.front() {
            match w.write(&front[self.head..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        ErrorKind::WriteZero,
                        "connection closed mid-response",
                    ));
                }
                Ok(n) => {
                    sent += n;
                    self.head += n;
                    self.queued -= n;
                    if self.head == front.len() {
                        self.chunks.pop_front();
                        self.head = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(sent),
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(sent)
    }
}

/// A parsed request waiting its FIFO turn (or a pre-formed reply).
pub enum QueueEntry {
    /// A response line already decided at parse time (malformed JSON,
    /// oversized frame, bad request …) waiting its in-order turn so a
    /// pipelining v1 client sees the exact byte order the threaded core
    /// produces.
    Reply(String),
    /// A parsed request waiting to be answered or dispatched.
    Job(PendingJob),
}

/// One parsed request plus the bookkeeping `cancel` needs to find it.
pub struct PendingJob {
    /// The request's op name (static, from [`Request::op`]).
    pub op: &'static str,
    /// The client-supplied `id`, echoed on every frame answering it.
    pub id: Option<Value>,
    /// Canonical JSON of `id` — what a `cancel` frame's `target` must
    /// match (string `"7"` and number `7` are distinct ids, exactly as
    /// they are distinct echoes).
    pub id_key: Option<String>,
    /// The parsed request itself.
    pub request: Request,
    /// Trips when this request is cancelled (cancel frame, disconnect,
    /// or server drain); checked at chunk boundaries by the fold.
    pub cancel: CancelToken,
    /// The validated wire `trace` table, if the frame carried one —
    /// echoed on every frame answering this request and parented by the
    /// serving span.
    pub trace: Option<Value>,
    /// When the frame was parsed — the latency origin for requests
    /// answered without ever dispatching (cancelled while queued).
    pub queued_at: Instant,
}

/// The in-flight residue of a [`PendingJob`] handed to a runner thread:
/// enough to echo progress frames and to match a later `cancel`.
pub struct InFlight {
    /// Op name of the running request.
    pub op: &'static str,
    /// Canonical JSON of the running request's `id`, if any.
    pub id_key: Option<String>,
    /// The running request's cooperative cancellation token.
    pub cancel: CancelToken,
}

/// Per-connection state machine for the event-loop core.
pub struct Conn {
    /// The nonblocking socket.
    pub stream: TcpStream,
    /// Incremental inbound framing.
    pub frames: FrameBuf,
    /// Bounded outbound byte queue.
    pub out: WriteQueue,
    /// Negotiated protocol version; starts at [`PROTOCOL_V1`] and only
    /// a `hello` frame can raise it. Interim (progress/keepalive)
    /// frames are emitted iff this is ≥ 2.
    pub version: u32,
    /// Requests (and pre-formed replies) awaiting their FIFO turn.
    pub queue: VecDeque<QueueEntry>,
    /// The single request currently computing on a runner thread.
    pub in_flight: Option<InFlight>,
    /// Peer closed its write side (or a read error): no further frames
    /// will be parsed; the connection drops once `in_flight` resolves.
    pub read_closed: bool,
    /// When the last response byte chunk was queued — drives keepalive
    /// cadence for v2 connections with work in flight.
    pub last_tx: Instant,
    /// Last instant `write_to` made progress — drives the stuck-writer
    /// drop during drain.
    pub last_write_progress: Instant,
}

impl Conn {
    /// Wrap a freshly accepted (already nonblocking) socket.
    pub fn new(stream: TcpStream) -> Conn {
        let now = Instant::now();
        Conn {
            stream,
            frames: FrameBuf::new(),
            out: WriteQueue::new(),
            version: PROTOCOL_V1,
            queue: VecDeque::new(),
            in_flight: None,
            read_closed: false,
            last_tx: now,
            last_write_progress: now,
        }
    }

    /// Queue one response line and stamp the keepalive clock.
    pub fn send(&mut self, line: &str) {
        self.out.push_line(line);
        self.last_tx = Instant::now();
    }

    /// Whether backpressure says to stop reading this socket: either
    /// bound being exceeded parks the connection until the queues drain.
    pub fn throttled(&self) -> bool {
        self.out.queued_bytes() > WRITE_QUEUE_CAP || self.queue.len() >= MAX_PIPELINE
    }

    /// Trip the token of the in-flight or queued request whose `id`
    /// canonicalizes to `key`. Returns whether anything matched — a miss
    /// is the caller's `unknown-id` error (unknown, already answered,
    /// or issued by a different connection: all indistinguishable here
    /// by design).
    pub fn cancel_target(&mut self, key: &str) -> bool {
        if let Some(f) = &self.in_flight {
            if f.id_key.as_deref() == Some(key) {
                f.cancel.cancel();
                return true;
            }
        }
        for entry in &self.queue {
            if let QueueEntry::Job(job) = entry {
                if job.id_key.as_deref() == Some(key) {
                    job.cancel.cancel();
                    return true;
                }
            }
        }
        false
    }

    /// Trip every token this connection owns (disconnect / drain).
    pub fn cancel_all(&mut self) {
        if let Some(f) = &self.in_flight {
            f.cancel.cancel();
        }
        for entry in &self.queue {
            if let QueueEntry::Job(job) = entry {
                job.cancel.cancel();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(ev: Option<FrameEvent>) -> Vec<u8> {
        match ev {
            Some(FrameEvent::Frame(f)) => f,
            other => panic!("expected a frame, got {other:?}"),
        }
    }

    #[test]
    fn framebuf_splits_lines_across_arbitrary_push_boundaries() {
        let mut fb = FrameBuf::new();
        let wire = b"{\"op\": \"metrics\"}\r\n\n{\"op\": \"shutdown\"}\n";
        // Feed one byte at a time: the cruellest fragmentation.
        let mut frames = Vec::new();
        for b in wire {
            fb.push(&[*b]);
            while let Some(ev) = fb.next_event() {
                frames.push(frame(Some(ev)));
            }
        }
        assert_eq!(
            frames,
            vec![
                b"{\"op\": \"metrics\"}".to_vec(),
                Vec::new(), // the blank keep-alive line
                b"{\"op\": \"shutdown\"}".to_vec(),
            ]
        );
        assert_eq!(fb.next_event(), None);
    }

    #[test]
    fn framebuf_oversized_lines_surface_once_and_resynchronize() {
        let mut fb = FrameBuf::new();
        // Grow past the cap without a newline: Oversized fires exactly
        // once, then the tail (including more pushes) is discarded.
        fb.push(&vec![b'x'; MAX_FRAME_BYTES + 1]);
        assert_eq!(fb.next_event(), Some(FrameEvent::Oversized));
        fb.push(&vec![b'y'; 4096]);
        assert_eq!(fb.next_event(), None);
        fb.push(b"tail\n{\"ok\": 1}\n");
        // The newline ends the discard; the next line parses normally.
        assert_eq!(frame(fb.next_event()), b"{\"ok\": 1}".to_vec());

        // A whole oversized line arriving in one gulp (newline included)
        // also surfaces once, with nothing left to discard.
        let mut one = vec![b'z'; MAX_FRAME_BYTES + 1];
        one.push(b'\n');
        one.extend_from_slice(b"next\n");
        fb.push(&one);
        assert_eq!(fb.next_event(), Some(FrameEvent::Oversized));
        assert_eq!(frame(fb.next_event()), b"next".to_vec());
    }

    /// A sink that accepts at most `cap` bytes per write call and
    /// blocks after `limit` total bytes — a slow client in miniature.
    struct Throttle {
        cap: usize,
        limit: usize,
        got: Vec<u8>,
    }

    impl Write for Throttle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.got.len() >= self.limit {
                return Err(std::io::Error::new(ErrorKind::WouldBlock, "full"));
            }
            let n = buf.len().min(self.cap).min(self.limit - self.got.len());
            self.got.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_queue_resumes_partial_writes_without_reordering() {
        let mut q = WriteQueue::new();
        q.push_line("first response");
        q.push_line("second");
        assert_eq!(q.queued_bytes(), "first response\nsecond\n".len());
        assert_eq!(q.peak_bytes(), q.queued_bytes());

        let mut sink = Throttle { cap: 5, limit: 9, got: Vec::new() };
        assert_eq!(q.write_to(&mut sink).unwrap(), 9);
        assert!(!q.is_empty());

        sink.limit = usize::MAX;
        q.write_to(&mut sink).unwrap();
        assert!(q.is_empty());
        assert_eq!(sink.got, b"first response\nsecond\n");
        // Peak survives the drain.
        assert_eq!(q.peak_bytes(), "first response\nsecond\n".len());
    }

    #[test]
    fn write_queue_surfaces_closed_sinks_as_write_zero() {
        struct Closed;
        impl Write for Closed {
            fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
                Ok(0)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut q = WriteQueue::new();
        q.push_line("doomed");
        let err = q.write_to(&mut Closed).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::WriteZero);
    }
}
