//! The readiness-driven serving core: one event-loop thread multiplexes
//! every connection over `epoll(7)` (raw syscalls — the crate stays
//! zero-dependency) with a portable `poll(2)` fallback off Linux.
//!
//! ## Architecture
//!
//! * **Reactor thread** (the caller of [`serve_event_loop`]): accepts,
//!   reads nonblocking sockets into per-connection [`FrameBuf`]s,
//!   parses frames, answers cheap ops (`hello`, `eval`, `metrics`,
//!   `cancel`, `shutdown`, and every parse error) inline, and queues
//!   compute ops (`sweep`, `shard`, `accel`) per connection in strict
//!   FIFO order.
//! * **Runner threads** (a small fixed pool): pull one compute job at a
//!   time, evaluate it through the shared [`crate::exec::Pool`] under a
//!   [`FoldCtl`] carrying the job's [`CancelToken`] and a progress hook,
//!   and push the response line back over a completion queue.
//! * **Wakeup pipe** (a [`UnixStream::pair`]): runners write one byte
//!   after each completion or progress frame so the reactor's poll call
//!   returns immediately instead of waiting out its tick.
//!
//! Responses per connection are answered strictly in request order
//! (one compute job in flight per connection), so a pipelining v1
//! client observes the exact byte stream the threaded core produces.
//! The only out-of-order frame is `cancel`'s own response — answered
//! immediately, because a cancel queued behind the sweep it targets
//! would be useless — plus v2 interim `progress`/`keepalive` frames,
//! which only version-negotiated connections ever receive.
//!
//! ## Disconnect and drain
//!
//! A read of zero bytes (or any read/write error) is a disconnect: all
//! of the connection's queued and in-flight work is cancelled through
//! its tokens and the connection is dropped — an abandoned shard stops
//! burning pool cycles at its next chunk boundary. On shutdown the
//! reactor stops accepting and reading, drops undispatched pipelined
//! requests (the threaded core's long-standing semantics), lets
//! in-flight computes finish, flushes write queues, and force-drops any
//! connection whose peer stops draining for [`DRAIN_STUCK_GRACE`] — so
//! drain latency is bounded by the grace period, not by stuck clients.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpListener;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::Value;
use crate::dse::{FoldCtl, ShardPlan};
use crate::error::{Error, Result};
use crate::exec::{CancelToken, default_workers};

use super::conn::{Conn, FrameEvent, InFlight, PendingJob, QueueEntry};
use super::protocol::{
    PROTOCOL_V2, Request, error_frame, error_frame_traced, keepalive_frame, ok_frame_traced,
    progress_frame_traced,
};
use super::server::{
    ServerShared, cancelled_reject, dispatch, oversized_reject, parse_or_reply,
    unknown_id_reject,
};
pub use poller::{Event, Interest, Poller};

/// Poll tick: bounds drain-flag staleness and keepalive jitter.
const TICK: Duration = Duration::from_millis(25);

/// Minimum quiet interval before a v2 connection with work in flight is
/// sent a `keepalive` frame. Liveness deadlines (`--timeout-ms`) should
/// sit comfortably above this.
const KEEPALIVE_EVERY: Duration = Duration::from_millis(250);

/// During drain, a connection whose write queue makes no progress for
/// this long is force-dropped so stuck clients cannot delay shutdown.
const DRAIN_STUCK_GRACE: Duration = Duration::from_millis(400);

/// Poll token of the accept listener.
const TOKEN_LISTENER: u64 = 0;
/// Poll token of the wakeup pipe's read end.
const TOKEN_WAKEUP: u64 = 1;
/// First token handed to an accepted connection.
const TOKEN_FIRST_CONN: u64 = 2;

/// Compute ops that run on runner threads; everything else is answered
/// inline on the reactor.
fn is_compute(op: &str) -> bool {
    matches!(op, "sweep" | "shard" | "accel")
}

/// One compute job handed to a runner thread.
struct RunnerJob {
    conn_id: u64,
    op: &'static str,
    id: Option<Value>,
    request: Request,
    cancel: CancelToken,
    /// The connection's negotiated version when the job was dispatched —
    /// gates interim progress frames.
    version: u32,
    /// The request's validated wire `trace` table — echoed on the final
    /// response and every interim progress frame, and parented by the
    /// runner's serving span.
    trace: Option<Value>,
}

/// One line travelling back from a runner to the reactor.
struct Completion {
    conn_id: u64,
    line: String,
    /// `true` for the final response (clears the connection's in-flight
    /// slot); `false` for interim progress frames.
    end_of_job: bool,
}

#[derive(Default)]
struct JobQueue {
    queue: std::collections::VecDeque<RunnerJob>,
    drain: bool,
}

/// Shared plumbing between the reactor and its runner threads.
#[derive(Default)]
struct Bridge {
    jobs: Mutex<JobQueue>,
    jobs_cv: Condvar,
    done: Mutex<std::collections::VecDeque<Completion>>,
}

/// Write one byte into the wakeup pipe (nonblocking: a full pipe means
/// a wakeup is already pending, which is all we need).
fn wake_reactor(wake: &UnixStream) {
    let mut w = wake;
    let _ = Write::write(&mut w, &[1u8]);
}

fn push_completion(bridge: &Bridge, wake: &UnixStream, completion: Completion) {
    bridge.done.lock().unwrap().push_back(completion);
    wake_reactor(wake);
}

/// Grid points this job will evaluate — the `total` of its progress
/// frames. Requests the dispatcher will reject anyway report zero.
fn job_total(job: &RunnerJob) -> usize {
    match &job.request {
        Request::Sweep(req) => req.spec.len(),
        Request::Shard(req) => ShardPlan::new(&req.spec, req.selector.n_shards())
            .map(|plan| plan.range(req.selector.index()).len())
            .unwrap_or(0),
        _ => 0,
    }
}

/// Run one compute job to a response line (plus interim progress lines).
fn run_job(shared: &ServerShared, bridge: &Bridge, wake: &UnixStream, job: RunnerJob) {
    let start = Instant::now();
    if job.cancel.is_cancelled() {
        // Cancelled while queued behind this runner's previous job.
        shared.metrics.record_cancelled_frame(Some(job.op), start.elapsed().as_secs_f64());
        let line = error_frame_traced(Some(job.op), job.id.as_ref(), job.trace.as_ref(), &cancelled_reject());
        push_completion(bridge, wake, Completion { conn_id: job.conn_id, line, end_of_job: true });
        return;
    }
    let span = crate::obs::server_span(job.op, job.trace.as_ref());
    let total = job_total(&job);
    let done = AtomicUsize::new(0);
    let emitted = AtomicUsize::new(0);
    let progress_every = shared.progress_every;
    let progress = |points: usize| {
        shared.metrics.record_chunk(points);
        let so_far = done.fetch_add(points, Ordering::AcqRel) + points;
        let Some(every) = progress_every else { return };
        if job.version < PROTOCOL_V2 {
            return;
        }
        let last = emitted.load(Ordering::Acquire);
        if so_far.saturating_sub(last) >= every
            && emitted
                .compare_exchange(last, so_far, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        {
            push_completion(
                bridge,
                wake,
                Completion {
                    conn_id: job.conn_id,
                    line: progress_frame_traced(
                        job.op,
                        job.id.as_ref(),
                        job.trace.as_ref(),
                        so_far,
                        total,
                    ),
                    end_of_job: false,
                },
            );
        }
    };
    let ctl = FoldCtl {
        cancel: Some(&job.cancel),
        progress: Some(&progress),
        // Tighten serial-path chunking to the progress cadence so tiny
        // grids still demonstrate it (chunk size never changes bytes).
        chunk: progress_every,
        trace: span.is_recording().then(|| span.ctx()),
    };
    let dispatched = Instant::now();
    let line = match dispatch(&job.request, shared, ctl) {
        Ok(result) => {
            let dispatch_s = dispatched.elapsed().as_secs_f64();
            shared.metrics.record_stage("dispatch", dispatch_s);
            shared.metrics.record_stage("compute", dispatch_s);
            shared.metrics.record_request(job.op, start.elapsed().as_secs_f64());
            ok_frame_traced(job.op, job.id.as_ref(), job.trace.as_ref(), result)
        }
        Err(reject) => {
            let dispatch_s = dispatched.elapsed().as_secs_f64();
            shared.metrics.record_stage("dispatch", dispatch_s);
            shared.metrics.record_stage("compute", dispatch_s);
            if reject.code == super::protocol::CODE_CANCELLED {
                shared.metrics.record_cancelled_frame(Some(job.op), start.elapsed().as_secs_f64());
            } else {
                shared.metrics.record_error_frame(Some(job.op), start.elapsed().as_secs_f64());
            }
            error_frame_traced(Some(job.op), job.id.as_ref(), job.trace.as_ref(), &reject)
        }
    };
    drop(span);
    push_completion(bridge, wake, Completion { conn_id: job.conn_id, line, end_of_job: true });
}

fn runner_loop(shared: &ServerShared, bridge: &Bridge, wake: &UnixStream) {
    loop {
        let job = {
            let mut q = bridge.jobs.lock().unwrap();
            loop {
                if let Some(job) = q.queue.pop_front() {
                    break Some(job);
                }
                if q.drain {
                    break None;
                }
                q = bridge.jobs_cv.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => run_job(shared, bridge, wake, job),
            None => return,
        }
    }
}

/// Serve until a graceful shutdown completes — the event-loop analogue
/// of the threaded `Server::serve`.
pub(crate) fn serve_event_loop(listener: TcpListener, shared: Arc<ServerShared>) -> Result<()> {
    use std::os::unix::io::AsRawFd;

    let (wake_rx, wake_tx) = UnixStream::pair()
        .map_err(|e| Error::Runtime(format!("serve: wakeup pipe: {e}")))?;
    wake_rx
        .set_nonblocking(true)
        .and_then(|_| wake_tx.set_nonblocking(true))
        .map_err(|e| Error::Runtime(format!("serve: wakeup pipe: {e}")))?;

    let bridge = Arc::new(Bridge::default());
    let runners = default_workers().clamp(2, 4);
    let mut runner_handles = Vec::with_capacity(runners);
    for _ in 0..runners {
        let shared = Arc::clone(&shared);
        let bridge = Arc::clone(&bridge);
        let wake = wake_tx
            .try_clone()
            .map_err(|e| Error::Runtime(format!("serve: clone wakeup pipe: {e}")))?;
        runner_handles.push(std::thread::spawn(move || runner_loop(&shared, &bridge, &wake)));
    }

    let mut poller = Poller::new().map_err(|e| Error::Runtime(format!("serve: poller: {e}")))?;
    poller
        .register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::readable())
        .and_then(|_| poller.register(wake_rx.as_raw_fd(), TOKEN_WAKEUP, Interest::readable()))
        .map_err(|e| Error::Runtime(format!("serve: poller register: {e}")))?;

    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id = TOKEN_FIRST_CONN;
    let mut events: Vec<Event> = Vec::new();
    let mut draining = false;
    let mut listener_registered = true;

    loop {
        if let Err(e) = poller.wait(&mut events, TICK) {
            return Err(Error::Runtime(format!("serve: poll: {e}")));
        }
        for i in 0..events.len() {
            let ev = events[i];
            match ev.token {
                TOKEN_LISTENER => {
                    if !draining {
                        accept_ready(&listener, &mut poller, &mut conns, &mut next_id, &shared);
                    }
                }
                TOKEN_WAKEUP => {
                    drain_wakeups(&wake_rx);
                    deliver_completions(
                        &mut poller,
                        &mut conns,
                        &shared,
                        &bridge,
                        draining,
                    );
                }
                id => {
                    conn_event(&mut poller, &mut conns, id, ev, &shared, &bridge, draining);
                }
            }
        }
        // A `shutdown` frame (or a ServerHandle) may have flipped the
        // flag during event handling; enter drain mode exactly once.
        if !draining && shared.shutdown.load(std::sync::atomic::Ordering::SeqCst) {
            draining = true;
            if listener_registered {
                let _ = poller.deregister(listener.as_raw_fd());
                listener_registered = false;
            }
            let now = Instant::now();
            for (&id, conn) in conns.iter_mut() {
                // Undispatched pipelined requests are dropped, matching
                // the threaded core (which stops reading frames at the
                // same point); in-flight computes always finish.
                conn.queue.clear();
                conn.read_closed = true;
                conn.last_write_progress = now;
                update_interest(&mut poller, id, conn);
            }
        }
        keepalive_tick(&mut poller, &mut conns);
        if draining {
            conns.retain(|&id, conn| {
                let idle = conn.in_flight.is_none() && conn.out.is_empty();
                let stuck = !conn.out.is_empty()
                    && conn.last_write_progress.elapsed() > DRAIN_STUCK_GRACE;
                if idle || stuck {
                    conn.cancel_all();
                    let _ = poller.deregister(conn.stream.as_raw_fd());
                    return false;
                }
                true
            });
            if conns.is_empty() {
                break;
            }
        }
    }

    // Stop the runners: finish whatever is queued (tokens of dropped
    // connections are already tripped, so those unwind at their next
    // chunk), then exit.
    {
        let mut q = bridge.jobs.lock().unwrap();
        q.drain = true;
    }
    bridge.jobs_cv.notify_all();
    for handle in runner_handles {
        let _ = handle.join();
    }
    drop(listener);
    Ok(())
}

fn accept_ready(
    listener: &TcpListener,
    poller: &mut Poller,
    conns: &mut HashMap<u64, Conn>,
    next_id: &mut u64,
    shared: &ServerShared,
) {
    use std::os::unix::io::AsRawFd;
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                shared.metrics.connection_opened();
                let id = *next_id;
                *next_id += 1;
                if poller.register(stream.as_raw_fd(), id, Interest::readable()).is_ok() {
                    conns.insert(id, Conn::new(stream));
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                // Transient accept failures (fd pressure, aborted
                // handshakes) must not kill the daemon; the next tick
                // retries.
                eprintln!("cimdse serve: accept failed (retrying): {e}");
                break;
            }
        }
    }
}

fn drain_wakeups(wake_rx: &UnixStream) {
    let mut buf = [0u8; 256];
    let mut r = wake_rx;
    loop {
        match Read::read(&mut r, &mut buf) {
            Ok(0) => return,
            Ok(_) => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return, // WouldBlock: fully drained
        }
    }
}

fn deliver_completions(
    poller: &mut Poller,
    conns: &mut HashMap<u64, Conn>,
    shared: &ServerShared,
    bridge: &Bridge,
    draining: bool,
) {
    let batch: Vec<Completion> = {
        let mut done = bridge.done.lock().unwrap();
        done.drain(..).collect()
    };
    for completion in batch {
        let Some(conn) = conns.get_mut(&completion.conn_id) else {
            continue; // the connection disconnected mid-compute
        };
        conn.send(&completion.line);
        shared.metrics.note_write_queue_peak(conn.out.peak_bytes());
        if completion.end_of_job {
            conn.in_flight = None;
        }
        finish_touch(poller, conns, completion.conn_id, shared, bridge, draining);
    }
}

/// The per-event epilogue for one connection: re-parse buffered frames
/// (a completion or a write flush may have just lifted the backpressure
/// throttle, and no further read event would arrive for bytes already
/// sitting in the [`FrameBuf`]), pump the FIFO queue, flush what the
/// socket will take, reap finished connections, and refresh poll
/// interest. A write error drops the connection (disconnect ⇒ cancel
/// its work).
fn finish_touch(
    poller: &mut Poller,
    conns: &mut HashMap<u64, Conn>,
    id: u64,
    shared: &ServerShared,
    bridge: &Bridge,
    draining: bool,
) {
    use std::os::unix::io::AsRawFd;
    let Some(conn) = conns.get_mut(&id) else { return };
    if !draining {
        // Loop until quiescent, not once: pumping cheap replies can
        // lift the pipeline throttle while complete frames still sit
        // in the FrameBuf — and no future socket event will re-parse
        // bytes already consumed off the wire. Each iteration either
        // consumes buffered bytes or observes throttle, so this
        // terminates.
        loop {
            drain_frames(conn, shared);
            pump_conn(conn, id, shared, bridge);
            if conn.throttled() || !conn.frames.has_frame() {
                break;
            }
        }
    }
    let flush_started = (!conn.out.is_empty()).then(Instant::now);
    let alive = match conn.out.write_to(&mut conn.stream) {
        Ok(n) => {
            if n > 0 {
                conn.last_write_progress = Instant::now();
            }
            true
        }
        Err(_) => false,
    };
    if let Some(t) = flush_started {
        // One sample per non-empty flush attempt: the time the reactor
        // thread spent feeding this socket (partial writes included).
        shared.metrics.record_stage("write", t.elapsed().as_secs_f64());
    }
    // A fully answered connection whose peer has closed is done.
    let done = conn.read_closed
        && conn.in_flight.is_none()
        && conn.queue.is_empty()
        && conn.out.is_empty();
    if !alive || done {
        conn.cancel_all();
        let _ = poller.deregister(conn.stream.as_raw_fd());
        conns.remove(&id);
        return;
    }
    update_interest(poller, id, conn);
}

fn update_interest(poller: &mut Poller, id: u64, conn: &Conn) {
    use std::os::unix::io::AsRawFd;
    let interest = Interest {
        readable: !conn.read_closed && !conn.throttled(),
        writable: !conn.out.is_empty(),
    };
    let _ = poller.modify(conn.stream.as_raw_fd(), id, interest);
}

/// Parse every buffered frame the backpressure bounds allow into the
/// connection's FIFO queue.
fn drain_frames(conn: &mut Conn, shared: &ServerShared) {
    while !conn.throttled() {
        match conn.frames.next_event() {
            Some(FrameEvent::Frame(line)) => process_line(conn, &line, shared),
            Some(FrameEvent::Oversized) => {
                // The reject is formed the instant the cap trips, so its
                // latency is sub-ns; what matters is that reject storms
                // are visible in the error histograms at all.
                shared.metrics.record_error_frame(None, 0.0);
                let line = error_frame(None, None, &oversized_reject());
                conn.queue.push_back(QueueEntry::Reply(line));
            }
            None => break,
        }
    }
}

fn conn_event(
    poller: &mut Poller,
    conns: &mut HashMap<u64, Conn>,
    id: u64,
    ev: Event,
    shared: &ServerShared,
    bridge: &Bridge,
    draining: bool,
) {
    use std::os::unix::io::AsRawFd;
    let Some(conn) = conns.get_mut(&id) else { return };
    if ev.hangup {
        // EPOLLERR/EPOLLHUP: the connection is gone in both directions
        // (reset or fully closed). Nothing is deliverable — cancel every
        // token this connection owns and drop it; an abandoned sweep
        // stops at its next chunk boundary.
        conn.cancel_all();
        let _ = poller.deregister(conn.stream.as_raw_fd());
        conns.remove(&id);
        return;
    }
    if ev.readable && !conn.read_closed && !draining {
        let mut chunk = [0u8; 8192];
        loop {
            drain_frames(conn, shared);
            if conn.throttled() || conn.read_closed {
                break;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    // Clean EOF: no further requests will arrive, but
                    // everything already parsed is still answered (the
                    // peer may be half-closed and reading). Cancellation
                    // for a peer that *vanished* comes from the write
                    // error its reset produces — keepalive/progress
                    // frames keep v2 connections probing.
                    conn.read_closed = true;
                }
                Ok(n) => conn.frames.push(&chunk[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    conn.cancel_all();
                    let _ = poller.deregister(conn.stream.as_raw_fd());
                    conns.remove(&id);
                    return;
                }
            }
        }
    }
    // Parse + pump + flush, reap closed-and-answered connections,
    // refresh interest.
    finish_touch(poller, conns, id, shared, bridge, draining);
}

/// Parse one frame into the connection's FIFO queue (or answer `cancel`
/// immediately).
fn process_line(conn: &mut Conn, line: &[u8], shared: &ServerShared) {
    if line.iter().all(|b| b.is_ascii_whitespace()) {
        return; // blank keep-alive lines are not frames
    }
    match parse_or_reply(line, shared) {
        Err(reply) => conn.queue.push_back(QueueEntry::Reply(reply)),
        Ok((id, trace, Request::Cancel(target))) => {
            // Answered out of band by design: a cancel queued behind the
            // request it targets could never fire in time.
            let start = Instant::now();
            let key = target.to_json_string().unwrap_or_default();
            let line = if conn.cancel_target(&key) {
                shared.metrics.record_request("cancel", start.elapsed().as_secs_f64());
                let mut map = std::collections::BTreeMap::new();
                map.insert("target".to_string(), target.clone());
                map.insert("cancelled".to_string(), Value::Bool(true));
                ok_frame_traced("cancel", id.as_ref(), trace.as_ref(), Value::Table(map))
            } else {
                shared.metrics.record_error_frame(Some("cancel"), start.elapsed().as_secs_f64());
                error_frame_traced(Some("cancel"), id.as_ref(), trace.as_ref(), &unknown_id_reject(&key))
            };
            conn.send(&line);
        }
        Ok((id, trace, request)) => {
            let op = request.op();
            let id_key = id.as_ref().and_then(|v| v.to_json_string().ok());
            conn.queue.push_back(QueueEntry::Job(PendingJob {
                op,
                id,
                id_key,
                request,
                cancel: CancelToken::new(),
                trace,
                queued_at: Instant::now(),
            }));
        }
    }
}

/// Answer queue entries in FIFO order until a compute op goes in flight
/// (or the queue empties).
fn pump_conn(conn: &mut Conn, conn_id: u64, shared: &ServerShared, bridge: &Bridge) {
    while conn.in_flight.is_none() {
        let Some(entry) = conn.queue.pop_front() else { break };
        match entry {
            QueueEntry::Reply(line) => conn.send(&line),
            QueueEntry::Job(job) => {
                if job.cancel.is_cancelled() {
                    // Cancelled while queued: answered at its FIFO turn
                    // without ever touching the pool.
                    shared
                        .metrics
                        .record_cancelled_frame(Some(job.op), job.queued_at.elapsed().as_secs_f64());
                    conn.send(&error_frame_traced(
                        Some(job.op),
                        job.id.as_ref(),
                        job.trace.as_ref(),
                        &cancelled_reject(),
                    ));
                } else if is_compute(job.op) {
                    conn.in_flight = Some(InFlight {
                        op: job.op,
                        id_key: job.id_key.clone(),
                        cancel: job.cancel.clone(),
                    });
                    {
                        let mut q = bridge.jobs.lock().unwrap();
                        q.queue.push_back(RunnerJob {
                            conn_id,
                            op: job.op,
                            id: job.id,
                            request: job.request,
                            cancel: job.cancel,
                            version: conn.version,
                            trace: job.trace,
                        });
                    }
                    bridge.jobs_cv.notify_one();
                } else {
                    if let Request::Hello(version) = &job.request {
                        conn.version = *version;
                    }
                    let span = crate::obs::server_span(job.op, job.trace.as_ref());
                    let mut ctl = FoldCtl::default();
                    if span.is_recording() {
                        ctl.trace = Some(span.ctx());
                    }
                    let start = Instant::now();
                    let line = match dispatch(&job.request, shared, ctl) {
                        Ok(result) => {
                            let dt = start.elapsed().as_secs_f64();
                            shared.metrics.record_stage("dispatch", dt);
                            shared.metrics.record_request(job.op, dt);
                            ok_frame_traced(job.op, job.id.as_ref(), job.trace.as_ref(), result)
                        }
                        Err(reject) => {
                            let dt = start.elapsed().as_secs_f64();
                            shared.metrics.record_stage("dispatch", dt);
                            shared.metrics.record_error_frame(Some(job.op), dt);
                            error_frame_traced(
                                Some(job.op),
                                job.id.as_ref(),
                                job.trace.as_ref(),
                                &reject,
                            )
                        }
                    };
                    drop(span);
                    conn.send(&line);
                }
            }
        }
    }
    shared.metrics.note_write_queue_peak(conn.out.peak_bytes());
}

fn keepalive_tick(poller: &mut Poller, conns: &mut HashMap<u64, Conn>) {
    for (&id, conn) in conns.iter_mut() {
        if conn.version >= PROTOCOL_V2
            && conn.in_flight.is_some()
            && conn.last_tx.elapsed() >= KEEPALIVE_EVERY
        {
            conn.send(&keepalive_frame());
            let _ = conn.out.write_to(&mut conn.stream);
            update_interest(poller, id, conn);
        }
    }
}

/// Readiness polling over raw syscalls: `epoll(7)` on Linux, `poll(2)`
/// everywhere else — the only platform-specific code in the crate.
mod poller {
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    /// What a registration wants to be woken for.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct Interest {
        /// Wake when the fd is readable (or the peer hung up).
        pub readable: bool,
        /// Wake when the fd is writable.
        pub writable: bool,
    }

    impl Interest {
        /// Read-only interest.
        pub fn readable() -> Interest {
            Interest { readable: true, writable: false }
        }
    }

    /// One readiness event out of [`Poller::wait`].
    #[derive(Clone, Copy, Debug)]
    pub struct Event {
        /// The token the fd was registered with.
        pub token: u64,
        /// Readable (includes hangup/error so reads observe EOF).
        pub readable: bool,
        /// Writable (includes hangup/error so writes observe the error).
        pub writable: bool,
        /// Peer hung up or the fd errored.
        pub hangup: bool,
    }

    #[cfg(target_os = "linux")]
    mod sys {
        use std::os::raw::c_int;

        // `epoll_event` is packed on x86-64 only (a 12-byte struct); on
        // every other Linux architecture it has natural alignment. See
        // `epoll_ctl(2)` NOTES.
        #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
        #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
        #[derive(Clone, Copy)]
        pub struct EpollEvent {
            pub events: u32,
            pub data: u64,
        }

        pub const EPOLL_CLOEXEC: c_int = 0x80000;
        pub const EPOLL_CTL_ADD: c_int = 1;
        pub const EPOLL_CTL_DEL: c_int = 2;
        pub const EPOLL_CTL_MOD: c_int = 3;
        pub const EPOLLIN: u32 = 0x001;
        pub const EPOLLOUT: u32 = 0x004;
        pub const EPOLLERR: u32 = 0x008;
        pub const EPOLLHUP: u32 = 0x010;
        pub const EPOLLRDHUP: u32 = 0x2000;

        extern "C" {
            pub fn epoll_create1(flags: c_int) -> c_int;
            pub fn epoll_ctl(
                epfd: c_int,
                op: c_int,
                fd: c_int,
                event: *mut EpollEvent,
            ) -> c_int;
            pub fn epoll_wait(
                epfd: c_int,
                events: *mut EpollEvent,
                maxevents: c_int,
                timeout_ms: c_int,
            ) -> c_int;
        }
    }

    /// The Linux implementation: one epoll instance, level-triggered.
    #[cfg(target_os = "linux")]
    pub struct Poller {
        epfd: std::os::unix::io::OwnedFd,
    }

    #[cfg(target_os = "linux")]
    impl Poller {
        /// A fresh epoll instance (close-on-exec).
        pub fn new() -> io::Result<Poller> {
            use std::os::unix::io::FromRawFd;
            // SAFETY: epoll_create1 takes no pointers; it returns a new
            // fd or -1.
            let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            // SAFETY: `fd` was just returned by epoll_create1, is valid,
            // and nothing else owns it.
            let epfd = unsafe { std::os::unix::io::OwnedFd::from_raw_fd(fd) };
            Ok(Poller { epfd })
        }

        fn events_bits(interest: Interest) -> u32 {
            // EPOLLRDHUP rides with read interest only: once a
            // connection stops reading (EOF seen, or throttled), the
            // level-triggered half-close condition must stop waking the
            // loop. EPOLLERR/EPOLLHUP are always reported regardless.
            let mut bits = 0;
            if interest.readable {
                bits |= sys::EPOLLIN | sys::EPOLLRDHUP;
            }
            if interest.writable {
                bits |= sys::EPOLLOUT;
            }
            bits
        }

        fn ctl(&mut self, op: std::os::raw::c_int, fd: RawFd, ev: sys::EpollEvent) -> io::Result<()> {
            use std::os::unix::io::AsRawFd;
            let mut ev = ev;
            // SAFETY: `ev` lives across the call; the kernel copies it
            // before epoll_ctl returns, and both fds are valid.
            let rc = unsafe { sys::epoll_ctl(self.epfd.as_raw_fd(), op, fd, &mut ev) };
            if rc < 0 { Err(io::Error::last_os_error()) } else { Ok(()) }
        }

        /// Start watching `fd` under `token`.
        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let ev = sys::EpollEvent { events: Self::events_bits(interest), data: token };
            self.ctl(sys::EPOLL_CTL_ADD, fd, ev)
        }

        /// Update the interest of a watched `fd`.
        pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let ev = sys::EpollEvent { events: Self::events_bits(interest), data: token };
            self.ctl(sys::EPOLL_CTL_MOD, fd, ev)
        }

        /// Stop watching `fd`.
        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            // A non-null event pointer keeps pre-2.6.9 kernels happy.
            let ev = sys::EpollEvent { events: 0, data: 0 };
            self.ctl(sys::EPOLL_CTL_DEL, fd, ev)
        }

        /// Wait up to `timeout` for readiness; appends into `out`
        /// (cleared first). EINTR surfaces as zero events.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
            use std::os::unix::io::AsRawFd;
            out.clear();
            let mut buf = [sys::EpollEvent { events: 0, data: 0 }; 64];
            let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as std::os::raw::c_int;
            // SAFETY: `buf` provides 64 writable entries and we pass
            // maxevents = 64, so the kernel never writes past it.
            let n = unsafe {
                sys::epoll_wait(self.epfd.as_raw_fd(), buf.as_mut_ptr(), 64, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for ev in buf.iter().take(n as usize) {
                // Copy out of the (possibly packed) struct before use.
                let bits = ev.events;
                let token = ev.data;
                // EPOLLRDHUP (peer half-closed, our writes may still
                // matter) surfaces as readability so reads observe EOF;
                // only EPOLLERR/EPOLLHUP (gone both ways) is a hangup.
                let hangup = bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0;
                out.push(Event {
                    token,
                    readable: bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 || hangup,
                    writable: bits & sys::EPOLLOUT != 0,
                    hangup,
                });
            }
            Ok(())
        }
    }

    #[cfg(not(target_os = "linux"))]
    mod sys {
        use std::os::raw::{c_int, c_short, c_ulong};

        #[repr(C)]
        #[derive(Clone, Copy)]
        pub struct PollFd {
            pub fd: c_int,
            pub events: c_short,
            pub revents: c_short,
        }

        // Identical values on every poll(2) platform we can land on
        // (BSDs, macOS, illumos).
        pub const POLLIN: c_short = 0x001;
        pub const POLLOUT: c_short = 0x004;
        pub const POLLERR: c_short = 0x008;
        pub const POLLHUP: c_short = 0x010;

        extern "C" {
            pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout_ms: c_int) -> c_int;
        }
    }

    /// The portable fallback: a registration list replayed through one
    /// `poll(2)` call per wait. O(fds) per wait, which is fine for the
    /// connection counts the fallback platforms see in practice.
    #[cfg(not(target_os = "linux"))]
    pub struct Poller {
        entries: Vec<(RawFd, u64, Interest)>,
    }

    #[cfg(not(target_os = "linux"))]
    impl Poller {
        /// An empty registration table.
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { entries: Vec::new() })
        }

        /// Start watching `fd` under `token`.
        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            if self.entries.iter().any(|(f, _, _)| *f == fd) {
                return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd registered"));
            }
            self.entries.push((fd, token, interest));
            Ok(())
        }

        /// Update the interest of a watched `fd`.
        pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            match self.entries.iter_mut().find(|(f, _, _)| *f == fd) {
                Some(entry) => {
                    entry.1 = token;
                    entry.2 = interest;
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        /// Stop watching `fd`.
        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.entries.retain(|(f, _, _)| *f != fd);
            Ok(())
        }

        /// Wait up to `timeout` for readiness; appends into `out`
        /// (cleared first). EINTR surfaces as zero events.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<sys::PollFd> = self
                .entries
                .iter()
                .map(|(fd, _, interest)| sys::PollFd {
                    fd: *fd,
                    events: if interest.readable { sys::POLLIN } else { 0 }
                        | if interest.writable { sys::POLLOUT } else { 0 },
                    revents: 0,
                })
                .collect();
            let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as std::os::raw::c_int;
            // SAFETY: `fds` provides exactly `fds.len()` PollFd entries,
            // matching the nfds argument; the kernel only writes their
            // `revents` fields.
            let n = unsafe {
                sys::poll(fds.as_mut_ptr(), fds.len() as std::os::raw::c_ulong, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for (slot, (_, token, _)) in fds.iter().zip(&self.entries) {
                let bits = slot.revents;
                if bits == 0 {
                    continue;
                }
                let hangup = bits & (sys::POLLERR | sys::POLLHUP) != 0;
                out.push(Event {
                    token: *token,
                    readable: bits & sys::POLLIN != 0 || hangup,
                    writable: bits & sys::POLLOUT != 0,
                    hangup,
                });
            }
            Ok(())
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::io::Write;
        use std::os::unix::io::AsRawFd;
        use std::os::unix::net::UnixStream;

        #[test]
        fn poller_sees_readability_and_honors_interest() {
            let (a, mut b) = UnixStream::pair().unwrap();
            a.set_nonblocking(true).unwrap();
            let mut poller = Poller::new().unwrap();
            poller.register(a.as_raw_fd(), 7, Interest::readable()).unwrap();

            // Nothing to read yet: the wait times out empty.
            let mut events = Vec::new();
            poller.wait(&mut events, Duration::from_millis(10)).unwrap();
            assert!(events.iter().all(|e| e.token != 7 || !e.readable));

            b.write_all(b"ping").unwrap();
            poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
            let ev = events.iter().find(|e| e.token == 7).expect("readable event");
            assert!(ev.readable && !ev.writable);

            // Write interest on a socket with buffer space fires.
            poller
                .modify(a.as_raw_fd(), 7, Interest { readable: true, writable: true })
                .unwrap();
            poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
            assert!(events.iter().any(|e| e.token == 7 && e.writable));

            poller.deregister(a.as_raw_fd()).unwrap();
            poller.wait(&mut events, Duration::from_millis(10)).unwrap();
            assert!(events.iter().all(|e| e.token != 7));
        }

        #[test]
        fn poller_reports_peer_hangup() {
            let (a, b) = UnixStream::pair().unwrap();
            a.set_nonblocking(true).unwrap();
            let mut poller = Poller::new().unwrap();
            poller.register(a.as_raw_fd(), 3, Interest::readable()).unwrap();
            drop(b);
            let mut events = Vec::new();
            poller.wait(&mut events, Duration::from_millis(1000)).unwrap();
            let ev = events.iter().find(|e| e.token == 3).expect("hangup event");
            assert!(ev.readable, "hangup must surface as readable so reads see EOF");
        }
    }
}
