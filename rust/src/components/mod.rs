//! Accelergy-like component energy/area library.
//!
//! Each non-ADC accelerator component is characterized by an energy per
//! action and an area, specified at a 32 nm reference node and scaled to
//! the target technology by per-class exponents (digital logic and memory
//! scale ~quadratically with node; analog front-end components scale
//! ~linearly — mirroring how Accelergy's primitive tables behave across
//! nodes). Reference values are in the ISAAC / RAELLA ballpark and are
//! documented per component; the paper's experiments only require that
//! the non-ADC context has realistic relative magnitude, since every
//! variant shares these components (DESIGN.md §2).
//!
//! The ADC itself is priced by [`crate::adc::AdcModel`] — that is the
//! paper's point — and enters the rollup through [`AdcComponent`].

pub mod library;

pub use library::*;

use crate::adc::{AdcModel, AdcQuery};

/// Energy/area scaling class of a component.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalingClass {
    /// Digital logic / SRAM: energy ~ (T/32)^2, area ~ (T/32)^2.
    Digital,
    /// Analog front-end (DAC, S+H): energy ~ (T/32)^1, area ~ (T/32)^1.
    Analog,
    /// Memristive crossbar cells: energy ~ (T/32)^1, area ~ (T/32)^2 (4F²).
    Crossbar,
}

impl ScalingClass {
    /// Multiplicative energy scale factor from 32 nm to `tech_nm`.
    pub fn energy_scale(&self, tech_nm: f64) -> f64 {
        let r = tech_nm / 32.0;
        match self {
            ScalingClass::Digital => r * r,
            ScalingClass::Analog | ScalingClass::Crossbar => r,
        }
    }

    /// Multiplicative area scale factor from 32 nm to `tech_nm`.
    pub fn area_scale(&self, tech_nm: f64) -> f64 {
        let r = tech_nm / 32.0;
        match self {
            ScalingClass::Digital | ScalingClass::Crossbar => r * r,
            ScalingClass::Analog => r,
        }
    }
}

/// A primitive component instance: per-action energy and per-instance area
/// at a given technology node.
#[derive(Clone, Debug)]
pub struct Component {
    /// Display name (e.g. "dac", "shift-add").
    pub name: &'static str,
    /// Energy per action in picojoules (at `tech_nm`).
    pub energy_pj_per_action: f64,
    /// Area per instance in µm² (at `tech_nm`).
    pub area_um2: f64,
    /// Scaling class used to derive the above from 32 nm reference values.
    pub class: ScalingClass,
}

impl Component {
    /// Build from 32 nm reference values, scaled to `tech_nm`.
    pub fn at_tech(
        name: &'static str,
        ref_energy_pj: f64,
        ref_area_um2: f64,
        class: ScalingClass,
        tech_nm: f64,
    ) -> Self {
        Component {
            name,
            energy_pj_per_action: ref_energy_pj * class.energy_scale(tech_nm),
            area_um2: ref_area_um2 * class.area_scale(tech_nm),
            class,
        }
    }

    /// Energy (pJ) for `n` actions.
    pub fn energy_pj(&self, actions: f64) -> f64 {
        self.energy_pj_per_action * actions
    }
}

/// The ADC as a component: wraps the paper's model for use in the rollup.
#[derive(Clone, Debug)]
pub struct AdcComponent {
    /// The model (possibly tuned / fitted).
    pub model: AdcModel,
    /// The architecture-level query this instance answers.
    pub query: AdcQuery,
}

impl AdcComponent {
    /// Energy per convert (pJ).
    pub fn energy_pj_per_convert(&self) -> f64 {
        self.model.energy_pj_per_convert(&self.query)
    }

    /// Total area of all ADCs (µm²).
    pub fn total_area_um2(&self) -> f64 {
        self.model.area_um2_per_adc(&self.query) * self.query.n_adcs as f64
    }

    /// Energy (pJ) for `n` converts.
    pub fn energy_pj(&self, converts: f64) -> f64 {
        self.energy_pj_per_convert() * converts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_classes() {
        // 64 nm = 2x node: digital energy 4x, analog energy 2x.
        assert!((ScalingClass::Digital.energy_scale(64.0) - 4.0).abs() < 1e-12);
        assert!((ScalingClass::Analog.energy_scale(64.0) - 2.0).abs() < 1e-12);
        assert!((ScalingClass::Crossbar.energy_scale(64.0) - 2.0).abs() < 1e-12);
        assert!((ScalingClass::Crossbar.area_scale(64.0) - 4.0).abs() < 1e-12);
        // Identity at the reference node.
        for c in [ScalingClass::Digital, ScalingClass::Analog, ScalingClass::Crossbar] {
            assert!((c.energy_scale(32.0) - 1.0).abs() < 1e-12);
            assert!((c.area_scale(32.0) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn component_scales_from_reference() {
        let c = Component::at_tech("t", 1.0, 10.0, ScalingClass::Digital, 64.0);
        assert!((c.energy_pj_per_action - 4.0).abs() < 1e-12);
        assert!((c.area_um2 - 40.0).abs() < 1e-12);
        assert!((c.energy_pj(3.0) - 12.0).abs() < 1e-12);
    }

    #[test]
    fn adc_component_consistency() {
        let comp = AdcComponent {
            model: AdcModel::default(),
            query: AdcQuery { enob: 7.0, total_throughput: 1e9, tech_nm: 32.0, n_adcs: 4 },
        };
        let e = comp.energy_pj_per_convert();
        assert!((comp.energy_pj(100.0) - 100.0 * e).abs() < 1e-9);
        assert!(comp.total_area_um2() > 0.0);
    }
}
