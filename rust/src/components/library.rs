//! The concrete component catalog with 32 nm reference values.
//!
//! Values are in the ballpark of the ISAAC (ISCA'16) and RAELLA (ISCA'23)
//! component tables, normalized to 32 nm. Exact magnitudes are not the
//! point (our substrate is synthetic; see DESIGN.md §2) — what matters is
//! that the non-ADC context is realistic relative to the ADC so the
//! paper's full-accelerator tradeoffs (Figs. 4–5) keep their shape.

use super::{Component, ScalingClass};

/// ReRAM crossbar cell read: energy per cell per activated bit-plane;
/// area per cell including its share of array periphery (4F² cell plus
/// wordline/bitline overhead).
pub fn crossbar_cell(tech_nm: f64) -> Component {
    Component::at_tech("crossbar-cell", 0.0005, 0.05, ScalingClass::Crossbar, tech_nm)
}

/// 1-bit row DAC / wordline driver: energy per driven row per bit-plane.
pub fn dac(tech_nm: f64) -> Component {
    Component::at_tech("dac", 0.25, 1.2, ScalingClass::Analog, tech_nm)
}

/// Column sample-and-hold: energy per sampled column value.
pub fn sample_hold(tech_nm: f64) -> Component {
    Component::at_tech("sample-hold", 0.01, 10.0, ScalingClass::Analog, tech_nm)
}

/// Digital shift-add unit: energy per post-ADC accumulate operation;
/// area per instance (one per ADC).
pub fn shift_add(tech_nm: f64) -> Component {
    Component::at_tech("shift-add", 0.02, 600.0, ScalingClass::Digital, tech_nm)
}

/// Input/output registers: energy per bit moved.
pub fn register(tech_nm: f64) -> Component {
    Component::at_tech("register", 0.0002, 0.4, ScalingClass::Digital, tech_nm)
}

/// Local SRAM buffer: energy per byte accessed; area per byte.
pub fn sram(tech_nm: f64) -> Component {
    Component::at_tech("sram", 0.19, 0.35, ScalingClass::Digital, tech_nm)
}

/// Global eDRAM buffer: energy per byte accessed; area per byte.
pub fn edram(tech_nm: f64) -> Component {
    Component::at_tech("edram", 1.2, 0.08, ScalingClass::Digital, tech_nm)
}

/// On-chip router: energy per 32-byte flit; area per router instance.
pub fn router(tech_nm: f64) -> Component {
    Component::at_tech("router", 2.0, 25_000.0, ScalingClass::Digital, tech_nm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_positive_and_ordered_sanely() {
        let t = 32.0;
        // Cell reads are the cheapest action; router flits the priciest.
        let cell = crossbar_cell(t);
        let rt = router(t);
        assert!(cell.energy_pj_per_action < dac(t).energy_pj_per_action);
        assert!(sample_hold(t).energy_pj_per_action < shift_add(t).energy_pj_per_action * 10.0);
        assert!(rt.energy_pj_per_action > sram(t).energy_pj_per_action);
        for c in [cell, dac(t), sample_hold(t), shift_add(t), register(t), sram(t), edram(t), rt] {
            assert!(c.energy_pj_per_action > 0.0, "{}", c.name);
            assert!(c.area_um2 > 0.0, "{}", c.name);
        }
    }

    #[test]
    fn bigger_node_costs_more() {
        for f in [crossbar_cell, dac, sample_hold, shift_add, register, sram, edram, router]
        {
            let small = f(16.0);
            let big = f(65.0);
            assert!(big.energy_pj_per_action > small.energy_pj_per_action, "{}", big.name);
            assert!(big.area_um2 > small.area_um2, "{}", big.name);
        }
    }
}
