//! ResNet18 layer shapes (He et al., CVPR 2016) at 224x224 input.
//!
//! All 21 weight layers: the 7x7 stem, sixteen 3x3 convs in eight basic
//! blocks, three 1x1 downsample convs, and the final FC. These are the
//! layers the paper's Fig. 4 sweeps; its "small-tensor layer" corresponds
//! to a 1x1 downsample (few values available to sum analogically) and its
//! "large-tensor layer" to a late-stage 3x3 conv (C·R·S = 4608).

use super::{Layer, Workload};

/// Build the ResNet18 workload.
pub fn resnet18() -> Workload {
    let mut layers = vec![Layer::conv("conv1", 3, 64, 7, 7, 112, 112)];

    // conv2_x: 2 blocks @ 64ch, 56x56.
    for b in 1..=2 {
        layers.push(Layer::conv(&format!("conv2_{b}a"), 64, 64, 3, 3, 56, 56));
        layers.push(Layer::conv(&format!("conv2_{b}b"), 64, 64, 3, 3, 56, 56));
    }
    // conv3_x: 2 blocks @ 128ch, 28x28 (first conv strides down).
    layers.push(Layer::conv("conv3_1a", 64, 128, 3, 3, 28, 28));
    layers.push(Layer::conv("conv3_1b", 128, 128, 3, 3, 28, 28));
    layers.push(Layer::conv("conv3_ds", 64, 128, 1, 1, 28, 28));
    layers.push(Layer::conv("conv3_2a", 128, 128, 3, 3, 28, 28));
    layers.push(Layer::conv("conv3_2b", 128, 128, 3, 3, 28, 28));
    // conv4_x: 2 blocks @ 256ch, 14x14.
    layers.push(Layer::conv("conv4_1a", 128, 256, 3, 3, 14, 14));
    layers.push(Layer::conv("conv4_1b", 256, 256, 3, 3, 14, 14));
    layers.push(Layer::conv("conv4_ds", 128, 256, 1, 1, 14, 14));
    layers.push(Layer::conv("conv4_2a", 256, 256, 3, 3, 14, 14));
    layers.push(Layer::conv("conv4_2b", 256, 256, 3, 3, 14, 14));
    // conv5_x: 2 blocks @ 512ch, 7x7.
    layers.push(Layer::conv("conv5_1a", 256, 512, 3, 3, 7, 7));
    layers.push(Layer::conv("conv5_1b", 512, 512, 3, 3, 7, 7));
    layers.push(Layer::conv("conv5_ds", 256, 512, 1, 1, 7, 7));
    layers.push(Layer::conv("conv5_2a", 512, 512, 3, 3, 7, 7));
    layers.push(Layer::conv("conv5_2b", 512, 512, 3, 3, 7, 7));

    layers.push(Layer::fc("fc", 512, 1000));

    Workload { name: "resnet18".into(), layers }
}

/// The paper's Fig. 4 "large-tensor layer": a late 3x3 conv whose
/// C·R·S = 4608 lets even the XL variant sum at full utilization.
pub fn large_tensor_layer() -> Layer {
    resnet18().layer("conv5_2a").unwrap().clone()
}

/// The paper's Fig. 4 "small-tensor layer": a 1x1 downsample conv whose
/// C·R·S = 64 caps the analog sum below even the Small variant's limit.
pub fn small_tensor_layer() -> Layer {
    resnet18().layer("conv3_ds").unwrap().clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_21_weight_layers() {
        assert_eq!(resnet18().layers.len(), 21);
    }

    #[test]
    fn total_macs_match_published_figure() {
        // ResNet18 @224x224 is ~1.8 GMACs.
        let macs = resnet18().total_macs();
        assert!((1.6e9..2.0e9).contains(&(macs as f64)), "{macs}");
    }

    #[test]
    fn stem_and_fc_shapes() {
        let net = resnet18();
        let conv1 = net.layer("conv1").unwrap();
        assert_eq!(conv1.weight_rows(), 147);
        let fc = net.layer("fc").unwrap();
        assert_eq!(fc.weights(), 512_000);
    }

    #[test]
    fn tensor_extremes() {
        assert_eq!(large_tensor_layer().weight_rows(), 4608);
        assert_eq!(small_tensor_layer().weight_rows(), 64);
    }

    #[test]
    fn all_names_unique() {
        let net = resnet18();
        let mut names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), net.layers.len());
    }
}
