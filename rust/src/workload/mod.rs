//! DNN workload descriptors.
//!
//! Layers are described by their tensor shapes; the mapper consumes the
//! implied weight-matrix geometry (a conv layer is an
//! `(C·R·S) x K` matrix applied at `P·Q` output positions — the standard
//! CiM im2col view used by ISAAC/RAELLA/CiMLoop).

pub mod resnet18;
pub mod zoo;

pub use resnet18::resnet18;
pub use zoo::{lenet, vgg16};

/// One DNN layer.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    /// Name, e.g. "conv2_1a".
    pub name: String,
    /// Input channels.
    pub c: usize,
    /// Output channels (filters).
    pub k: usize,
    /// Kernel height.
    pub r: usize,
    /// Kernel width.
    pub s: usize,
    /// Output height.
    pub p: usize,
    /// Output width.
    pub q: usize,
}

impl Layer {
    /// Convolution layer.
    pub fn conv(name: &str, c: usize, k: usize, r: usize, s: usize, p: usize, q: usize) -> Layer {
        Layer { name: name.into(), c, k, r, s, p, q }
    }

    /// Fully-connected layer (a 1x1 conv at a single output position).
    pub fn fc(name: &str, c_in: usize, c_out: usize) -> Layer {
        Layer { name: name.into(), c: c_in, k: c_out, r: 1, s: 1, p: 1, q: 1 }
    }

    /// Rows of the im2col weight matrix: values contributing to one output.
    pub fn weight_rows(&self) -> usize {
        self.c * self.r * self.s
    }

    /// Columns of the im2col weight matrix (logical, pre-slicing).
    pub fn weight_cols(&self) -> usize {
        self.k
    }

    /// Output positions the matrix is applied at.
    pub fn output_positions(&self) -> usize {
        self.p * self.q
    }

    /// Total logical weights.
    pub fn weights(&self) -> usize {
        self.weight_rows() * self.k
    }

    /// Total multiply-accumulates for one inference.
    pub fn macs(&self) -> usize {
        self.weights() * self.output_positions()
    }
}

/// A named sequence of layers.
#[derive(Clone, Debug)]
pub struct Workload {
    /// Network name.
    pub name: String,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

impl Workload {
    /// Total MACs over all layers.
    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Find a layer by name.
    pub fn layer(&self, name: &str) -> Option<&Layer> {
        self.layers.iter().find(|l| l.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geometry() {
        let l = Layer::conv("t", 64, 128, 3, 3, 28, 28);
        assert_eq!(l.weight_rows(), 576);
        assert_eq!(l.weight_cols(), 128);
        assert_eq!(l.output_positions(), 784);
        assert_eq!(l.weights(), 576 * 128);
        assert_eq!(l.macs(), 576 * 128 * 784);
    }

    #[test]
    fn fc_is_single_position() {
        let l = Layer::fc("fc", 512, 1000);
        assert_eq!(l.weight_rows(), 512);
        assert_eq!(l.output_positions(), 1);
        assert_eq!(l.macs(), 512_000);
    }
}
