//! Additional DNN workloads beyond ResNet18, for generality studies:
//! VGG16 (large dense convs — high utilization everywhere) and a small
//! LeNet-style CNN (tiny tensors — the sum-size-limited regime), plus a
//! TOML loader for user-defined workloads.

use crate::config::{Value, parse_toml};
use crate::error::{Error, Result};

use super::{Layer, Workload};

/// VGG16 at 224x224 (Simonyan & Zisserman, 2015): thirteen 3x3 convs and
/// three FC layers. C·R·S ranges 27..4608 — a denser, more uniform
/// utilization profile than ResNet18.
pub fn vgg16() -> Workload {
    let mut layers = vec![
        Layer::conv("conv1_1", 3, 64, 3, 3, 224, 224),
        Layer::conv("conv1_2", 64, 64, 3, 3, 224, 224),
        Layer::conv("conv2_1", 64, 128, 3, 3, 112, 112),
        Layer::conv("conv2_2", 128, 128, 3, 3, 112, 112),
        Layer::conv("conv3_1", 128, 256, 3, 3, 56, 56),
        Layer::conv("conv3_2", 256, 256, 3, 3, 56, 56),
        Layer::conv("conv3_3", 256, 256, 3, 3, 56, 56),
        Layer::conv("conv4_1", 256, 512, 3, 3, 28, 28),
        Layer::conv("conv4_2", 512, 512, 3, 3, 28, 28),
        Layer::conv("conv4_3", 512, 512, 3, 3, 28, 28),
        Layer::conv("conv5_1", 512, 512, 3, 3, 14, 14),
        Layer::conv("conv5_2", 512, 512, 3, 3, 14, 14),
        Layer::conv("conv5_3", 512, 512, 3, 3, 14, 14),
    ];
    layers.push(Layer::fc("fc6", 512 * 7 * 7, 4096));
    layers.push(Layer::fc("fc7", 4096, 4096));
    layers.push(Layer::fc("fc8", 4096, 1000));
    Workload { name: "vgg16".into(), layers }
}

/// A LeNet-style small CNN (28x28 input): every layer's C·R·S is below
/// even the Small variant's sum size — the regime where the paper's
/// small-tensor effect dominates whole-network energy.
pub fn lenet() -> Workload {
    Workload {
        name: "lenet".into(),
        layers: vec![
            Layer::conv("conv1", 1, 6, 5, 5, 24, 24),
            Layer::conv("conv2", 6, 16, 5, 5, 8, 8),
            Layer::fc("fc1", 16 * 4 * 4, 120),
            Layer::fc("fc2", 120, 84),
            Layer::fc("fc3", 84, 10),
        ],
    }
}

/// Look up a built-in workload by name.
pub fn by_name(name: &str) -> Result<Workload> {
    match name.to_lowercase().as_str() {
        "resnet18" => Ok(super::resnet18()),
        "vgg16" => Ok(vgg16()),
        "lenet" => Ok(lenet()),
        other => Err(Error::Config(format!(
            "unknown workload `{other}` (resnet18|vgg16|lenet)"
        ))),
    }
}

/// Load a workload from a TOML-subset document:
///
/// ```toml
/// name = "custom"
/// [layers.conv1]
/// kind = "conv"    # or "fc"
/// c = 3
/// k = 64
/// r = 7
/// s = 7
/// p = 112
/// q = 112
/// ```
pub fn from_toml(text: &str) -> Result<Workload> {
    let v = parse_toml(text)?;
    let name = v.require_str("name")?.to_string();
    let layers_table = match v.get("layers") {
        Some(Value::Table(t)) => t,
        _ => return Err(Error::Config("workload: missing [layers.*] sections".into())),
    };
    let mut layers = Vec::new();
    for (lname, spec) in layers_table {
        let kind = spec
            .get("kind")
            .and_then(Value::as_str)
            .unwrap_or("conv");
        let layer = match kind {
            "conv" => Layer::conv(
                lname,
                spec.require_usize("c")?,
                spec.require_usize("k")?,
                spec.require_usize("r")?,
                spec.require_usize("s")?,
                spec.require_usize("p")?,
                spec.require_usize("q")?,
            ),
            "fc" => Layer::fc(lname, spec.require_usize("c")?, spec.require_usize("k")?),
            other => {
                return Err(Error::Config(format!("layer {lname}: unknown kind `{other}`")));
            }
        };
        layers.push(layer);
    }
    if layers.is_empty() {
        return Err(Error::Config("workload: no layers".into()));
    }
    Ok(Workload { name, layers })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_macs_match_published() {
        // VGG16 @224 is ~15.5 GMACs.
        let macs = vgg16().total_macs() as f64;
        assert!((14.5e9..16.5e9).contains(&macs), "{macs}");
        assert_eq!(vgg16().layers.len(), 16);
    }

    #[test]
    fn lenet_is_tiny_everywhere() {
        // Every layer's reduction dimension fits inside a 128-value sum.
        for l in &lenet().layers {
            assert!(l.weight_rows() <= 400, "{}: {}", l.name, l.weight_rows());
        }
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(by_name("resnet18").unwrap().layers.len(), 21);
        assert_eq!(by_name("VGG16").unwrap().name, "vgg16");
        assert!(by_name("alexnet").is_err());
    }

    #[test]
    fn toml_workload_roundtrip() {
        let doc = r#"
name = "toy"
[layers.conv1]
kind = "conv"
c = 3
k = 8
r = 3
s = 3
p = 8
q = 8
[layers.head]
kind = "fc"
c = 512
k = 10
"#;
        let w = from_toml(doc).unwrap();
        assert_eq!(w.name, "toy");
        assert_eq!(w.layers.len(), 2);
        let conv = w.layer("conv1").unwrap();
        assert_eq!(conv.macs(), 3 * 8 * 9 * 64);
        let fc = w.layer("head").unwrap();
        assert_eq!(fc.weights(), 5120);
    }

    #[test]
    fn toml_errors() {
        assert!(from_toml("name = \"x\"").is_err());
        let bad_kind = "name = \"x\"\n[layers.a]\nkind = \"pool\"\nc = 1\nk = 1";
        assert!(from_toml(bad_kind).is_err());
    }
}
