//! Layer-to-crossbar mapping and action counting (the CiMLoop analogue).
//!
//! Maps one DNN layer's im2col weight matrix onto a [`CimArch`] and
//! derives the per-component action counts that the energy rollup prices:
//! ADC converts, crossbar cell reads, DAC row drives, sample-and-holds,
//! shift-adds, and buffer traffic. The mapping follows the standard
//! ISAAC/RAELLA scheme: weights stay resident (weight-stationary),
//! activations stream bit-serially, each physical column is read through
//! an ADC once per (output position, bit-plane, row chunk).

use crate::arch::CimArch;
use crate::error::{Error, Result};
use crate::workload::Layer;

/// Per-component action counts for one layer inference.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ActionCounts {
    /// ADC conversions.
    pub adc_converts: f64,
    /// Crossbar cell activations (cell x bit-plane).
    pub cell_reads: f64,
    /// DAC / wordline drives (row x bit-plane x position).
    pub dac_drives: f64,
    /// Column sample-and-hold operations.
    pub sh_samples: f64,
    /// Digital shift-add operations.
    pub shift_add_ops: f64,
    /// Register bits moved (input staging + output collection).
    pub register_bits: f64,
    /// Local SRAM bytes accessed.
    pub sram_bytes: f64,
    /// Global eDRAM bytes accessed.
    pub edram_bytes: f64,
    /// NoC flits (32-byte) moved.
    pub noc_flits: f64,
}

/// The result of mapping a layer onto an architecture.
#[derive(Clone, Debug)]
pub struct Mapping {
    /// Row chunks: ADC converts needed to cover the reduction dimension.
    pub row_chunks: usize,
    /// Physical columns used (logical channels x column slices).
    pub cols_used: usize,
    /// Crossbar arrays needed to hold the layer's weights.
    pub arrays_used: usize,
    /// Analog sum utilization in (0, 1]: how full the average analog sum
    /// is relative to the architecture's `sum_size` (the paper's Fig. 4
    /// x-axis notion).
    pub utilization: f64,
    /// Action counts for one inference of this layer.
    pub counts: ActionCounts,
    /// ADC-bound latency for one inference, seconds.
    pub latency_s: f64,
}

/// Map `layer` onto `arch`, deriving action counts for one inference.
pub fn map_layer(arch: &CimArch, layer: &Layer) -> Result<Mapping> {
    arch.validate()?;
    let rows = layer.weight_rows();
    let k = layer.weight_cols();
    let positions = layer.output_positions() as f64;
    if rows == 0 || k == 0 {
        return Err(Error::Mapping(format!("layer {} has empty weights", layer.name)));
    }

    let col_slices = arch.col_slices();
    let planes = arch.planes() as f64;
    let cols_used = k * col_slices;

    // The analog sum covers min(sum_size, rows) values per convert; the
    // reduction dimension needs ceil(rows / sum_size) sequential chunks.
    let row_chunks = rows.div_ceil(arch.sum_size);
    let utilization = rows as f64 / (row_chunks * arch.sum_size) as f64;

    // Weight storage: arrays are tiled rows x cols.
    let arrays_rows = rows.div_ceil(arch.array_rows);
    let arrays_cols = cols_used.div_ceil(arch.array_cols);
    let arrays_used = arrays_rows * arrays_cols;

    // One convert per (position, plane, physical column, row chunk).
    let adc_converts = positions * planes * cols_used as f64 * row_chunks as f64;
    // Only occupied rows are driven / read.
    let dac_drives = positions * planes * rows as f64;
    let cell_reads = dac_drives * cols_used as f64;
    // Each convert is preceded by a column sample and followed by a
    // shift-add into the digital accumulator.
    let sh_samples = adc_converts;
    let shift_add_ops = adc_converts;

    // Input staging: each input value is registered once per position
    // (act_bits each); outputs collected at 2 bytes per channel.
    let register_bits =
        positions * rows as f64 * arch.act_bits as f64 + positions * k as f64 * 16.0;
    // SRAM: im2col input reads (1 byte per value) + output writes.
    let sram_bytes = positions * rows as f64 + positions * k as f64 * 2.0;
    // eDRAM: unique input activations (~rows / (r·s) channels per
    // position) + outputs spilled once.
    let edram_bytes = positions * layer.c as f64 + positions * k as f64 * 2.0;
    let noc_flits = edram_bytes / 32.0;

    let latency_s = adc_converts / arch.adc.total_throughput;

    Ok(Mapping {
        row_chunks,
        cols_used,
        arrays_used,
        utilization,
        counts: ActionCounts {
            adc_converts,
            cell_reads,
            dac_drives,
            sh_samples,
            shift_add_ops,
            register_bits,
            sram_bytes,
            edram_bytes,
            noc_flits,
        },
        latency_s,
    })
}

/// Arrays needed to keep a whole workload's weights resident.
pub fn arrays_for_workload(arch: &CimArch, layers: &[Layer]) -> usize {
    layers
        .iter()
        .map(|l| map_layer(arch, l).map(|m| m.arrays_used).unwrap_or(0))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::raella::{RaellaVariant, raella};
    use crate::workload::Layer;

    fn large() -> Layer {
        crate::workload::resnet18::large_tensor_layer()
    }

    fn small() -> Layer {
        crate::workload::resnet18::small_tensor_layer()
    }

    #[test]
    fn row_chunks_shrink_with_sum_size() {
        let l = large(); // rows = 4608
        let chunks: Vec<usize> = RaellaVariant::ALL
            .iter()
            .map(|&v| map_layer(&raella(v), &l).unwrap().row_chunks)
            .collect();
        assert_eq!(chunks, vec![36, 9, 3, 1]);
    }

    #[test]
    fn converts_scale_with_chunks() {
        let l = large();
        let s = map_layer(&raella(RaellaVariant::Small), &l).unwrap();
        let xl = map_layer(&raella(RaellaVariant::ExtraLarge), &l).unwrap();
        assert!((s.counts.adc_converts / xl.counts.adc_converts - 36.0).abs() < 1e-9);
        // Exact count: P·Q=49, planes=8, cols=512·4=2048, chunks.
        let expect = 49.0 * 8.0 * 2048.0 * 36.0;
        assert!((s.counts.adc_converts - expect).abs() < 1e-6);
    }

    #[test]
    fn small_layer_converts_are_sum_size_invariant() {
        // rows=64 < 128: every variant needs exactly one chunk, so converts
        // are identical and only per-convert ADC energy differs (the
        // paper's small-tensor mechanism).
        let l = small();
        let counts: Vec<f64> = RaellaVariant::ALL
            .iter()
            .map(|&v| map_layer(&raella(v), &l).unwrap().counts.adc_converts)
            .collect();
        assert!(counts.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-9), "{counts:?}");
    }

    #[test]
    fn utilization_definition() {
        let l = small(); // rows=64
        let s = map_layer(&raella(RaellaVariant::Small), &l).unwrap(); // sum 128
        assert!((s.utilization - 0.5).abs() < 1e-12);
        let xl = map_layer(&raella(RaellaVariant::ExtraLarge), &l).unwrap(); // sum 8192
        assert!((xl.utilization - 64.0 / 8192.0).abs() < 1e-12);
        let full = map_layer(&raella(RaellaVariant::ExtraLarge), &large()).unwrap();
        assert!((full.utilization - 4608.0 / 8192.0).abs() < 1e-12);
        assert!(full.utilization <= 1.0);
    }

    #[test]
    fn non_adc_counts_are_variant_invariant() {
        // DAC and cell activity depend on occupied rows only — identical
        // across S/M/L/XL (same weights, same slicing).
        let l = large();
        let ms: Vec<Mapping> = RaellaVariant::ALL
            .iter()
            .map(|&v| map_layer(&raella(v), &l).unwrap())
            .collect();
        for m in &ms[1..] {
            assert_eq!(m.counts.dac_drives, ms[0].counts.dac_drives);
            assert_eq!(m.counts.cell_reads, ms[0].counts.cell_reads);
            assert_eq!(m.counts.sram_bytes, ms[0].counts.sram_bytes);
        }
    }

    #[test]
    fn mac_conservation() {
        // cell_reads == MACs x planes x col_slices: every MAC touches each
        // of its bit-plane x slice combinations exactly once.
        let arch = raella(RaellaVariant::Medium);
        let l = large();
        let m = map_layer(&arch, &l).unwrap();
        let expect = l.macs() as f64 * arch.planes() as f64 * arch.col_slices() as f64;
        assert!((m.counts.cell_reads - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn arrays_used_covers_weights() {
        let arch = raella(RaellaVariant::Medium);
        let l = large(); // 4608 x 2048 physical
        let m = map_layer(&arch, &l).unwrap();
        assert_eq!(m.arrays_used, 9 * 4);
        assert!(m.arrays_used * arch.array_rows * arch.array_cols >= l.weights() * 4);
    }

    #[test]
    fn latency_is_adc_bound() {
        let mut arch = raella(RaellaVariant::Medium);
        arch.adc.total_throughput = 1e9;
        let m = map_layer(&arch, &large()).unwrap();
        assert!((m.latency_s - m.counts.adc_converts / 1e9).abs() < 1e-15);
    }

    #[test]
    fn empty_layer_rejected() {
        let l = Layer::conv("bad", 0, 8, 3, 3, 1, 1);
        assert!(map_layer(&raella(RaellaVariant::Small), &l).is_err());
    }
}
