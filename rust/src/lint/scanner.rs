//! Lexical scanner behind `cimdse lint`.
//!
//! This is deliberately *not* a Rust parser: the lint rules only need to
//! know, per line, which text is code and which is comment, with string
//! and char-literal contents neutralized so a string that merely
//! *mentions* `unsafe` or `HashMap` cannot trip a rule. A small
//! hand-rolled state machine delivers exactly that:
//!
//! * `code` lines: source text with comments removed and the contents of
//!   string/char literals blanked to spaces (quotes are kept so
//!   expression shape survives, e.g. `format!("...")` still shows its
//!   argument slots).
//! * `comment` lines: the text of `//`, `///`, `//!` and (possibly
//!   nested) `/* ... */` comments, which is where `SAFETY:` audits and
//!   `lint:allow(...)` suppressions live.
//!
//! The scanner understands raw strings (`r"..."`, `r#"..."#` with any
//! hash count), byte strings, escape sequences, block-comment nesting,
//! and the `'a` lifetime vs `'a'` char-literal ambiguity. It does not
//! attempt macro expansion or type inference — rules that need more
//! (e.g. float detection) layer their own heuristics on top.

use std::fs;
use std::mem;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Scanner state.
enum S {
    Normal,
    LineComment,
    BlockComment,
    Str,
    RawStr,
    Char,
}

/// Split `text` into per-line `(code, comment)` strings.
///
/// Every `\n` in the input produces one entry in each vector (plus one
/// final entry for the trailing partial line), so indices align with
/// 0-based line numbers of the raw text.
pub fn scan_text(text: &str) -> (Vec<String>, Vec<String>) {
    let cs: Vec<char> = text.chars().collect();
    let n = cs.len();
    let mut code: Vec<String> = Vec::new();
    let mut comm: Vec<String> = Vec::new();
    let mut cur_code = String::new();
    let mut cur_comm = String::new();
    let mut state = S::Normal;
    let mut depth = 0usize; // block-comment nesting
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        let nxt = if i + 1 < n { cs[i + 1] } else { '\0' };
        if c == '\n' {
            code.push(mem::take(&mut cur_code));
            comm.push(mem::take(&mut cur_comm));
            if matches!(state, S::LineComment) {
                state = S::Normal;
            }
            i += 1;
            continue;
        }
        match state {
            S::Normal => {
                if c == '/' && nxt == '/' {
                    state = S::LineComment;
                    i += 2;
                } else if c == '/' && nxt == '*' {
                    state = S::BlockComment;
                    depth = 1;
                    i += 2;
                } else if c == '"' {
                    cur_code.push('"');
                    state = S::Str;
                    i += 1;
                } else if c == 'r' && (nxt == '"' || nxt == '#') {
                    // raw string r"..." or r#"..."# (any hash count)
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        cur_code.push_str("r\"");
                        state = S::RawStr;
                        raw_hashes = h;
                        i = j + 1;
                    } else {
                        cur_code.push(c);
                        i += 1;
                    }
                } else if c == 'b' && nxt == '"' {
                    cur_code.push_str("b\"");
                    state = S::Str;
                    i += 2;
                } else if c == '\'' {
                    if nxt == '\\' {
                        // escaped char literal: '\n', '\\', '\x7f', ...
                        cur_code.push('\'');
                        state = S::Char;
                        i += 1;
                    } else {
                        let after = if i + 2 < n { cs[i + 2] } else { '\0' };
                        if (nxt.is_alphanumeric() || nxt == '_') && after != '\'' {
                            // lifetime: 'a not followed by a closing quote
                            cur_code.push('\'');
                            i += 1;
                        } else {
                            cur_code.push_str("' ");
                            state = S::Char;
                            i += 2;
                        }
                    }
                } else {
                    cur_code.push(c);
                    i += 1;
                }
            }
            S::LineComment => {
                cur_comm.push(c);
                i += 1;
            }
            S::BlockComment => {
                if c == '/' && nxt == '*' {
                    depth += 1;
                    cur_comm.push_str("/*");
                    i += 2;
                } else if c == '*' && nxt == '/' {
                    depth -= 1;
                    i += 2;
                    if depth == 0 {
                        state = S::Normal;
                    } else {
                        cur_comm.push_str("*/");
                    }
                } else {
                    cur_comm.push(c);
                    i += 1;
                }
            }
            S::Str => {
                if c == '\\' {
                    if nxt == '\n' {
                        // line continuation inside a string literal: the
                        // newline still has to produce a line entry.
                        cur_code.push(' ');
                        code.push(mem::take(&mut cur_code));
                        comm.push(mem::take(&mut cur_comm));
                        i += 2;
                    } else {
                        cur_code.push_str("  ");
                        i += 2;
                    }
                } else if c == '"' {
                    cur_code.push('"');
                    state = S::Normal;
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            S::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' && h < raw_hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == raw_hashes {
                        cur_code.push('"');
                        state = S::Normal;
                        i = j;
                    } else {
                        cur_code.push(' ');
                        i += 1;
                    }
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
            S::Char => {
                if c == '\\' {
                    cur_code.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    cur_code.push('\'');
                    state = S::Normal;
                    i += 1;
                } else {
                    cur_code.push(' ');
                    i += 1;
                }
            }
        }
    }
    code.push(cur_code);
    comm.push(cur_comm);
    (code, comm)
}

/// True when `c` can be part of an identifier-ish word.
pub fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// True when `needle` occurs in `hay` as a whole word (neither neighbor
/// is an identifier character).
pub fn has_word(hay: &str, needle: &str) -> bool {
    find_word(hay, needle, 0).is_some()
}

/// Byte offset of the first whole-word occurrence of `needle` in `hay`
/// at or after `from`.
pub fn find_word(hay: &str, needle: &str, from: usize) -> Option<usize> {
    let mut start = from;
    while let Some(off) = hay[start..].find(needle) {
        let pos = start + off;
        let before_ok = hay[..pos].chars().next_back().map_or(true, |c| !is_ident(c));
        let after_ok = hay[pos + needle.len()..]
            .chars()
            .next()
            .map_or(true, |c| !is_ident(c));
        if before_ok && after_ok {
            return Some(pos);
        }
        start = pos + needle.len().max(1);
        if start >= hay.len() {
            return None;
        }
    }
    None
}

/// Extract every `lint:allow(rule-name)` marker from a comment line.
fn allow_markers(comment: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find("lint:allow(") {
        let after = &rest[pos + "lint:allow(".len()..];
        if let Some(end) = after.find(')') {
            let name = &after[..end];
            if !name.is_empty()
                && name
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c == '-')
            {
                out.push(name.to_string());
            }
            rest = &after[end..];
        } else {
            break;
        }
    }
    out
}

/// One scanned source file, ready for rule checks.
pub struct ScannedFile {
    /// Path relative to the lint root, with `/` separators.
    pub rel: String,
    /// Raw source lines (needed where string *contents* matter: format
    /// strings, `cfg(feature = "pjrt")` attributes, error-code consts).
    pub raw_lines: Vec<String>,
    /// Per-line code text (comments stripped, literals blanked).
    pub code: Vec<String>,
    /// Per-line comment text.
    pub comments: Vec<String>,
    /// Per-line `lint:allow(...)` rule names.
    allows: Vec<Vec<String>>,
}

impl ScannedFile {
    /// Scan `text` as the contents of `rel`.
    pub fn from_text(rel: &str, text: &str) -> ScannedFile {
        let raw_lines: Vec<String> = text.split('\n').map(str::to_string).collect();
        let (code, comments) = scan_text(text);
        let allows = comments.iter().map(|c| allow_markers(c)).collect();
        ScannedFile {
            rel: rel.to_string(),
            raw_lines,
            code,
            comments,
            allows,
        }
    }

    /// Is `rule` suppressed at 0-based `line_idx`?
    ///
    /// A `// lint:allow(rule) — reason` marker applies to its own line
    /// and to the first code line below it: the marker may sit on the
    /// offending line itself or anywhere in the contiguous run of
    /// comment/blank lines directly above it (so multi-line
    /// justification comments work).
    pub fn allowed(&self, rule: &str, line_idx: usize) -> bool {
        if line_idx < self.allows.len() && self.allows[line_idx].iter().any(|r| r == rule) {
            return true;
        }
        let mut k = line_idx;
        while k > 0 && self.code[k - 1].trim().is_empty() {
            k -= 1;
            if self.allows[k].iter().any(|r| r == rule) {
                return true;
            }
        }
        false
    }
}

/// Recursively collect `.rs` files under `dir`, skipping any directory
/// named `lint_fixtures` (fixtures are deliberately rule-breaking).
fn walk_dir(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .map_err(Error::Io)?
        .collect::<std::io::Result<Vec<_>>>()
        .map_err(Error::Io)?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if name == "lint_fixtures" {
                continue;
            }
            walk_dir(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `root`'s `src/`, `tests/` and `benches/`
/// directories, in deterministic (sorted-path) order.
pub fn scan_root(root: &Path) -> Result<Vec<ScannedFile>> {
    let mut paths = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk_dir(&dir, &mut paths)?;
        }
    }
    let mut files = Vec::with_capacity(paths.len());
    for path in paths {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(&path).map_err(Error::Io)?;
        files.push(ScannedFile::from_text(&rel, &text));
    }
    Ok(files)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_stripped_from_code() {
        let (code, comm) = scan_text("let x = 1; // trailing\n/* block */ let y = 2;");
        assert_eq!(code[0], "let x = 1; ");
        assert_eq!(comm[0], " trailing");
        assert_eq!(code[1], " let y = 2;");
        assert_eq!(comm[1], " block ");
    }

    #[test]
    fn string_contents_are_blanked() {
        let (code, _) = scan_text(r#"call("unsafe // not a comment", x)"#);
        assert!(!code[0].contains("unsafe"));
        assert!(!code[0].contains("//"));
        assert!(code[0].starts_with("call(\""));
        assert!(code[0].ends_with("\", x)"));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let (code, _) = scan_text(r##"let s = r#"quote " inside"#; let t = 1;"##);
        assert!(code[0].contains("let t = 1;"));
        assert!(!code[0].contains("inside"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let (code, _) = scan_text("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert_eq!(code[0], "fn f<'a>(x: &'a str) -> &'a str { x }");
    }

    #[test]
    fn char_literals_are_blanked() {
        let (code, _) = scan_text("let c = 'x'; let esc = '\\\\'; let q = '\\'';");
        assert!(!code[0].contains('x'), "{}", code[0]);
        // line structure survives escaped quotes and backslashes
        assert_eq!(code.len(), 1);
        assert!(code[0].ends_with(';'));
    }

    #[test]
    fn block_comment_nesting() {
        let (code, comm) = scan_text("/* outer /* inner */ still */ let z = 3;");
        assert_eq!(code[0], " let z = 3;");
        assert!(comm[0].contains("inner"));
    }

    #[test]
    fn line_counts_match_raw() {
        let text = "a\nb\\\nc\n\"multi\nline\"\n";
        let (code, comm) = scan_text(text);
        let raw = text.split('\n').count();
        assert_eq!(code.len(), raw);
        assert_eq!(comm.len(), raw);
    }

    #[test]
    fn allow_markers_parse() {
        let f = ScannedFile::from_text(
            "x.rs",
            "// lint:allow(determinism) — reason\n// more words\nlet t = now();\n",
        );
        assert!(f.allowed("determinism", 2));
        assert!(!f.allowed("unsafe-audit", 2));
        // marker applies only through contiguous comment/blank lines
        let g = ScannedFile::from_text(
            "y.rs",
            "// lint:allow(determinism) — reason\nlet a = 1;\nlet t = now();\n",
        );
        assert!(g.allowed("determinism", 1));
        assert!(!g.allowed("determinism", 2));
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(!has_word("unsafe_fn()", "unsafe"));
        assert!(!has_word("not_unsafe", "unsafe"));
    }
}
