//! Rendering for [`LintReport`](crate::lint::LintReport): the
//! `file:line: [rule] message` text form and a schema-stable JSON form
//! built on [`config::Value`](crate::config::Value) so `--json` output
//! round-trips through the crate's own parser.

use std::collections::BTreeMap;

use crate::config::Value;
use crate::lint::{LintReport, all_rules};

/// JSON schema version of [`to_json_value`]. Bump only on breaking
/// shape changes; `tests/lint_selfcheck.rs` pins the current shape.
pub const JSON_SCHEMA_VERSION: f64 = 1.0;

/// Plain-text report: one `file:line: [rule] message` line per finding
/// plus a trailing summary line.
pub fn render_text(report: &LintReport) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    out.push_str(&format!(
        "lint: {} finding(s) across {} file(s) scanned\n",
        report.findings.len(),
        report.files_scanned
    ));
    out
}

/// Structured report for `cimdse lint --json`.
///
/// Shape (schema 1):
/// `{schema, root, files_scanned, rules: [{name, description}],`
/// `findings: [{file, line, rule, message}]}`.
pub fn to_json_value(report: &LintReport) -> Value {
    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Value::Number(JSON_SCHEMA_VERSION));
    top.insert(
        "root".to_string(),
        Value::String(report.root.to_string_lossy().into_owned()),
    );
    top.insert(
        "files_scanned".to_string(),
        Value::Number(report.files_scanned as f64),
    );
    top.insert(
        "rules".to_string(),
        Value::Array(
            all_rules()
                .iter()
                .map(|r| {
                    let mut m = BTreeMap::new();
                    m.insert("name".to_string(), Value::String(r.name().to_string()));
                    m.insert(
                        "description".to_string(),
                        Value::String(r.description().to_string()),
                    );
                    Value::Table(m)
                })
                .collect(),
        ),
    );
    top.insert(
        "findings".to_string(),
        Value::Array(
            report
                .findings
                .iter()
                .map(|f| {
                    let mut m = BTreeMap::new();
                    m.insert("file".to_string(), Value::String(f.file.clone()));
                    m.insert("line".to_string(), Value::Number(f.line as f64));
                    m.insert("rule".to_string(), Value::String(f.rule.to_string()));
                    m.insert("message".to_string(), Value::String(f.message.clone()));
                    Value::Table(m)
                })
                .collect(),
        ),
    );
    Value::Table(top)
}
