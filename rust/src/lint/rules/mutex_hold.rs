//! `mutex-hold`: no I/O or heavy statistics while a `Mutex` guard is
//! held in `src/service/`. The serving daemon shares one state mutex
//! across client threads; writing frames, flushing sockets or running
//! `quantile` over latency samples while holding it serializes every
//! other request behind the slowest client. The convention (clone out,
//! drop the guard, then work) is enforced here.
//!
//! Scope detection is lexical: a `let guard = x.lock().unwrap();`
//! binding holds to the end of its enclosing brace block; a temporary
//! `x.lock().unwrap().field` holds to the end of its statement. Every
//! lock site in the real tree is single-line, which keeps the
//! line-local `.lock().unwrap()` detection sound.

use crate::lint::scanner::find_word;
use crate::lint::{Context, Finding, Rule};

const SCOPE_PREFIX: &str = "src/service/";

/// Tokens that mean "I/O or heavy work" when they appear in guard scope.
const IO_TOKENS: &[&str] = &[
    "write_line",
    "quantile(",
    "println!",
    "eprintln!",
    "write!",
    "writeln!",
    ".flush(",
    "std::fs::",
    "File::",
    ".write_all(",
    ".read_line(",
    "read_to_string",
];

pub struct MutexHold;

impl Rule for MutexHold {
    fn name(&self) -> &'static str {
        "mutex-hold"
    }

    fn description(&self) -> &'static str {
        "no I/O or quantile work while a mutex guard is held in src/service/"
    }

    fn check(&self, ctx: &Context, out: &mut Vec<Finding>) {
        for f in &ctx.files {
            if !f.rel.starts_with(SCOPE_PREFIX) {
                continue;
            }
            // (start, end) brace depth per line
            let mut depth: i64 = 0;
            let mut depths = Vec::with_capacity(f.code.len());
            for code in &f.code {
                let start = depth;
                let opens = code.matches('{').count() as i64;
                let closes = code.matches('}').count() as i64;
                depth += opens - closes;
                depths.push((start, depth));
            }
            for (i, code) in f.code.iter().enumerate() {
                let Some(lock_pos) = code.find(".lock().unwrap()") else {
                    continue;
                };
                if f.allowed("mutex-hold", i) {
                    continue;
                }
                if is_binding(code, lock_pos) && code.trim_end().ends_with(';') {
                    // Guard lives to the end of the enclosing block.
                    let block_depth = depths[i].0;
                    let mut j = i;
                    while j < f.code.len() {
                        if j != i {
                            emit_tokens(f, j, &format!("while a mutex guard from line {} is held", i + 1), out);
                        }
                        j += 1;
                        if j < f.code.len() && depths[j].1 < block_depth {
                            break;
                        }
                    }
                } else {
                    // Temporary guard: lives to the end of the statement.
                    let mut j = i;
                    loop {
                        emit_tokens(
                            f,
                            j,
                            &format!("in a statement holding a mutex guard (line {})", i + 1),
                            out,
                        );
                        if f.code[j].trim_end().ends_with(';') || j + 1 >= f.code.len() {
                            break;
                        }
                        j += 1;
                    }
                }
            }
        }
    }
}

/// Does this line bind the guard (`let g = ....lock().unwrap()...`)?
fn is_binding(code: &str, lock_pos: usize) -> bool {
    let Some(let_pos) = find_word(code, "let", 0) else {
        return false;
    };
    if let_pos >= lock_pos {
        return false;
    }
    match code[let_pos..lock_pos].find('=') {
        Some(off) => !code[let_pos + off..lock_pos].contains(';'),
        None => false,
    }
}

fn emit_tokens(
    f: &crate::lint::scanner::ScannedFile,
    j: usize,
    why: &str,
    out: &mut Vec<Finding>,
) {
    for tok in IO_TOKENS {
        if f.code[j].contains(tok) && !f.allowed("mutex-hold", j) {
            let label = tok.trim_matches(|c| c == '(' || c == '.');
            out.push(Finding {
                rule: "mutex-hold",
                file: f.rel.clone(),
                line: j + 1,
                message: format!("`{label}` {why}"),
            });
        }
    }
}
