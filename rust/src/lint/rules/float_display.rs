//! `float-display`: no lossy float formatting in serialization-adjacent
//! code. Shard artifacts, NDJSON frames and config output round-trip
//! floats bit-exactly via `f64_to_bits_hex`/`fnum`; a stray
//! `format!("{}", x)` or `x.to_string()` silently truncates to decimal
//! and breaks the byte-identical merge guarantee. The rule flags bare
//! `{}` / `{:?}` / `{ident}` placeholders and `.to_string()` calls when
//! there is *float evidence* — the ident is annotated `f32`/`f64`
//! somewhere in the file, the expression contains a float literal, or
//! an `as f32`/`as f64` cast. Placeholders carrying an explicit spec
//! (`{:.3}`, `{:016x}`, `{:e}`) mark intentional display and pass.
//!
//! Scope: `src/service/`, `src/config/` and `src/dse/shard.rs` — the
//! files whose output crosses process boundaries.
//!
//! Heuristic caveats (documented in rust/docs/lints.md): format calls
//! are parsed line-locally (the format string and its args must share
//! the line), and float evidence for `{ident}` is the file-wide set of
//! `ident: f32/f64` annotations, not real type inference.

use std::collections::BTreeSet;

use crate::lint::scanner::{ScannedFile, find_word, is_ident};
use crate::lint::{Context, Finding, Rule};

const SCOPES: &[&str] = &["src/service/", "src/config/"];
const FILES: &[&str] = &["src/dse/shard.rs"];
const FMT_MACROS: &[&str] = &[
    "format!",
    "println!",
    "print!",
    "eprintln!",
    "eprint!",
    "write!",
    "writeln!",
];

pub struct FloatDisplay;

impl Rule for FloatDisplay {
    fn name(&self) -> &'static str {
        "float-display"
    }

    fn description(&self) -> &'static str {
        "no bare {}/{:?}/to_string() on f32/f64 in serialization paths"
    }

    fn check(&self, ctx: &Context, out: &mut Vec<Finding>) {
        for f in &ctx.files {
            let in_scope = SCOPES.iter().any(|p| f.rel.starts_with(p))
                || FILES.contains(&f.rel.as_str());
            if !in_scope {
                continue;
            }
            let idents = float_idents(f);
            for (i, raw) in f.raw_lines.iter().enumerate() {
                if f.allowed("float-display", i) {
                    continue;
                }
                let code = &f.code[i];
                check_to_string(f, i, code, &idents, out);
                if !FMT_MACROS.iter().any(|m| code.contains(m)) {
                    continue;
                }
                check_format_call(f, i, raw, &idents, out);
            }
        }
    }
}

/// File-wide set of idents annotated `: f32` / `: f64` (incl. `&`,
/// `&mut` forms) — the rule's stand-in for type inference.
fn float_idents(f: &ScannedFile) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for code in &f.code {
        let b: Vec<char> = code.chars().collect();
        let n = b.len();
        for i in 0..n {
            if b[i] != 'f' || i + 2 >= n {
                continue;
            }
            let suffix_ok = (b[i + 1] == '3' && b[i + 2] == '2')
                || (b[i + 1] == '6' && b[i + 2] == '4');
            if !suffix_ok
                || (i + 3 < n && is_ident(b[i + 3]))
                || (i > 0 && is_ident(b[i - 1]))
            {
                continue;
            }
            // walk backwards over:  ident \s* : \s* &? (mut \s+)? f{32,64}
            let mut k = i;
            if k > 0 && b[k - 1].is_whitespace() {
                let mut k2 = k;
                while k2 > 0 && b[k2 - 1].is_whitespace() {
                    k2 -= 1;
                }
                let is_mut = k2 >= 3
                    && b[k2 - 3] == 'm'
                    && b[k2 - 2] == 'u'
                    && b[k2 - 1] == 't'
                    && (k2 == 3 || !is_ident(b[k2 - 4]));
                if is_mut {
                    k = k2 - 3;
                }
            }
            if k > 0 && b[k - 1] == '&' {
                k -= 1;
            }
            while k > 0 && b[k - 1].is_whitespace() {
                k -= 1;
            }
            if k == 0 || b[k - 1] != ':' {
                continue;
            }
            k -= 1;
            while k > 0 && b[k - 1].is_whitespace() {
                k -= 1;
            }
            let end = k;
            while k > 0 && is_ident(b[k - 1]) {
                k -= 1;
            }
            if k == end {
                continue; // e.g. `std::f64` — `::` yields no ident
            }
            let run: String = b[k..end].iter().collect();
            if let Some(p) = run.find(|c: char| c.is_ascii_lowercase() || c == '_') {
                out.insert(run[p..].to_string());
            }
        }
    }
    out
}

/// Flag `ident.to_string()` when `ident` is float-annotated.
fn check_to_string(
    f: &ScannedFile,
    i: usize,
    code: &str,
    idents: &BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    let b: Vec<char> = code.chars().collect();
    let pat: Vec<char> = "to_string()".chars().collect();
    let n = b.len();
    let mut pos = 0usize;
    while pos + pat.len() <= n {
        if b[pos..pos + pat.len()] != pat[..] {
            pos += 1;
            continue;
        }
        // backwards:  ident \s* . \s* to_string()
        let mut k = pos;
        while k > 0 && b[k - 1].is_whitespace() {
            k -= 1;
        }
        if k == 0 || b[k - 1] != '.' {
            pos += pat.len();
            continue;
        }
        k -= 1;
        while k > 0 && b[k - 1].is_whitespace() {
            k -= 1;
        }
        let end = k;
        while k > 0 && is_ident(b[k - 1]) {
            k -= 1;
        }
        if k < end {
            let run: String = b[k..end].iter().collect();
            if let Some(p) = run.find(|c: char| c.is_ascii_lowercase() || c == '_') {
                let name = &run[p..];
                if idents.contains(name) {
                    out.push(Finding {
                        rule: "float-display",
                        file: f.rel.clone(),
                        line: i + 1,
                        message: format!(
                            "`{name}.to_string()` on an f32/f64; use bit-hex or fnum"
                        ),
                    });
                }
            }
        }
        pos += pat.len();
    }
}

/// Flag bare `{}` / `{:?}` / `{ident}` placeholders with float evidence.
/// Parses the *raw* line: the scanner blanks string contents, but here
/// the format string itself is the input.
fn check_format_call(
    f: &ScannedFile,
    i: usize,
    raw: &str,
    idents: &BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    let b: Vec<char> = raw.chars().collect();
    let Some((fmt, rest_start)) = first_string_literal(&b) else {
        return;
    };
    let rest: String = b[rest_start..].iter().collect();
    let rest = rest.trim_start_matches([',', ' ']);
    let args: Vec<String> = split_args(rest)
        .into_iter()
        .map(|a| a.trim().to_string())
        .collect();
    let fc: Vec<char> = fmt.chars().collect();
    let n = fc.len();
    let mut pos_arg = 0usize;
    let mut j = 0usize;
    while j < n {
        if fc[j] != '{' {
            j += 1;
            continue;
        }
        // try to parse  { ident? (:spec)? }
        let mut k = j + 1;
        let name_start = k;
        if k < n && (fc[k].is_ascii_alphabetic() || fc[k] == '_') {
            k += 1;
            while k < n && is_ident(fc[k]) {
                k += 1;
            }
        }
        let name: Option<String> = if k > name_start {
            Some(fc[name_start..k].iter().collect())
        } else {
            None
        };
        let spec_start = k;
        if k < n && fc[k] == ':' {
            k += 1;
            while k < n && fc[k] != '}' {
                k += 1;
            }
        }
        let spec: String = fc[spec_start..k].iter().collect();
        if k >= n || fc[k] != '}' {
            j += 1; // not a placeholder; resume scan at next char
            continue;
        }
        j = k + 1;
        if !(spec.is_empty() || spec == ":?") {
            // explicit spec (precision, width, hex, ...) = intentional
            if name.is_none() {
                pos_arg += 1;
            }
            continue;
        }
        match name {
            Some(nm) => {
                if idents.contains(&nm) {
                    out.push(Finding {
                        rule: "float-display",
                        file: f.rel.clone(),
                        line: i + 1,
                        message: format!(
                            "bare `{{{nm}}}` formats an f32/f64; use bit-hex/fnum or a precision spec"
                        ),
                    });
                }
            }
            None => {
                if pos_arg < args.len() && float_evidence(&args[pos_arg], idents) {
                    out.push(Finding {
                        rule: "float-display",
                        file: f.rel.clone(),
                        line: i + 1,
                        message: format!(
                            "bare `{{}}` formats float expr `{}`; use bit-hex/fnum or a precision spec",
                            args[pos_arg]
                        ),
                    });
                }
                pos_arg += 1;
            }
        }
    }
}

/// First `"..."` literal on the raw line (escape-aware). Returns the
/// contents and the char index just past the closing quote.
fn first_string_literal(b: &[char]) -> Option<(String, usize)> {
    let q = b.iter().position(|&c| c == '"')?;
    let mut i = q + 1;
    let mut content = String::new();
    while i < b.len() {
        if b[i] == '\\' && i + 1 < b.len() {
            content.push(b[i]);
            content.push(b[i + 1]);
            i += 2;
        } else if b[i] == '"' {
            return Some((content, i + 1));
        } else {
            content.push(b[i]);
            i += 1;
        }
    }
    None
}

/// Split trailing macro arguments on top-level commas; stop at the
/// macro's closing delimiter.
fn split_args(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut depth = 0i32;
    for c in s.chars() {
        if "([{".contains(c) {
            depth += 1;
        } else if ")]}".contains(c) {
            depth -= 1;
            if depth < 0 {
                break;
            }
        }
        if c == ',' && depth == 0 {
            out.push(std::mem::take(&mut cur));
        } else {
            cur.push(c);
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Does `expr` smell like a float? (cast, float literal, or a
/// float-annotated ident.)
fn float_evidence(expr: &str, idents: &BTreeSet<String>) -> bool {
    // `as f32` / `as f64`
    let mut from = 0;
    while let Some(pos) = find_word(expr, "as", from) {
        let rest = &expr[pos + 2..];
        let trimmed = rest.trim_start();
        if trimmed.len() < rest.len()
            && (find_word(trimmed, "f32", 0) == Some(0) || find_word(trimmed, "f64", 0) == Some(0))
        {
            return true;
        }
        from = pos + 2;
    }
    // decimal float literal
    let b: Vec<char> = expr.chars().collect();
    let n = b.len();
    let mut i = 0;
    while i < n {
        if b[i].is_ascii_digit() && (i == 0 || !(is_ident(b[i - 1]) || b[i - 1] == '.')) {
            let mut j = i;
            while j < n && b[j].is_ascii_digit() {
                j += 1;
            }
            if j < n && b[j] == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                return true;
            }
            i = j.max(i + 1);
        } else {
            i += 1;
        }
    }
    // float-annotated ident
    let mut start = None;
    for (idx, c) in b.iter().enumerate() {
        if is_ident(*c) {
            if start.is_none() {
                start = Some(idx);
            }
        } else if let Some(s) = start.take() {
            if ident_run_matches(&b[s..idx], idents) {
                return true;
            }
        }
    }
    if let Some(s) = start {
        if ident_run_matches(&b[s..], idents) {
            return true;
        }
    }
    false
}

/// Membership check for one maximal ident run, mirroring the lexical
/// convention that idents start `[a-z_]`.
fn ident_run_matches(run: &[char], idents: &BTreeSet<String>) -> bool {
    let s: String = run.iter().collect();
    match s.find(|c: char| c.is_ascii_lowercase() || c == '_') {
        Some(p) => idents.contains(&s[p..]),
        None => false,
    }
}
