//! `determinism`: fingerprinted/serialized paths must not consult wall
//! clocks, iterate unordered maps, or call ULP-bounded fast-tier math.
//! Sweep fingerprints, shard artifacts and NDJSON frames are diffed
//! byte-for-byte across processes (see `merge-shards` and the serve
//! protocol), so `SystemTime::now` / `Instant::now` readings and
//! `HashMap` iteration order must never reach those payloads — and
//! neither may the approximate sweep tier (`util::fastmath`,
//! `PreparedRowLanes`, `pow10_fast`), whose results are only
//! ULP-bounded against the bit-exact reference. `obs::` is banned for
//! the same reason: trace spans carry monotonic timestamps and
//! process-local ids, so nothing from the tracing layer may flow into a
//! fingerprinted or serialized payload. The rule is scoped to
//! the files that build those payloads: `src/config/` (serializers),
//! `src/dse/shard.rs` (artifacts + fingerprints) and the
//! protocol/server pair. Legitimate uses (e.g. latency metrics in the
//! server, or `obs::server_span` whose data flows only to the trace
//! sink) carry a `lint:allow(determinism)` with the reason.

use crate::lint::{Context, Finding, Rule};

const DET_FILES: &[&str] = &[
    "src/dse/shard.rs",
    "src/service/protocol.rs",
    "src/service/server.rs",
];
const DET_SCOPES: &[&str] = &["src/config/"];
const DET_TOKENS: &[&str] = &[
    "SystemTime::now",
    "Instant::now",
    "HashMap",
    "fastmath",
    "PreparedRowLanes",
    "pow10_fast",
    "obs::",
];

pub struct Determinism;

impl Rule for Determinism {
    fn name(&self) -> &'static str {
        "determinism"
    }

    fn description(&self) -> &'static str {
        "no wall-clock reads, HashMap, or fast-tier math in fingerprinted/serialized paths"
    }

    fn check(&self, ctx: &Context, out: &mut Vec<Finding>) {
        for f in &ctx.files {
            let in_scope = DET_FILES.contains(&f.rel.as_str())
                || DET_SCOPES.iter().any(|p| f.rel.starts_with(p));
            if !in_scope {
                continue;
            }
            for (i, code) in f.code.iter().enumerate() {
                for tok in DET_TOKENS {
                    if code.contains(tok) && !f.allowed("determinism", i) {
                        out.push(Finding {
                            rule: "determinism",
                            file: f.rel.clone(),
                            line: i + 1,
                            message: format!("`{tok}` in a fingerprinted/serialized path"),
                        });
                    }
                }
            }
        }
    }
}
