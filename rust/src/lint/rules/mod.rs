//! The individual lint rules. Each rule lives in its own module and is
//! registered in [`all_rules`](crate::lint::all_rules); rule names are
//! stable and documented in `rust/docs/lints.md`.

pub mod dep_hygiene;
pub mod determinism;
pub mod error_codes;
pub mod float_display;
pub mod mutex_hold;
pub mod unsafe_audit;
