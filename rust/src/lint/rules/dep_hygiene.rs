//! `dep-hygiene`: the crate stays zero-dependency. `Cargo.toml` may
//! list only optional vendored path dependencies (the `xla` PJRT shim),
//! never registry crates or `[dev-dependencies]`; the `pjrt` backend
//! module must be compiled only behind `#[cfg(feature = "pjrt")]`; and
//! no `xla::` reference may appear outside `src/runtime/pjrt.rs` unless
//! its enclosing top-level item carries the same cfg gate (e.g. the
//! `From<xla::Error>` impl in `src/error.rs`).

use std::fs;

use crate::lint::scanner::{find_word, is_ident, scan_text};
use crate::lint::{Context, Finding, Rule};

/// The one module allowed to talk to `xla` ungated.
const BACKEND_RS: &str = "src/runtime/pjrt.rs";
const GATE: &str = "feature = \"pjrt\"";

pub struct DepHygiene;

impl Rule for DepHygiene {
    fn name(&self) -> &'static str {
        "dep-hygiene"
    }

    fn description(&self) -> &'static str {
        "zero external deps; pjrt backend and xla refs gated behind the pjrt feature"
    }

    fn check(&self, ctx: &Context, out: &mut Vec<Finding>) {
        check_cargo_toml(ctx, out);
        check_mod_gating(ctx, out);
        check_xla_refs(ctx, out);
    }
}

/// `[dependencies]` may only hold optional vendored path deps; no
/// `[dev-dependencies]` / `[build-dependencies]` sections at all.
fn check_cargo_toml(ctx: &Context, out: &mut Vec<Finding>) {
    let Ok(text) = fs::read_to_string(ctx.root.join("Cargo.toml")) else {
        return;
    };
    let mut section: Option<String> = None;
    for (i, line) in text.split('\n').enumerate() {
        let s = line.trim();
        if s.starts_with('[') {
            section = Some(s.to_string());
            if s == "[dev-dependencies]" || s == "[build-dependencies]" {
                out.push(Finding {
                    rule: "dep-hygiene",
                    file: "Cargo.toml".to_string(),
                    line: i + 1,
                    message: format!("{s} is not allowed (zero-dependency crate)"),
                });
            }
            continue;
        }
        if section.as_deref() == Some("[dependencies]")
            && !s.is_empty()
            && !s.starts_with('#')
            && s.contains('=')
            && !(s.contains("path") && s.contains("vendor/") && s.contains("optional = true"))
        {
            let name = s.split('=').next().unwrap_or("").trim();
            out.push(Finding {
                rule: "dep-hygiene",
                file: "Cargo.toml".to_string(),
                line: i + 1,
                message: format!(
                    "external dependency `{name}` (only optional vendored path deps are allowed)"
                ),
            });
        }
    }
}

/// If the backend module exists, `runtime/mod.rs` must gate it: the
/// nearest code line above `mod pjrt` must be a `#[cfg(feature =
/// "pjrt")]` attribute (comment/blank lines in between are fine,
/// comments *mentioning* the gate are not enough).
fn check_mod_gating(ctx: &Context, out: &mut Vec<Finding>) {
    let modrs = ctx.root.join("src/runtime/mod.rs");
    if !ctx.root.join(BACKEND_RS).exists() || !modrs.exists() {
        return;
    }
    let Ok(text) = fs::read_to_string(&modrs) else {
        return;
    };
    let raw: Vec<&str> = text.split('\n').collect();
    let (code, _) = scan_text(&text);
    for (i, line) in code.iter().enumerate() {
        if !is_mod_pjrt(line) {
            continue;
        }
        let mut k = i;
        let mut gated = false;
        while k > 0 {
            k -= 1;
            if code[k].trim().is_empty() {
                continue; // comment or blank line
            }
            gated = code[k].trim().starts_with('#') && raw[k].contains(GATE);
            break;
        }
        if !gated {
            out.push(Finding {
                rule: "dep-hygiene",
                file: "src/runtime/mod.rs".to_string(),
                line: i + 1,
                message: "`mod pjrt` is not gated behind #[cfg(feature = \"pjrt\")]".to_string(),
            });
        }
    }
}

/// `mod pjrt` as two whole words separated by whitespace.
fn is_mod_pjrt(code: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = find_word(code, "mod", from) {
        let rest = &code[pos + 3..];
        let trimmed = rest.trim_start();
        if trimmed.len() < rest.len() && find_word(trimmed, "pjrt", 0) == Some(0) {
            return true;
        }
        from = pos + 3;
    }
    false
}

/// Any `xla::` / `use xla` reference outside the backend module must sit
/// inside a top-level item gated with `#[cfg(feature = "pjrt")]`.
fn check_xla_refs(ctx: &Context, out: &mut Vec<Finding>) {
    for f in &ctx.files {
        if f.rel == BACKEND_RS {
            continue;
        }
        let mut depth: i64 = 0;
        let mut gated = false;
        for (i, code) in f.code.iter().enumerate() {
            let start = depth;
            depth += code.matches('{').count() as i64 - code.matches('}').count() as i64;
            let stripped = code.trim();
            let is_attr = start == 0
                && stripped.starts_with('#')
                && f.raw_lines[i].contains(&format!("cfg({GATE})"));
            if is_attr {
                gated = true;
            }
            if references_xla(code) && !gated && !f.allowed("dep-hygiene", i) {
                out.push(Finding {
                    rule: "dep-hygiene",
                    file: f.rel.clone(),
                    line: i + 1,
                    message: "`xla` referenced outside a #[cfg(feature = \"pjrt\")]-gated item"
                        .to_string(),
                });
            }
            if depth == 0 && !is_attr && !stripped.is_empty() && !stripped.starts_with('#') {
                gated = false;
            }
        }
    }
}

/// `xla::` (word-bounded) or `use xla` anywhere on the code line.
fn references_xla(code: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find("xla::").map(|o| from + o) {
        if code[..pos].chars().next_back().map_or(true, |c| !is_ident(c)) {
            return true;
        }
        from = pos + 5;
    }
    let mut from = 0;
    while let Some(pos) = find_word(code, "use", from) {
        let rest = &code[pos + 3..];
        let trimmed = rest.trim_start();
        if trimmed.len() < rest.len() && find_word(trimmed, "xla", 0) == Some(0) {
            return true;
        }
        from = pos + 3;
    }
    false
}
