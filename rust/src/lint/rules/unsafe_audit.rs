//! `unsafe-audit`: every `unsafe` keyword must be justified by a
//! `// SAFETY:` comment on the same line or within the five lines
//! above it. The scanner already strips comments and blanks string
//! literals, so doc-comment *mentions* of `unsafe` (e.g. the
//! `exec::unchecked` module docs) and strings never trip the rule.

use crate::lint::scanner::has_word;
use crate::lint::{Context, Finding, Rule};

/// How far above the `unsafe` line a `SAFETY:` comment may sit.
const SAFETY_WINDOW: usize = 5;

pub struct UnsafeAudit;

impl Rule for UnsafeAudit {
    fn name(&self) -> &'static str {
        "unsafe-audit"
    }

    fn description(&self) -> &'static str {
        "every `unsafe` must carry a `// SAFETY:` comment within the 5 lines above"
    }

    fn check(&self, ctx: &Context, out: &mut Vec<Finding>) {
        for f in &ctx.files {
            for (i, code) in f.code.iter().enumerate() {
                if !has_word(code, "unsafe") {
                    continue;
                }
                let lo = i.saturating_sub(SAFETY_WINDOW);
                let audited = f.comments[lo..=i].iter().any(|c| c.contains("SAFETY:"));
                if !audited && !f.allowed("unsafe-audit", i) {
                    out.push(Finding {
                        rule: "unsafe-audit",
                        file: f.rel.clone(),
                        line: i + 1,
                        message: "`unsafe` without a `// SAFETY:` comment in the 5 lines above"
                            .to_string(),
                    });
                }
            }
        }
    }
}
