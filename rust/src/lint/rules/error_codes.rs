//! `error-code-registry`: the NDJSON protocol's stable error codes are
//! declared in three places that historically drifted by hand —
//! `pub const CODE_*` in `src/service/protocol.rs`, the code table in
//! `docs/protocol.md`, and the `expect` fields of
//! `tests/protocol_corpus.json`. This rule machine-verifies the three
//! sets are identical: every source code must be documented *and*
//! exercised by at least one corpus case, every documented code must
//! exist in source, and the corpus must not expect phantom codes.
//!
//! The extraction helpers are `pub` so `tests/lint_selfcheck.rs` can
//! assert set identity directly (including `internal` and
//! `over-budget`, the two codes that drifted before this rule existed).

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::config::{Value, parse_json};
use crate::error::{Error, Result};
use crate::lint::scanner::ScannedFile;
use crate::lint::{Context, Finding, Rule};

/// Where the three registries live, relative to the lint root.
pub const PROTOCOL_RS: &str = "src/service/protocol.rs";
pub const PROTOCOL_MD: &str = "docs/protocol.md";
pub const CORPUS_JSON: &str = "tests/protocol_corpus.json";

/// `code -> 1-based line` of every `pub const CODE_*: &str = "..."` in
/// the protocol source. Works on raw lines because the scanner blanks
/// string contents in code lines.
pub fn source_codes(proto: &ScannedFile) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for (i, line) in proto.raw_lines.iter().enumerate() {
        if let Some(code) = parse_code_const(line) {
            out.entry(code).or_insert(i + 1);
        }
    }
    out
}

/// Parse one `pub const CODE_X: &str = "value";` line.
fn parse_code_const(line: &str) -> Option<String> {
    let pos = line.find("pub const CODE_")?;
    let rest = &line[pos + "pub const CODE_".len()..];
    let name_len = rest
        .find(|c: char| !(c.is_ascii_uppercase() || c == '_'))
        .unwrap_or(rest.len());
    if name_len == 0 {
        return None;
    }
    let rest = rest[name_len..].strip_prefix(':')?.trim_start();
    let rest = rest.strip_prefix("&str")?.trim_start();
    let rest = rest.strip_prefix('=')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    let end = rest.find('"')?;
    if end == 0 {
        return None;
    }
    Some(rest[..end].to_string())
}

/// `code -> 1-based line` of every code documented in the
/// `docs/protocol.md` error-code table (the table whose header row's
/// first cell is `code`; code cells are backtick-wrapped kebab-case).
pub fn doc_codes(text: &str) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    let mut in_table = false;
    for (i, line) in text.split('\n').enumerate() {
        let stripped = line.trim();
        if let Some(body) = stripped.strip_prefix('|') {
            let body = body.strip_suffix('|').unwrap_or(body);
            let first = body.split('|').next().unwrap_or("").trim();
            if first == "code" {
                in_table = true;
                continue;
            }
            if in_table {
                if let Some(code) = backtick_code(first) {
                    out.entry(code).or_insert(i + 1);
                }
            }
        } else {
            in_table = false;
        }
    }
    out
}

/// `` `kebab-case` `` cell -> `kebab-case`.
fn backtick_code(cell: &str) -> Option<String> {
    let inner = cell.strip_prefix('`')?.strip_suffix('`')?;
    if !inner.is_empty()
        && inner
            .chars()
            .all(|c| c.is_ascii_lowercase() || c == '-')
    {
        Some(inner.to_string())
    } else {
        None
    }
}

/// `code -> first case name` for every non-`ok` `expect` in the corpus.
pub fn corpus_codes(text: &str) -> Result<BTreeMap<String, String>> {
    let doc = parse_json(text)?;
    let mut out = BTreeMap::new();
    let cases = doc
        .get("cases")
        .and_then(Value::as_array)
        .ok_or_else(|| Error::Config("protocol corpus has no `cases` array".to_string()))?;
    for case in cases {
        let expect = case.get("expect").and_then(Value::as_str);
        if let Some(e) = expect {
            if !e.is_empty() && e != "ok" {
                let name = case
                    .get("name")
                    .and_then(Value::as_str)
                    .unwrap_or("<unnamed>");
                out.entry(e.to_string()).or_insert_with(|| name.to_string());
            }
        }
    }
    Ok(out)
}

/// The three code registries for the tree at `root`, for direct set
/// comparison in tests.
pub struct CodeSets {
    pub source: BTreeMap<String, usize>,
    pub docs: BTreeMap<String, usize>,
    pub corpus: BTreeMap<String, String>,
}

/// Extract all three registries from `root`. Errors if any of the three
/// files is missing or unparsable — the real tree must always have all
/// of them.
pub fn code_sets(root: &Path) -> Result<CodeSets> {
    let proto_text = fs::read_to_string(root.join(PROTOCOL_RS)).map_err(Error::Io)?;
    let proto = ScannedFile::from_text(PROTOCOL_RS, &proto_text);
    let docs_text = fs::read_to_string(root.join(PROTOCOL_MD)).map_err(Error::Io)?;
    let corpus_text = fs::read_to_string(root.join(CORPUS_JSON)).map_err(Error::Io)?;
    Ok(CodeSets {
        source: source_codes(&proto),
        docs: doc_codes(&docs_text),
        corpus: corpus_codes(&corpus_text)?,
    })
}

pub struct ErrorCodeRegistry;

impl Rule for ErrorCodeRegistry {
    fn name(&self) -> &'static str {
        "error-code-registry"
    }

    fn description(&self) -> &'static str {
        "protocol error codes identical across protocol.rs, docs/protocol.md and the corpus"
    }

    fn check(&self, ctx: &Context, out: &mut Vec<Finding>) {
        // Inert unless the tree actually has a protocol source (so rule
        // fixtures for *other* rules don't all need one).
        let Some(proto) = ctx.file(PROTOCOL_RS) else {
            return;
        };
        let src = source_codes(proto);
        let docs = fs::read_to_string(ctx.root.join(PROTOCOL_MD))
            .map(|t| doc_codes(&t))
            .unwrap_or_default();
        let corpus = fs::read_to_string(ctx.root.join(CORPUS_JSON))
            .ok()
            .and_then(|t| corpus_codes(&t).ok())
            .unwrap_or_default();
        for (code, line) in &src {
            if !docs.contains_key(code) {
                out.push(Finding {
                    rule: "error-code-registry",
                    file: PROTOCOL_RS.to_string(),
                    line: *line,
                    message: format!("code `{code}` is not documented in docs/protocol.md"),
                });
            }
            if !corpus.contains_key(code) {
                out.push(Finding {
                    rule: "error-code-registry",
                    file: PROTOCOL_RS.to_string(),
                    line: *line,
                    message: format!("code `{code}` has no case in tests/protocol_corpus.json"),
                });
            }
        }
        for (code, line) in &docs {
            if !src.contains_key(code) {
                out.push(Finding {
                    rule: "error-code-registry",
                    file: PROTOCOL_MD.to_string(),
                    line: *line,
                    message: format!("documented code `{code}` is not defined in protocol.rs"),
                });
            }
        }
        for code in corpus.keys() {
            if !src.contains_key(code) {
                out.push(Finding {
                    rule: "error-code-registry",
                    file: CORPUS_JSON.to_string(),
                    line: 1,
                    message: format!(
                        "corpus expects code `{code}` which protocol.rs does not define"
                    ),
                });
            }
        }
    }
}
