//! `cimdse lint` — a zero-dependency invariant checker for the
//! hand-enforced contracts this crate relies on.
//!
//! The crate deliberately carries no external dependencies, which means
//! several correctness contracts that `clippy` plugins or proc-macro
//! frameworks would normally police are enforced by convention instead:
//! every `unsafe` block carries a `// SAFETY:` audit, the NDJSON error
//! codes stay in lock-step across `protocol.rs` / `docs/protocol.md` /
//! `tests/protocol_corpus.json`, floats never hit `{}`-style lossy
//! display in serialization paths, mutex guards never span I/O, and
//! fingerprinted paths never consult wall clocks or unordered maps.
//! This module turns those conventions into machine-checked rules built
//! on a small lexical scanner ([`scanner`]) — no `syn`, no proc-macros,
//! no new dependencies.
//!
//! Rules are individually suppressible at the offending line with
//! `// lint:allow(<rule>) — reason` (see `rust/docs/lints.md`); every
//! rule ships with known-bad/known-good fixtures under
//! `tests/lint_fixtures/` exercised by `tests/lint_selfcheck.rs`.

pub mod report;
pub mod rules;
pub mod scanner;

use std::path::{Path, PathBuf};

use crate::error::Result;
use self::scanner::ScannedFile;

/// One lint finding at a specific file/line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Stable rule name, e.g. `unsafe-audit`.
    pub rule: &'static str,
    /// Path relative to the lint root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description of the violation.
    pub message: String,
}

/// Everything a rule gets to look at.
pub struct Context {
    /// The lint root (a crate directory: contains `src/`, `Cargo.toml`).
    pub root: PathBuf,
    /// All scanned `.rs` files under `src/`, `tests/`, `benches/`.
    pub files: Vec<ScannedFile>,
}

impl Context {
    /// The scanned file at `rel`, if present in this tree.
    pub fn file(&self, rel: &str) -> Option<&ScannedFile> {
        self.files.iter().find(|f| f.rel == rel)
    }
}

/// A named, individually-suppressible lint rule.
pub trait Rule {
    /// Stable kebab-case name used in reports and `lint:allow(...)`.
    fn name(&self) -> &'static str;
    /// One-line description for `--json` output and docs.
    fn description(&self) -> &'static str;
    /// Append findings for `ctx` to `out`.
    fn check(&self, ctx: &Context, out: &mut Vec<Finding>);
}

/// All rules, in report order.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(rules::unsafe_audit::UnsafeAudit),
        Box::new(rules::error_codes::ErrorCodeRegistry),
        Box::new(rules::float_display::FloatDisplay),
        Box::new(rules::mutex_hold::MutexHold),
        Box::new(rules::determinism::Determinism),
        Box::new(rules::dep_hygiene::DepHygiene),
    ]
}

/// The stable rule-name list (for docs and the self-check).
pub fn rule_names() -> Vec<&'static str> {
    all_rules().iter().map(|r| r.name()).collect()
}

/// Result of linting one tree.
pub struct LintReport {
    /// Lint root the report was produced from.
    pub root: PathBuf,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All findings, sorted by (file, line, rule, message).
    pub findings: Vec<Finding>,
}

/// Scan `root` and run every rule.
pub fn lint_root(root: &Path) -> Result<LintReport> {
    let files = scanner::scan_root(root)?;
    let files_scanned = files.len();
    let ctx = Context {
        root: root.to_path_buf(),
        files,
    };
    let mut findings = Vec::new();
    for rule in all_rules() {
        rule.check(&ctx, &mut findings);
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    Ok(LintReport {
        root: root.to_path_buf(),
        files_scanned,
        findings,
    })
}
