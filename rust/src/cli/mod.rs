//! Argument-parsing substrate (no clap in the offline registry).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`
//! with typed accessors, defaults, and a generated usage string.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: subcommand, options, flags, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (e.g. `fit`, `sweep`), if any.
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (typically `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare `--` is not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let value = iter.next().unwrap();
                    args.options.insert(name.to_string(), value);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// String option value.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// String option with default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// f64 option with default; errors on unparsable input.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: `{s}` is not a number"))),
        }
    }

    /// usize option with default.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: `{s}` is not an integer"))),
        }
    }

    /// u64 option with default.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: `{s}` is not an integer"))),
        }
    }

    /// Whether a boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments (after the subcommand).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Comma-separated f64 list option (e.g. `--enob 4,8,12`).
    pub fn f64_list(&self, name: &str) -> Result<Option<Vec<f64>>> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim().parse::<f64>().map_err(|_| {
                        Error::Config(format!("--{name}: `{p}` is not a number"))
                    })
                })
                .collect::<Result<Vec<f64>>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags_positionals() {
        let a = parse("sweep --enob 8 --verbose --out=x.csv input1 input2");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.opt("enob"), Some("8"));
        assert_eq!(a.opt("out"), Some("x.csv"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), &["input1".to_string(), "input2".to_string()]);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse("model --enob 7.5 --n 4");
        assert_eq!(a.f64_or("enob", 0.0).unwrap(), 7.5);
        assert_eq!(a.usize_or("n", 1).unwrap(), 4);
        assert_eq!(a.f64_or("missing", 2.5).unwrap(), 2.5);
        assert_eq!(a.opt_or("backend", "native"), "native");
    }

    #[test]
    fn bad_numbers_error_with_context() {
        let a = parse("model --enob seven");
        let e = a.f64_or("enob", 0.0).unwrap_err().to_string();
        assert!(e.contains("enob") && e.contains("seven"), "{e}");
    }

    #[test]
    fn comma_lists() {
        let a = parse("figures --enob 4,8,12");
        assert_eq!(a.f64_list("enob").unwrap().unwrap(), vec![4.0, 8.0, 12.0]);
        assert_eq!(a.f64_list("missing").unwrap(), None);
        let bad = parse("figures --enob 4,x");
        assert!(bad.f64_list("enob").is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("cmd --dry-run --seed 7");
        assert!(a.flag("dry-run"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }
}
