//! Argument-parsing substrate (no clap in the offline registry).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`
//! with typed accessors, defaults, and a generated usage string.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: subcommand, options, flags, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (e.g. `fit`, `sweep`), if any.
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (typically `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        Args::parse_with_flags(tokens, &[])
    }

    /// [`Args::parse`] with a set of *declared boolean flags*. An
    /// undeclared `--name` followed by a non-`--` token is recorded as
    /// `name = token` (option with value); a declared flag never
    /// consumes the next token, so a following positional (e.g.
    /// `merge-shards --allow-partial shard_0.json`) is not swallowed,
    /// and `--flag=value` on a declared flag is a typed error.
    ///
    /// Repeating an option or a flag (`--points 4 --points 8`,
    /// `--allow-partial --allow-partial`, or any option/flag mix on one
    /// name) is a typed [`Error::Config`] naming the flag — a silent
    /// last-wins would make the dropped value look accepted.
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(
        tokens: I,
        boolean_flags: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare `--` is not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    if boolean_flags.contains(&k) {
                        return Err(Error::Config(format!(
                            "--{k} is a flag and takes no value (got `{v}`)"
                        )));
                    }
                    args.reject_duplicate(k)?;
                    args.options.insert(k.to_string(), v.to_string());
                } else if boolean_flags.contains(&name) {
                    args.reject_duplicate(name)?;
                    args.flags.push(name.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let value = iter.next().unwrap();
                    args.reject_duplicate(name)?;
                    args.options.insert(name.to_string(), value);
                } else {
                    args.reject_duplicate(name)?;
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// Typed error if `name` was already seen as an option or a flag.
    fn reject_duplicate(&self, name: &str) -> Result<()> {
        if self.options.contains_key(name) || self.flags.iter().any(|f| f == name) {
            return Err(Error::Config(format!("--{name} given more than once")));
        }
        Ok(())
    }

    /// String option value.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    /// String option with default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    /// Required string option; a typed error names the flag when absent.
    pub fn require_opt(&self, name: &str) -> Result<&str> {
        self.opt(name)
            .ok_or_else(|| Error::Config(format!("missing required option --{name}")))
    }

    /// f64 option with default; errors on unparsable input.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: `{s}` is not a number"))),
        }
    }

    /// usize option with default.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: `{s}` is not an integer"))),
        }
    }

    /// u64 option with default.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: `{s}` is not an integer"))),
        }
    }

    /// Whether a boolean flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments (after the subcommand).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Comma-separated f64 list option (e.g. `--enob 4,8,12`).
    pub fn f64_list(&self, name: &str) -> Result<Option<Vec<f64>>> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim().parse::<f64>().map_err(|_| {
                        Error::Config(format!("--{name}: `{p}` is not a number"))
                    })
                })
                .collect::<Result<Vec<f64>>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags_positionals() {
        let a = parse("sweep --enob 8 --verbose --out=x.csv input1 input2");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.opt("enob"), Some("8"));
        assert_eq!(a.opt("out"), Some("x.csv"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), &["input1".to_string(), "input2".to_string()]);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse("model --enob 7.5 --n 4");
        assert_eq!(a.f64_or("enob", 0.0).unwrap(), 7.5);
        assert_eq!(a.usize_or("n", 1).unwrap(), 4);
        assert_eq!(a.f64_or("missing", 2.5).unwrap(), 2.5);
        assert_eq!(a.opt_or("backend", "native"), "native");
    }

    #[test]
    fn bad_numbers_error_with_context() {
        let a = parse("model --enob seven");
        let e = a.f64_or("enob", 0.0).unwrap_err().to_string();
        assert!(e.contains("enob") && e.contains("seven"), "{e}");
    }

    #[test]
    fn comma_lists() {
        let a = parse("figures --enob 4,8,12");
        assert_eq!(a.f64_list("enob").unwrap().unwrap(), vec![4.0, 8.0, 12.0]);
        assert_eq!(a.f64_list("missing").unwrap(), None);
        let bad = parse("figures --enob 4,x");
        assert!(bad.f64_list("enob").is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("cmd --dry-run --seed 7");
        assert!(a.flag("dry-run"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn require_opt_errors_name_the_flag() {
        let a = parse("merge-shards x.json");
        assert_eq!(a.require_opt("out").unwrap_err().to_string(),
                   "config error: missing required option --out");
        let a = parse("sweep --out merged.json");
        assert_eq!(a.require_opt("out").unwrap(), "merged.json");
    }

    #[test]
    fn bare_double_dash_is_a_typed_error_not_a_panic() {
        let e = Args::parse(["sweep".to_string(), "--".to_string()]).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn negative_and_fractional_integers_are_typed_errors() {
        let a = parse("sweep --points -3");
        // `-3` is consumed as the option value and fails the usize parse.
        assert!(a.usize_or("points", 1).is_err());
        let a = parse("sweep --points 2.5");
        assert!(a.usize_or("points", 1).is_err());
        let a = parse("sweep --seed -1");
        assert!(a.u64_or("seed", 0).is_err());
    }

    #[test]
    fn declared_boolean_flags_do_not_swallow_positionals() {
        let tokens = |s: &str| s.split_whitespace().map(String::from).collect::<Vec<_>>();
        let a = Args::parse_with_flags(
            tokens("merge-shards --allow-partial shard_0.json shard_1.json"),
            &["allow-partial"],
        )
        .unwrap();
        assert!(a.flag("allow-partial"));
        assert_eq!(a.opt("allow-partial"), None);
        assert_eq!(
            a.positionals(),
            &["shard_0.json".to_string(), "shard_1.json".to_string()]
        );
        // Undeclared, the same tokens mis-parse as an option (the reason
        // the declaration exists).
        let b = Args::parse(tokens("merge-shards --allow-partial shard_0.json")).unwrap();
        assert_eq!(b.opt("allow-partial"), Some("shard_0.json"));
        // Declared flags reject `=value` loudly.
        let e = Args::parse_with_flags(
            tokens("merge-shards --allow-partial=yes shard_0.json"),
            &["allow-partial"],
        )
        .unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn duplicate_options_are_typed_errors_naming_the_flag() {
        // Last-wins used to silently drop `--points 4` here.
        let e = Args::parse("sweep --points 4 --points 8".split_whitespace().map(String::from))
            .unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        assert!(e.to_string().contains("--points"), "{e}");
        assert!(e.to_string().contains("more than once"), "{e}");
        // `--k=v` and `--k v` spellings collide too, in either order.
        for cmd in ["sweep --out=a.json --out b.json", "sweep --out a.json --out=b.json"] {
            let e = Args::parse(cmd.split_whitespace().map(String::from)).unwrap_err();
            assert!(e.to_string().contains("--out"), "`{cmd}`: {e}");
        }
        // Distinct options are of course still fine.
        let a = parse("sweep --points 4 --tsteps 8");
        assert_eq!(a.opt("points"), Some("4"));
        assert_eq!(a.opt("tsteps"), Some("8"));
    }

    #[test]
    fn duplicate_flags_are_typed_errors_naming_the_flag() {
        let tokens = |s: &str| s.split_whitespace().map(String::from).collect::<Vec<_>>();
        // Declared boolean flag repeated.
        let e = Args::parse_with_flags(
            tokens("merge-shards --allow-partial --allow-partial a.json"),
            &["allow-partial"],
        )
        .unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        assert!(e.to_string().contains("--allow-partial"), "{e}");
        // Undeclared flag repeated.
        let e = Args::parse(tokens("cmd --verbose --verbose")).unwrap_err();
        assert!(e.to_string().contains("--verbose"), "{e}");
        // Flag/option mix on one name: the first `--dry-run` is consumed
        // as a flag (next token is another `--`), the second as an option.
        let e = Args::parse(tokens("sweep --dry-run --dry-run 3")).unwrap_err();
        assert!(e.to_string().contains("--dry-run"), "{e}");
    }

    #[test]
    fn shard_style_values_survive_parsing() {
        // `1/3` must come through as an opaque option value for
        // ShardSelector::parse to handle (including its error cases).
        let a = parse("sweep --shard 1/3 --out s.json");
        assert_eq!(a.opt("shard"), Some("1/3"));
        let a = parse("sweep --shard 0/0");
        assert_eq!(a.opt("shard"), Some("0/0"));
    }
}
