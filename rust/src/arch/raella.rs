//! RAELLA-like architecture presets (paper §III-A).
//!
//! Four parameterizations trade computations-per-convert against ADC
//! resolution: Small sums up to 128 analog values and reads with a 6-bit
//! ADC; Medium / Large / Extra-Large sum up to 512 / 2048 / 8192 values
//! with 7 / 8 / 9-bit ADCs — each step sums 4x more values for +1 ADC bit.

use super::{AdcArchConfig, CimArch};

/// The four parameterizations evaluated in the paper's Fig. 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RaellaVariant {
    /// Sum ≤ 128, 6-bit ADC.
    Small,
    /// Sum ≤ 512, 7-bit ADC.
    Medium,
    /// Sum ≤ 2048, 8-bit ADC.
    Large,
    /// Sum ≤ 8192, 9-bit ADC.
    ExtraLarge,
}

impl RaellaVariant {
    /// All four variants in S..XL order.
    pub const ALL: [RaellaVariant; 4] = [
        RaellaVariant::Small,
        RaellaVariant::Medium,
        RaellaVariant::Large,
        RaellaVariant::ExtraLarge,
    ];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            RaellaVariant::Small => "S",
            RaellaVariant::Medium => "M",
            RaellaVariant::Large => "L",
            RaellaVariant::ExtraLarge => "XL",
        }
    }

    /// (sum size, ADC ENOB) of this variant (paper §III-A).
    pub fn params(&self) -> (usize, f64) {
        match self {
            RaellaVariant::Small => (128, 6.0),
            RaellaVariant::Medium => (512, 7.0),
            RaellaVariant::Large => (2048, 8.0),
            RaellaVariant::ExtraLarge => (8192, 9.0),
        }
    }
}

/// Build a RAELLA-like [`CimArch`] for a variant.
///
/// Common structure across variants: 512x512 crossbars, 2-bit cells,
/// 8-bit weights (4 column slices), 8-bit bit-serial activations, 64 KiB
/// tile SRAM, 4 MiB global eDRAM, 32 nm. Only `(sum_size, ADC ENOB)`
/// differ — exactly the §III-A experiment design. `n_adcs` and ADC
/// throughput default to 8 ADCs at the paper's Fig. 5 base throughput
/// (1.3e9 conv/s total => 1.6e8 per ADC, inside the minimum-energy
/// region for all four variants' ENOBs) and are overridden by the
/// Fig. 5 sweep.
pub fn raella(variant: RaellaVariant) -> CimArch {
    let (sum_size, enob) = variant.params();
    CimArch {
        name: format!("raella-{}", variant.name().to_lowercase()),
        tech_nm: 32.0,
        array_rows: 512,
        array_cols: 512,
        sum_size,
        cell_bits: 2,
        weight_bits: 8,
        act_bits: 8,
        adc: AdcArchConfig { enob, n_adcs: 8, total_throughput: 1.3e9 },
        sram_bytes: 64 * 1024,
        edram_bytes: 4 * 1024 * 1024,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_match_paper_parameters() {
        assert_eq!(raella(RaellaVariant::Small).sum_size, 128);
        assert_eq!(raella(RaellaVariant::Small).adc.enob, 6.0);
        assert_eq!(raella(RaellaVariant::Medium).sum_size, 512);
        assert_eq!(raella(RaellaVariant::Medium).adc.enob, 7.0);
        assert_eq!(raella(RaellaVariant::Large).sum_size, 2048);
        assert_eq!(raella(RaellaVariant::Large).adc.enob, 8.0);
        assert_eq!(raella(RaellaVariant::ExtraLarge).sum_size, 8192);
        assert_eq!(raella(RaellaVariant::ExtraLarge).adc.enob, 9.0);
    }

    #[test]
    fn each_step_trades_4x_sum_for_one_bit() {
        for w in RaellaVariant::ALL.windows(2) {
            let (s0, e0) = w[0].params();
            let (s1, e1) = w[1].params();
            assert_eq!(s1, 4 * s0);
            assert_eq!(e1, e0 + 1.0);
        }
    }

    #[test]
    fn presets_validate() {
        for v in RaellaVariant::ALL {
            raella(v).validate().unwrap();
        }
    }

    #[test]
    fn only_sum_and_enob_differ() {
        let s = raella(RaellaVariant::Small);
        let xl = raella(RaellaVariant::ExtraLarge);
        assert_eq!(s.array_rows, xl.array_rows);
        assert_eq!(s.cell_bits, xl.cell_bits);
        assert_eq!(s.weight_bits, xl.weight_bits);
        assert_eq!(s.sram_bytes, xl.sram_bytes);
        assert_ne!(s.sum_size, xl.sum_size);
        assert_ne!(s.adc.enob, xl.adc.enob);
    }
}
