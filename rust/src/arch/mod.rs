//! CiM accelerator architecture specifications.
//!
//! A [`CimArch`] describes one analog CiM design point at the architecture
//! level: crossbar geometry, weight/activation slicing, the analog sum
//! size (how many values one ADC convert reads — the paper's central
//! knob), the ADC configuration, and the buffer hierarchy. Presets for
//! the RAELLA-like S/M/L/XL parameterizations of §III live in [`mod@raella`];
//! arbitrary specs load from TOML via [`from_toml`].

pub mod raella;

pub use raella::{RaellaVariant, raella};

use crate::config::{Value, parse_toml};
use crate::error::{Error, Result};

/// ADC configuration of an architecture.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdcArchConfig {
    /// ADC resolution in effective bits.
    pub enob: f64,
    /// Number of ADCs operating in parallel.
    pub n_adcs: u32,
    /// Aggregate converts/second across all ADCs.
    pub total_throughput: f64,
}

/// One CiM architecture design point.
#[derive(Clone, Debug, PartialEq)]
pub struct CimArch {
    /// Display name (e.g. "raella-m").
    pub name: String,
    /// Technology node (nm).
    pub tech_nm: f64,
    /// Physical crossbar rows per array.
    pub array_rows: usize,
    /// Physical crossbar columns per array.
    pub array_cols: usize,
    /// Analog sum size: values summed on a column line per ADC convert.
    /// May exceed `array_rows` (CASCADE-style analog chaining of arrays).
    pub sum_size: usize,
    /// Bits stored per memory cell.
    pub cell_bits: u32,
    /// Weight precision in bits (=> `weight_bits / cell_bits` column slices).
    pub weight_bits: u32,
    /// Activation precision in bits (bit-serial 1-bit DACs => planes).
    pub act_bits: u32,
    /// ADC configuration.
    pub adc: AdcArchConfig,
    /// Local SRAM buffer capacity (bytes) per tile.
    pub sram_bytes: usize,
    /// Global eDRAM buffer capacity (bytes).
    pub edram_bytes: usize,
}

impl CimArch {
    /// Column slices each logical weight occupies.
    pub fn col_slices(&self) -> usize {
        (self.weight_bits as usize).div_ceil(self.cell_bits as usize)
    }

    /// Bit-serial activation planes.
    pub fn planes(&self) -> usize {
        self.act_bits as usize
    }

    /// Logical weights that fit in one array (rows x logical columns).
    pub fn weights_per_array(&self) -> usize {
        self.array_rows * (self.array_cols / self.col_slices())
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<()> {
        if self.array_rows == 0 || self.array_cols == 0 {
            return Err(Error::Config("array dimensions must be positive".into()));
        }
        if self.sum_size == 0 {
            return Err(Error::Config("sum_size must be positive".into()));
        }
        if self.cell_bits == 0 || self.weight_bits < self.cell_bits {
            return Err(Error::Config(format!(
                "invalid slicing: weight_bits={} cell_bits={}",
                self.weight_bits, self.cell_bits
            )));
        }
        if self.act_bits == 0 {
            return Err(Error::Config("act_bits must be positive".into()));
        }
        if self.array_cols % self.col_slices() != 0 {
            return Err(Error::Config(format!(
                "array_cols={} not divisible by col_slices={}",
                self.array_cols,
                self.col_slices()
            )));
        }
        if self.adc.n_adcs == 0 || self.adc.total_throughput <= 0.0 || self.adc.enob <= 0.0 {
            return Err(Error::Config("invalid ADC config".into()));
        }
        Ok(())
    }

    /// The analog full-scale (distinct levels - 1) a column sum can reach:
    /// sum_size rows each contributing up to (2^cell_bits - 1). Total for
    /// any `cell_bits` (the raw `1u64 << cell_bits` shift panicked/wrapped
    /// from 64 up): the per-cell level count saturates to `+∞` via
    /// [`crate::adc::enob::pow2_f64`].
    pub fn column_full_scale(&self) -> f64 {
        self.sum_size as f64 * (crate::adc::enob::pow2_f64(self.cell_bits) - 1.0)
    }

    /// ENOB needed to read a full-scale column losslessly
    /// (log2 of distinct levels). The paper's S/M/L/XL ADCs deliberately
    /// sit *below* this (RAELLA keeps sums small so low ENOB suffices).
    pub fn lossless_enob(&self) -> f64 {
        (self.column_full_scale() + 1.0).log2()
    }
}

/// Load an architecture from a TOML-subset document (see `configs/`).
pub fn from_toml(text: &str) -> Result<CimArch> {
    let v = parse_toml(text)?;
    from_value(&v)
}

/// Build a [`CimArch`] from a parsed config [`Value`].
pub fn from_value(v: &Value) -> Result<CimArch> {
    let arch = CimArch {
        name: v.require_str("name")?.to_string(),
        tech_nm: v.require_f64("tech_nm")?,
        array_rows: v.require_usize("array.rows")?,
        array_cols: v.require_usize("array.cols")?,
        sum_size: v.require_usize("array.sum_size")?,
        cell_bits: v.require_usize("array.cell_bits")? as u32,
        weight_bits: v.require_usize("precision.weight_bits")? as u32,
        act_bits: v.require_usize("precision.act_bits")? as u32,
        adc: AdcArchConfig {
            enob: v.require_f64("adc.enob")?,
            n_adcs: v.require_usize("adc.n_adcs")? as u32,
            total_throughput: v.require_f64("adc.total_throughput")?,
        },
        sram_bytes: v.require_usize("buffers.sram_bytes")?,
        edram_bytes: v.require_usize("buffers.edram_bytes")?,
    };
    arch.validate()?;
    Ok(arch)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
name = "custom"
tech_nm = 32

[array]
rows = 512
cols = 512
sum_size = 256
cell_bits = 2

[precision]
weight_bits = 8
act_bits = 8

[adc]
enob = 7
n_adcs = 2
total_throughput = 1.3e9

[buffers]
sram_bytes = 65536
edram_bytes = 4194304
"#;

    #[test]
    fn parses_full_spec() {
        let a = from_toml(DOC).unwrap();
        assert_eq!(a.name, "custom");
        assert_eq!(a.array_rows, 512);
        assert_eq!(a.sum_size, 256);
        assert_eq!(a.col_slices(), 4);
        assert_eq!(a.planes(), 8);
        assert_eq!(a.adc.n_adcs, 2);
        assert!((a.adc.total_throughput - 1.3e9).abs() < 1.0);
    }

    #[test]
    fn missing_field_is_reported() {
        let bad = DOC.replace("rows = 512\n", "");
        let err = from_toml(&bad).unwrap_err().to_string();
        assert!(err.contains("array.rows"), "{err}");
    }

    #[test]
    fn validate_catches_bad_slicing() {
        let mut a = from_toml(DOC).unwrap();
        a.weight_bits = 1; // < cell_bits
        assert!(a.validate().is_err());
        let mut b = from_toml(DOC).unwrap();
        b.array_cols = 510; // not divisible by 4 slices
        assert!(b.validate().is_err());
    }

    #[test]
    fn full_scale_and_lossless_enob() {
        let a = from_toml(DOC).unwrap();
        // 256 rows x 3 (2-bit cells) = 768 levels above zero.
        assert_eq!(a.column_full_scale(), 768.0);
        let enob = a.lossless_enob();
        assert!(enob > 9.5 && enob < 9.6, "{enob}"); // log2(769)
    }

    #[test]
    fn weights_per_array() {
        let a = from_toml(DOC).unwrap();
        assert_eq!(a.weights_per_array(), 512 * 128);
    }

    #[test]
    fn huge_cell_bits_saturate_instead_of_panicking() {
        // A TOML spec can carry any cell width; full scale and lossless
        // ENOB must stay total rather than hitting a 64-bit shift.
        let mut a = from_toml(DOC).unwrap();
        a.cell_bits = 64;
        a.weight_bits = 64;
        assert!(a.column_full_scale().is_finite());
        assert!(a.lossless_enob().is_finite());
        a.cell_bits = 4096;
        a.weight_bits = 4096;
        assert_eq!(a.column_full_scale(), f64::INFINITY);
        assert_eq!(a.lossless_enob(), f64::INFINITY);
    }
}
