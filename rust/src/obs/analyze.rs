//! The `cimdse trace <FILE>` analyzer: loads an NDJSON trace (one
//! process's file, or several concatenated — the fleet case), and
//! renders per-op latency breakdowns, a per-process timeline, and the
//! critical path of the largest trace.
//!
//! Cross-process caveat: `t_us` timestamps are monotonic readings of
//! *each process's own clock*, so timeline offsets are relative within
//! one process and never compared across processes. Cross-process
//! structure — which worker span served which launcher shard — comes
//! entirely from the `trace`/`parent` span links, which is why the
//! critical path is computed over the link forest, not over clocks.

use std::collections::BTreeMap;

use crate::bench_util::fmt_secs;
use crate::config::{Value, parse_json};
use crate::error::{Error, Result};
use crate::obs::parse_hex16;

/// One decoded trace line.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// `"span"` or `"event"`.
    pub ev: String,
    /// Span/event name.
    pub name: String,
    /// Trace id this event belongs to.
    pub trace: u64,
    /// This event's own span id.
    pub span: u64,
    /// Parent span id, when linked.
    pub parent: Option<u64>,
    /// Monotonic start, µs since the *originating process's* tracer init.
    pub t_us: u64,
    /// Duration in µs (0 for instant events).
    pub dur_us: u64,
    /// Per-process thread tag.
    pub tid: u64,
    /// Process label (`"launcher"`, a worker address, ...).
    pub proc: String,
    /// Free-form attributes (`Value::Null` when absent).
    pub attrs: Value,
}

/// Parse a whole NDJSON trace text. Every non-blank line must parse
/// with the crate's own JSON parser and carry the span-event schema;
/// the error names the offending line.
pub fn parse_trace(text: &str) -> Result<Vec<TraceEvent>> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let doc = parse_json(line)
            .map_err(|e| Error::Config(format!("trace line {}: unparsable JSON: {e}", i + 1)))?;
        events.push(event_from_value(&doc).map_err(|e| {
            Error::Config(format!("trace line {}: {e}", i + 1))
        })?);
    }
    Ok(events)
}

fn event_from_value(v: &Value) -> std::result::Result<TraceEvent, String> {
    let field = |key: &str| {
        v.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| format!("missing string `{key}`"))
    };
    let hex = |key: &str| {
        parse_hex16(field(key)?).ok_or_else(|| format!("`{key}` is not 16 hex digits"))
    };
    let num = |key: &str| {
        v.get(key)
            .and_then(Value::as_f64)
            .filter(|x| x.is_finite() && *x >= 0.0)
            .map(|x| x as u64)
            .ok_or_else(|| format!("missing numeric `{key}`"))
    };
    let ev = field("ev")?.to_string();
    if ev != "span" && ev != "event" {
        return Err(format!("unknown event kind `{ev}`"));
    }
    let parent = match v.get("parent") {
        None => None,
        Some(_) => Some(hex("parent")?),
    };
    Ok(TraceEvent {
        name: field("name")?.to_string(),
        trace: hex("trace")?,
        span: hex("span")?,
        parent,
        t_us: num("t_us")?,
        dur_us: if ev == "span" { num("dur_us")? } else { 0 },
        tid: num("tid")?,
        proc: field("proc")?.to_string(),
        attrs: v.get("attrs").cloned().unwrap_or(Value::Null),
        ev,
    })
}

const TIMELINE_SPAN_CAP: usize = 24;

/// Render the human report for a parsed trace.
pub fn render_report(events: &[TraceEvent]) -> String {
    let spans: Vec<&TraceEvent> = events.iter().filter(|e| e.ev == "span").collect();
    let mut traces = BTreeMap::new();
    let mut procs: BTreeMap<&str, Vec<&TraceEvent>> = BTreeMap::new();
    for &e in &spans {
        *traces.entry(e.trace).or_insert(0usize) += 1;
        procs.entry(e.proc.as_str()).or_default().push(e);
    }
    let mut out = format!(
        "cimdse trace: {} events ({} spans), {} process(es), {} trace(s)\n",
        events.len(),
        spans.len(),
        procs.len(),
        traces.len()
    );
    if spans.is_empty() {
        out.push_str("  (no spans recorded)\n");
        return out;
    }

    // Per-op latency breakdown: group span durations by name.
    out.push_str("\nper-op latency:\n");
    let mut by_name: BTreeMap<&str, (usize, u64, u64)> = BTreeMap::new();
    for e in &spans {
        let entry = by_name.entry(e.name.as_str()).or_insert((0, 0, 0));
        entry.0 += 1;
        entry.1 += e.dur_us;
        entry.2 = entry.2.max(e.dur_us);
    }
    for (name, (count, total_us, max_us)) in &by_name {
        out.push_str(&format!(
            "  {name:<16} {count:>6} spans  total {:>9}  mean {:>9}  max {:>9}\n",
            fmt_secs(*total_us as f64 / 1e6),
            fmt_secs(*total_us as f64 / 1e6 / *count as f64),
            fmt_secs(*max_us as f64 / 1e6),
        ));
    }

    // Per-process timeline: offsets relative to that process's first
    // span (monotonic clocks are per-process; see module docs).
    out.push_str("\nper-process timeline (offsets are per-process):\n");
    for (proc, list) in &procs {
        let mut list: Vec<&&TraceEvent> = list.iter().collect();
        list.sort_by_key(|e| (e.t_us, e.span));
        let t0 = list.first().map(|e| e.t_us).unwrap_or(0);
        let busy_us: u64 = list.iter().map(|e| e.dur_us).sum();
        let label = if proc.is_empty() { "(unlabeled)" } else { proc };
        out.push_str(&format!(
            "  {label}: {} spans, busy {}\n",
            list.len(),
            fmt_secs(busy_us as f64 / 1e6)
        ));
        for e in list.iter().take(TIMELINE_SPAN_CAP) {
            out.push_str(&format!(
                "    +{:>9} {:<16} {:>9}  [tid {}]\n",
                fmt_secs((e.t_us - t0) as f64 / 1e6),
                e.name,
                fmt_secs(e.dur_us as f64 / 1e6),
                e.tid,
            ));
        }
        if list.len() > TIMELINE_SPAN_CAP {
            out.push_str(&format!(
                "    ... {} more spans\n",
                list.len() - TIMELINE_SPAN_CAP
            ));
        }
    }

    // Critical path over the parent-link forest of the largest trace:
    // the root-to-leaf chain with the largest summed duration. Links,
    // not clocks, so it is valid across processes.
    let (&big_trace, _) = traces
        .iter()
        .max_by_key(|&(id, n)| (*n, std::cmp::Reverse(*id)))
        .expect("spans is non-empty");
    out.push_str(&format!(
        "\ncritical path (trace {}):\n",
        crate::obs::hex16(big_trace)
    ));
    let in_trace: Vec<&&TraceEvent> = spans.iter().filter(|e| e.trace == big_trace).collect();
    let known: BTreeMap<u64, &&TraceEvent> = in_trace.iter().map(|e| (e.span, *e)).collect();
    let mut children: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    let mut roots = Vec::new();
    for e in &in_trace {
        match e.parent {
            // A parent recorded in another file still counts as a link
            // only if its span made it into this trace text.
            Some(p) if known.contains_key(&p) => children.entry(p).or_default().push(e.span),
            _ => roots.push(e.span),
        }
    }
    let mut best: Option<(u64, Vec<u64>)> = None;
    for &root in &roots {
        let chain = heaviest_chain(root, &known, &children);
        let cost: u64 = chain.iter().map(|s| known[s].dur_us).sum();
        if best.as_ref().map(|(c, _)| cost > *c).unwrap_or(true) {
            best = Some((cost, chain));
        }
    }
    if let Some((cost, chain)) = best {
        for (depth, span) in chain.iter().enumerate() {
            let e = known[span];
            out.push_str(&format!(
                "  {}{} {} [{}]\n",
                "  ".repeat(depth),
                e.name,
                fmt_secs(e.dur_us as f64 / 1e6),
                if e.proc.is_empty() { "(unlabeled)" } else { &e.proc },
            ));
        }
        out.push_str(&format!("  = {} along the path\n", fmt_secs(cost as f64 / 1e6)));
    }
    out
}

/// Depth-first heaviest (by summed `dur_us`) root-to-leaf chain.
/// Iterative so a pathological deep trace cannot overflow the stack.
fn heaviest_chain(
    root: u64,
    known: &BTreeMap<u64, &&TraceEvent>,
    children: &BTreeMap<u64, Vec<u64>>,
) -> Vec<u64> {
    // Post-order accumulate best child chains.
    let mut best_down: BTreeMap<u64, (u64, Option<u64>)> = BTreeMap::new();
    let mut stack = vec![(root, false)];
    while let Some((node, expanded)) = stack.pop() {
        if !expanded {
            stack.push((node, true));
            for &c in children.get(&node).into_iter().flatten() {
                stack.push((c, false));
            }
            continue;
        }
        let mut pick: (u64, Option<u64>) = (0, None);
        for &c in children.get(&node).into_iter().flatten() {
            let down = best_down.get(&c).map(|(cost, _)| *cost).unwrap_or(0);
            if down > pick.0 || pick.1.is_none() {
                pick = (down, Some(c));
            }
        }
        let self_cost = known.get(&node).map(|e| e.dur_us).unwrap_or(0);
        best_down.insert(node, (self_cost + pick.0, pick.1));
    }
    let mut chain = vec![root];
    let mut cur = root;
    while let Some((_, Some(next))) = best_down.get(&cur) {
        chain.push(*next);
        cur = *next;
    }
    chain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Value;
    use crate::obs::Tracer;

    fn fleet_fixture() -> String {
        // Three "processes": a launcher whose shard spans parent the
        // two workers' compute spans, exactly the wire contract.
        let launcher = Tracer::new();
        launcher.enable_ring("launcher");
        let mut lines = Vec::new();
        let root = launcher.span("launch");
        let root_ctx = root.ctx();
        for (i, worker) in ["127.0.0.1:7101", "127.0.0.1:7102"].iter().enumerate() {
            let mut shard = launcher.child_span("shard", root_ctx);
            shard.attr("shard", Value::String(format!("{i}/2")));
            let w = Tracer::new();
            w.enable_ring(worker);
            {
                let compute = w.child_span("shard", shard.ctx());
                {
                    let _chunk = w.child_span("chunk", compute.ctx());
                }
            }
            lines.extend(w.ring());
        }
        drop(root);
        lines.extend(launcher.ring());
        lines.join("\n") + "\n"
    }

    #[test]
    fn parses_and_reports_a_fleet_trace() {
        let text = fleet_fixture();
        let events = parse_trace(&text).unwrap();
        assert_eq!(events.len(), 7); // 1 launch + 2x(shard + compute + chunk)
        let traces: std::collections::BTreeSet<u64> =
            events.iter().map(|e| e.trace).collect();
        assert_eq!(traces.len(), 1, "one fleet run = one trace id");

        let report = render_report(&events);
        assert!(report.contains("3 process(es)"), "{report}");
        assert!(report.contains("127.0.0.1:7101"), "{report}");
        assert!(report.contains("127.0.0.1:7102"), "{report}");
        assert!(report.contains("per-op latency"), "{report}");
        assert!(report.contains("critical path"), "{report}");
        // The critical path must cross processes: launch -> shard ->
        // worker-side shard -> chunk is 4 levels deep.
        assert!(report.contains("      chunk"), "chunk at depth 3:\n{report}");
    }

    #[test]
    fn rejects_malformed_lines_with_line_numbers() {
        let good = fleet_fixture();
        let bad = format!("{good}this is not json\n");
        let err = parse_trace(&bad).unwrap_err().to_string();
        assert!(err.contains("trace line 8"), "{err}");
        let bad_schema = "{\"ev\": \"span\"}\n";
        let err = parse_trace(bad_schema).unwrap_err().to_string();
        assert!(err.contains("trace line 1"), "{err}");
        assert!(err.contains("name"), "{err}");
    }

    #[test]
    fn empty_trace_renders() {
        let events = parse_trace("").unwrap();
        assert!(events.is_empty());
        let report = render_report(&events);
        assert!(report.contains("0 events"), "{report}");
    }

    #[test]
    fn critical_path_prefers_the_heavy_chain() {
        // Hand-built forest: root with a fast deep chain and one slow
        // shallow child; the slow child must win.
        let mk = |name: &str, span: u64, parent: Option<u64>, dur_us: u64| TraceEvent {
            ev: "span".to_string(),
            name: name.to_string(),
            trace: 1,
            span,
            parent,
            t_us: 0,
            dur_us,
            tid: 1,
            proc: "p".to_string(),
            attrs: Value::Null,
        };
        let events = vec![
            mk("root", 1, None, 10),
            mk("fast", 2, Some(1), 5),
            mk("fast", 3, Some(2), 5),
            mk("slow", 4, Some(1), 1_000_000),
        ];
        let report = render_report(&events);
        assert!(report.contains("slow"), "{report}");
        let root_pos = report.find("critical path").unwrap();
        let tail = &report[root_pos..];
        assert!(tail.contains("slow"), "{tail}");
        assert!(!tail.contains("fast"), "fast chain must lose: {tail}");
    }
}
