//! Fixed-bucket log2 latency histograms: mergeable, constant-memory,
//! exact counts.
//!
//! The serving layer used to keep a 4096-sample ring of recent
//! latencies: quantiles were exact but windowed (a burst of rejects
//! evicted the history that mattered), merging two servers' rings was
//! meaningless, and memory grew with the window. A log2 histogram
//! inverts every one of those trades: 64 fixed buckets over
//! nanoseconds, every observation counted forever, and merging is
//! element-wise addition — associative, commutative, and exact on
//! counts — at the cost of quantiles that are only bucket-resolution
//! (within one power of two) approximations.
//!
//! Bucket layout over a duration of `n` whole nanoseconds:
//!
//! * bucket `0` — `n == 0` (sub-nanosecond),
//! * bucket `i` in `1..=62` — `n` in `[2^(i-1), 2^i)`,
//! * bucket `63` — everything at or above `2^62` ns (~146 years), the
//!   overflow bucket.
//!
//! Exposed through the `metrics` op (bucket table + derived quantiles)
//! and the Prometheus text exposition; see `rust/docs/observability.md`.

use std::collections::BTreeMap;

use crate::config::Value;

/// Number of log2 buckets. Fixed so any two histograms merge.
pub const BUCKETS: usize = 64;

const NS_PER_S: f64 = 1e9;

/// A mergeable log2 histogram of durations in seconds.
#[derive(Clone, Debug)]
pub struct Hist {
    counts: [u64; BUCKETS],
    count: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    /// An empty histogram.
    pub fn new() -> Hist {
        Hist {
            counts: [0; BUCKETS],
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: f64::NEG_INFINITY,
        }
    }

    /// The bucket a duration of `latency_s` seconds lands in.
    /// Total: negative/NaN durations clamp into bucket 0, absurdly
    /// large ones into the overflow bucket.
    pub fn bucket_index(latency_s: f64) -> usize {
        let ns = latency_s * NS_PER_S;
        if !(ns >= 1.0) {
            return 0; // < 1 ns, negative, or NaN
        }
        if ns >= (1u64 << 62) as f64 {
            return BUCKETS - 1;
        }
        let n = ns as u64; // truncation == floor for positive finite
        (64 - n.leading_zeros() as usize).min(BUCKETS - 1)
    }

    /// Exclusive upper edge of bucket `i` in seconds (`+inf` for the
    /// overflow bucket).
    pub fn bucket_le_s(i: usize) -> f64 {
        if i >= BUCKETS - 1 { f64::INFINITY } else { (1u64 << i) as f64 / NS_PER_S }
    }

    /// Inclusive lower edge of bucket `i` in seconds.
    pub fn bucket_lo_s(i: usize) -> f64 {
        if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 / NS_PER_S }
    }

    /// Record one duration.
    pub fn observe(&mut self, latency_s: f64) {
        self.counts[Self::bucket_index(latency_s)] += 1;
        self.count += 1;
        if latency_s.is_finite() {
            self.sum_s += latency_s.max(0.0);
            self.min_s = self.min_s.min(latency_s.max(0.0));
            self.max_s = self.max_s.max(latency_s.max(0.0));
        }
    }

    /// Total observations (exact: every `observe` lands in exactly one
    /// bucket).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observed durations (seconds).
    pub fn sum_s(&self) -> f64 {
        self.sum_s
    }

    /// Smallest observation, if any.
    pub fn min_s(&self) -> Option<f64> {
        (self.count > 0).then_some(self.min_s)
    }

    /// Largest observation, if any.
    pub fn max_s(&self) -> Option<f64> {
        (self.count > 0).then_some(self.max_s)
    }

    /// Per-bucket count.
    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Fold another histogram in. Counts add exactly; the float
    /// `sum_s` is the only field subject to rounding, so merged counts
    /// are order-independent bit-for-bit and sums are order-independent
    /// up to f64 addition error.
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
        if other.count > 0 {
            self.min_s = self.min_s.min(other.min_s);
            self.max_s = self.max_s.max(other.max_s);
        }
    }

    /// Approximate quantile (`q` in `[0, 1]`): the midpoint of the
    /// bucket holding the rank-`q` observation, clamped to the observed
    /// `[min, max]` range. Accurate to within one power of two, which
    /// is the histogram trade.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if c > 0 && seen > rank {
                let lo = Self::bucket_lo_s(i);
                let hi = if i == BUCKETS - 1 { self.max_s } else { Self::bucket_le_s(i) };
                let mid = 0.5 * (lo + hi);
                return Some(mid.clamp(self.min_s, self.max_s));
            }
        }
        Some(self.max_s) // unreachable in practice: counts sum to count
    }

    /// Mean observed duration, if any.
    pub fn mean_s(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum_s / self.count as f64)
    }

    /// The histogram as a `metrics`-frame payload: exact totals,
    /// derived quantiles, and the non-empty buckets as
    /// `{le_s, count}` rows (the overflow bucket omits `le_s`,
    /// standing for `+inf`, which JSON cannot carry as a number).
    pub fn to_value(&self) -> Value {
        let mut map = BTreeMap::new();
        map.insert("count".to_string(), Value::Number(self.count as f64));
        map.insert("sum_s".to_string(), Value::Number(self.sum_s));
        if let (Some(min), Some(max)) = (self.min_s(), self.max_s()) {
            map.insert("min_s".to_string(), Value::Number(min));
            map.insert("max_s".to_string(), Value::Number(max));
        }
        if let (Some(p50), Some(p99)) = (self.quantile(0.50), self.quantile(0.99)) {
            map.insert("p50_s".to_string(), Value::Number(p50));
            map.insert("p99_s".to_string(), Value::Number(p99));
        }
        let mut buckets = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let mut b = BTreeMap::new();
            b.insert("count".to_string(), Value::Number(c as f64));
            let le = Self::bucket_le_s(i);
            if le.is_finite() {
                b.insert("le_s".to_string(), Value::Number(le));
            }
            buckets.push(Value::Table(b));
        }
        map.insert("buckets".to_string(), Value::Array(buckets));
        Value::Table(map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_boundaries_are_exact_powers_of_two() {
        // Exactly representable nanosecond durations sit in the bucket
        // whose half-open range [2^(i-1), 2^i) contains them.
        assert_eq!(Hist::bucket_index(0.0), 0);
        assert_eq!(Hist::bucket_index(-1.0), 0);
        assert_eq!(Hist::bucket_index(f64::NAN), 0);
        assert_eq!(Hist::bucket_index(0.5e-9), 0); // sub-ns
        assert_eq!(Hist::bucket_index(1e-9), 1); // exactly 1 ns
        for i in 1..=52usize {
            let lo_ns = (1u64 << (i - 1)) as f64;
            let hi_ns = (1u64 << i) as f64;
            assert_eq!(Hist::bucket_index(lo_ns / 1e9), i, "lower edge of bucket {i}");
            assert_eq!(
                Hist::bucket_index((hi_ns - 1.0) / 1e9),
                i,
                "last ns of bucket {i}"
            );
            assert_eq!(Hist::bucket_index(hi_ns / 1e9), i + 1, "upper edge leaves bucket {i}");
        }
        // Overflow bucket swallows everything gigantic.
        assert_eq!(Hist::bucket_index(1e60), BUCKETS - 1);
        assert_eq!(Hist::bucket_index(f64::INFINITY), BUCKETS - 1);
        // Edges are consistent: lo of bucket i+1 == le of bucket i.
        for i in 0..BUCKETS - 2 {
            assert_eq!(Hist::bucket_lo_s(i + 1), Hist::bucket_le_s(i));
        }
        assert!(Hist::bucket_le_s(BUCKETS - 1).is_infinite());
    }

    /// Deterministic pseudo-random latencies spanning ns..minutes.
    fn sample_latencies(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let exp = rng.uniform(-9.0, 2.0); // 1 ns .. 100 s
                10f64.powf(exp)
            })
            .collect()
    }

    #[test]
    fn counts_are_conserved_vs_naive_reference() {
        let xs = sample_latencies(7, 5000);
        let mut h = Hist::new();
        let mut naive = [0u64; BUCKETS];
        for &x in &xs {
            h.observe(x);
            naive[Hist::bucket_index(x)] += 1;
        }
        assert_eq!(h.count(), xs.len() as u64);
        assert_eq!(naive.iter().sum::<u64>(), xs.len() as u64);
        for i in 0..BUCKETS {
            assert_eq!(h.bucket_count(i), naive[i], "bucket {i}");
        }
        let true_sum: f64 = xs.iter().sum();
        assert!((h.sum_s() - true_sum).abs() <= 1e-9 * true_sum.abs());
        let true_min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let true_max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(h.min_s(), Some(true_min));
        assert_eq!(h.max_s(), Some(true_max));
    }

    fn hist_of(xs: &[f64]) -> Hist {
        let mut h = Hist::new();
        for &x in xs {
            h.observe(x);
        }
        h
    }

    fn assert_same_counts(a: &Hist, b: &Hist) {
        assert_eq!(a.count(), b.count());
        for i in 0..BUCKETS {
            assert_eq!(a.bucket_count(i), b.bucket_count(i), "bucket {i}");
        }
        assert_eq!(a.min_s(), b.min_s());
        assert_eq!(a.max_s(), b.max_s());
        let (sa, sb) = (a.sum_s(), b.sum_s());
        assert!((sa - sb).abs() <= 1e-9 * sa.abs().max(1.0), "{sa} vs {sb}");
    }

    #[test]
    fn merge_is_associative_and_order_independent() {
        let xs = sample_latencies(11, 900);
        let parts: Vec<&[f64]> = xs.chunks(300).collect();
        let (a, b, c) = (hist_of(parts[0]), hist_of(parts[1]), hist_of(parts[2]));

        // (a + b) + c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a + (b + c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        // c + a + b (another order)
        let mut rot = c.clone();
        rot.merge(&a);
        rot.merge(&b);
        // the single-pass reference
        let whole = hist_of(&xs);

        assert_same_counts(&left, &right);
        assert_same_counts(&left, &rot);
        assert_same_counts(&left, &whole);
        // Merging an empty histogram is the identity on counts.
        let mut with_empty = whole.clone();
        with_empty.merge(&Hist::new());
        assert_same_counts(&with_empty, &whole);
    }

    #[test]
    fn quantiles_are_bucket_accurate() {
        // All mass in one bucket: every quantile lands inside it.
        let mut h = Hist::new();
        for _ in 0..1000 {
            h.observe(3e-3); // bucket holding ~3 ms
        }
        let i = Hist::bucket_index(3e-3);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let est = h.quantile(q).unwrap();
            assert!(
                est >= Hist::bucket_lo_s(i) && est < Hist::bucket_le_s(i),
                "q={q}: {est} outside bucket {i}"
            );
        }
        // Clamped into the observed range.
        assert_eq!(h.quantile(0.5), Some(3e-3));

        // Bimodal: the median must sit at the heavy mode.
        let mut h = Hist::new();
        for _ in 0..900 {
            h.observe(1e-6);
        }
        for _ in 0..100 {
            h.observe(1.0);
        }
        let p50 = h.quantile(0.50).unwrap();
        assert!(p50 < 1e-4, "median pulled off the heavy mode: {p50}");
        let p999 = h.quantile(0.999).unwrap();
        assert!(p999 >= 0.5, "tail quantile missed the slow mode: {p999}");
        assert!(Hist::new().quantile(0.5).is_none());
    }

    #[test]
    fn payload_value_shape() {
        let mut h = Hist::new();
        h.observe(1e-3);
        h.observe(2e-3);
        let v = h.to_value();
        assert_eq!(v.require_f64("count").unwrap(), 2.0);
        assert!(v.require_f64("sum_s").unwrap() > 0.0);
        assert!(v.require_f64("p50_s").unwrap() > 0.0);
        assert!(v.require_f64("p99_s").unwrap() > 0.0);
        let buckets = v.get("buckets").and_then(Value::as_array).unwrap();
        assert!(!buckets.is_empty());
        let total: f64 = buckets
            .iter()
            .map(|b| b.require_f64("count").unwrap())
            .sum();
        assert_eq!(total, 2.0, "bucket rows conserve the count");
        // Serializes even with overflow-bucket mass (no non-finite
        // numbers may reach the JSON layer).
        h.observe(f64::INFINITY);
        let text = h.to_value().to_json_string().unwrap();
        assert!(text.contains("\"count\""), "{text}");
    }
}
