//! Structured tracing and profiling: lock-cheap spans, NDJSON trace
//! events, mergeable latency histograms, and the trace analyzer behind
//! `cimdse trace`.
//!
//! ## Span model
//!
//! A *span* is a named, timed region of work: it carries a 64-bit
//! trace id (shared by every span of one logical operation, e.g. a
//! whole distributed sweep), its own 64-bit span id, an optional
//! parent span id, a monotonic start timestamp, a duration, the
//! recording thread, and free-form attributes. Spans are RAII guards
//! ([`Span`]): create one with [`span`]/[`child_span`], attach
//! attributes, and the event is recorded when the guard drops. A
//! *trace context* ([`TraceCtx`]) is the `(trace id, span id)` pair
//! that travels across process boundaries — over the wire as the
//! optional protocol-v2 `trace` frame field (16 lowercase hex digits
//! each; see `rust/docs/protocol.md`) — so a fleet run stitches into
//! one forest: launcher shard spans parent the worker-side compute
//! spans, which parent the pool chunk spans.
//!
//! ## Recording
//!
//! The global [`Tracer`] starts disabled: every span call is a single
//! relaxed atomic load and no lock is touched, so the serving hot path
//! pays nothing until `--trace-out` enables it. Enabled, each event is
//! serialized through the crate's own [`crate::config::Value`] JSON
//! layer (no new dependencies) into a bounded in-memory ring of the
//! most recent [`RING_CAPACITY`] lines and, when a file sink is
//! configured, appended as one NDJSON line (written and flushed per
//! event — trace volume is request-scale, not point-scale, and a
//! crashed process keeps its trace).
//!
//! Timestamps are *monotonic* (`t_us` = microseconds since this
//! process's tracer initialized) and therefore only comparable within
//! one process; cross-process ordering comes from the parent links,
//! never from clocks. Trace data flows only to the ring/file sink —
//! never into fingerprinted artifacts or response frames; the
//! `determinism` lint machine-checks that `obs::` is unreachable from
//! serialized paths (see `rust/docs/lints.md`).
//!
//! ## Event schema (one JSON object per line)
//!
//! | key      | type   | meaning                                        |
//! |----------|--------|------------------------------------------------|
//! | `ev`     | string | `"span"` or `"event"` (instant, no duration)   |
//! | `name`   | string | span/event name (`"shard"`, `"chunk"`, ...)    |
//! | `trace`  | string | 16-hex trace id                                |
//! | `span`   | string | 16-hex span id                                 |
//! | `parent` | string | 16-hex parent span id (absent for roots)       |
//! | `t_us`   | number | monotonic start, µs since tracer init          |
//! | `dur_us` | number | span duration in µs (spans only)               |
//! | `tid`    | number | small per-process thread tag                   |
//! | `proc`   | string | process label (`"launcher"`, a worker address) |
//! | `attrs`  | object | free-form attributes (present when non-empty)  |

pub mod analyze;
pub mod hist;

use std::collections::{BTreeMap, VecDeque};
use std::fs::File;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::config::Value;
use crate::error::{Error, Result};

/// Most recent trace lines retained in memory per tracer.
pub const RING_CAPACITY: usize = 4096;

/// A propagatable trace context: which trace this work belongs to and
/// which span is its parent. Wire form: 16 lowercase hex digits each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    /// Identifies the whole logical operation (one distributed sweep).
    pub trace_id: u64,
    /// The span to parent child work under.
    pub span_id: u64,
}

impl TraceCtx {
    /// The wire form of this context: `{"id": <16-hex>, "span": <16-hex>}`,
    /// the exact table the protocol's optional `trace` field carries.
    pub fn to_value(self) -> Value {
        let mut map = BTreeMap::new();
        map.insert("id".to_string(), Value::String(hex16(self.trace_id)));
        map.insert("span".to_string(), Value::String(hex16(self.span_id)));
        Value::Table(map)
    }

    /// Parse the wire form back; `None` if the shape is not a valid
    /// trace table (callers on the serve path validate separately and
    /// reject — this is the lenient read for already-validated echoes).
    pub fn from_value(v: &Value) -> Option<TraceCtx> {
        let trace_id = parse_hex16(v.get("id")?.as_str()?)?;
        let span_id = parse_hex16(v.get("span")?.as_str()?)?;
        Some(TraceCtx { trace_id, span_id })
    }
}

/// Format a 64-bit id as 16 lowercase hex digits.
pub fn hex16(x: u64) -> String {
    format!("{x:016x}")
}

/// Parse exactly 16 lowercase hex digits.
pub fn parse_hex16(s: &str) -> Option<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b)) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Where recorded lines go: the bounded ring plus an optional file.
struct Sink {
    proc_label: String,
    ring: VecDeque<String>,
    file: Option<File>,
}

/// A lock-cheap structured tracer. Disabled (the initial state) it
/// costs one atomic load per span; enabled it serializes each event
/// under a short mutex hold.
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    next_id: AtomicU64,
    sink: Mutex<Sink>,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

impl Tracer {
    /// A fresh, disabled tracer.
    pub fn new() -> Tracer {
        // Seed ids from the wall clock and pid so independently-started
        // processes (launcher + workers) cannot collide; ids never
        // enter fingerprinted payloads, only the trace sink.
        let wall = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let seed = wall ^ (std::process::id() as u64) << 32;
        Tracer {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            next_id: AtomicU64::new(seed),
            sink: Mutex::new(Sink { proc_label: String::new(), ring: VecDeque::new(), file: None }),
        }
    }

    /// Is this tracer recording? The only cost a disabled hot path pays.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Enable in-memory recording only (tests, ad-hoc probes).
    pub fn enable_ring(&self, proc_label: &str) {
        let mut sink = self.sink.lock().unwrap();
        sink.proc_label = proc_label.to_string();
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Enable recording with an NDJSON file sink (the `--trace-out`
    /// path), labeling every event with `proc_label`.
    pub fn enable_file(&self, path: &str, proc_label: &str) -> Result<()> {
        let file = File::create(path)
            .map_err(|e| Error::Config(format!("cannot create trace file `{path}`: {e}")))?;
        let mut sink = self.sink.lock().unwrap();
        sink.proc_label = proc_label.to_string();
        sink.file = Some(file);
        self.enabled.store(true, Ordering::Relaxed);
        Ok(())
    }

    fn fresh_id(&self) -> u64 {
        // SplitMix64 over an atomic counter: unique, well-mixed, and
        // never zero (zero is reserved as "no id").
        let mut z = self.next_id.fetch_add(1, Ordering::Relaxed);
        z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) | 1
    }

    /// Start a root span: a fresh trace id with no parent.
    pub fn span(&self, name: &'static str) -> Span<'_> {
        if !self.enabled() {
            return Span::noop(name);
        }
        let ctx = TraceCtx { trace_id: self.fresh_id(), span_id: self.fresh_id() };
        self.live_span(name, ctx, None)
    }

    /// Start a span under `parent`: same trace, parented to the
    /// context's span (the cross-process link).
    pub fn child_span(&self, name: &'static str, parent: TraceCtx) -> Span<'_> {
        if !self.enabled() {
            return Span::noop(name);
        }
        let ctx = TraceCtx { trace_id: parent.trace_id, span_id: self.fresh_id() };
        self.live_span(name, ctx, Some(parent.span_id))
    }

    fn live_span(&self, name: &'static str, ctx: TraceCtx, parent: Option<u64>) -> Span<'_> {
        Span {
            tracer: Some(self),
            name,
            ctx,
            parent,
            t_us: self.epoch.elapsed().as_micros() as u64,
            started: Instant::now(),
            attrs: BTreeMap::new(),
        }
    }

    /// Record an instant event (no duration) under `parent` if given.
    pub fn event(&self, name: &'static str, parent: Option<TraceCtx>, attrs: &[(&str, Value)]) {
        if !self.enabled() {
            return;
        }
        let ctx = match parent {
            Some(p) => TraceCtx { trace_id: p.trace_id, span_id: self.fresh_id() },
            None => TraceCtx { trace_id: self.fresh_id(), span_id: self.fresh_id() },
        };
        let mut map = event_base("event", name, ctx, parent.map(|p| p.span_id));
        map.insert(
            "t_us".to_string(),
            Value::Number(self.epoch.elapsed().as_micros() as u64 as f64),
        );
        if !attrs.is_empty() {
            let mut a = BTreeMap::new();
            for (k, v) in attrs {
                a.insert((*k).to_string(), v.clone());
            }
            map.insert("attrs".to_string(), Value::Table(a));
        }
        self.record(map);
    }

    /// The in-memory ring, oldest first (tests and ad-hoc inspection).
    pub fn ring(&self) -> Vec<String> {
        self.sink.lock().unwrap().ring.iter().cloned().collect()
    }

    fn record(&self, mut map: BTreeMap<String, Value>) {
        map.insert("tid".to_string(), Value::Number(thread_tag() as f64));
        let mut sink = self.sink.lock().unwrap();
        map.insert("proc".to_string(), Value::String(sink.proc_label.clone()));
        let Ok(line) = Value::Table(map).to_json_string() else {
            return; // an unserializable attr never takes the process down
        };
        if sink.ring.len() >= RING_CAPACITY {
            sink.ring.pop_front();
        }
        sink.ring.push_back(line.clone());
        if let Some(file) = sink.file.as_mut() {
            // Best-effort: a full disk degrades tracing, never serving.
            let _ = writeln!(file, "{line}");
            let _ = file.flush();
        }
    }
}

fn event_base(
    ev: &str,
    name: &str,
    ctx: TraceCtx,
    parent: Option<u64>,
) -> BTreeMap<String, Value> {
    let mut map = BTreeMap::new();
    map.insert("ev".to_string(), Value::String(ev.to_string()));
    map.insert("name".to_string(), Value::String(name.to_string()));
    map.insert("trace".to_string(), Value::String(hex16(ctx.trace_id)));
    map.insert("span".to_string(), Value::String(hex16(ctx.span_id)));
    if let Some(p) = parent {
        map.insert("parent".to_string(), Value::String(hex16(p)));
    }
    map
}

/// Small sequential per-process thread tag (monotonic-clock traces
/// need stable thread identity, not OS thread ids).
fn thread_tag() -> u64 {
    use std::cell::Cell;
    static NEXT_TAG: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TAG: Cell<u64> = const { Cell::new(0) };
    }
    TAG.with(|c| {
        if c.get() == 0 {
            c.set(NEXT_TAG.fetch_add(1, Ordering::Relaxed));
        }
        c.get()
    })
}

/// An RAII span guard: records its event (with duration) on drop.
/// No-op — no lock, no allocation beyond the struct — when the tracer
/// is disabled.
pub struct Span<'a> {
    tracer: Option<&'a Tracer>,
    name: &'static str,
    ctx: TraceCtx,
    parent: Option<u64>,
    t_us: u64,
    started: Instant,
    attrs: BTreeMap<String, Value>,
}

impl Span<'_> {
    fn noop(name: &'static str) -> Span<'static> {
        Span {
            tracer: None,
            name,
            ctx: TraceCtx { trace_id: 0, span_id: 0 },
            parent: None,
            t_us: 0,
            started: Instant::now(),
            attrs: BTreeMap::new(),
        }
    }

    /// Is this span actually recording (tracer enabled at creation)?
    pub fn is_recording(&self) -> bool {
        self.tracer.is_some()
    }

    /// This span's propagatable context (zeros when not recording —
    /// callers gate propagation on [`Span::is_recording`]).
    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }

    /// Attach an attribute (recorded with the span on drop).
    pub fn attr(&mut self, key: &str, value: Value) {
        if self.tracer.is_some() {
            self.attrs.insert(key.to_string(), value);
        }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(tracer) = self.tracer else { return };
        let mut map = event_base("span", self.name, self.ctx, self.parent);
        map.insert("t_us".to_string(), Value::Number(self.t_us as f64));
        map.insert(
            "dur_us".to_string(),
            Value::Number(self.started.elapsed().as_micros() as u64 as f64),
        );
        if !self.attrs.is_empty() {
            map.insert("attrs".to_string(), Value::Table(std::mem::take(&mut self.attrs)));
        }
        tracer.record(map);
    }
}

static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer (disabled until [`init_file`] or
/// [`Tracer::enable_ring`] flips it on).
pub fn global() -> &'static Tracer {
    GLOBAL.get_or_init(Tracer::new)
}

/// Enable the global tracer with an NDJSON file sink — the
/// `--trace-out FILE` entry point.
pub fn init_file(path: &str, proc_label: &str) -> Result<()> {
    global().enable_file(path, proc_label)
}

/// Is the global tracer recording?
pub fn enabled() -> bool {
    global().enabled()
}

/// Start a root span on the global tracer.
pub fn span(name: &'static str) -> Span<'static> {
    global().span(name)
}

/// Start a child span on the global tracer.
pub fn child_span(name: &'static str, parent: TraceCtx) -> Span<'static> {
    global().child_span(name, parent)
}

/// Start a span for a served request: a child of the request's wire
/// `trace` table when it carried a valid one, else a fresh root. The
/// single entry point both serving cores call (so each core carries
/// one audited determinism-lint suppression, not a scatter).
pub fn server_span(name: &'static str, trace: Option<&Value>) -> Span<'static> {
    match trace.and_then(TraceCtx::from_value) {
        Some(parent) => child_span(name, parent),
        None => span(name),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_json;

    #[test]
    fn hex_ids_roundtrip_and_reject_junk() {
        for x in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(parse_hex16(&hex16(x)), Some(x));
        }
        for bad in ["", "123", "0123456789abcdeF", "0123456789abcdeg", "0123456789abcdef0"] {
            assert_eq!(parse_hex16(bad), None, "{bad:?}");
        }
        let ctx = TraceCtx { trace_id: 7, span_id: 9 };
        assert_eq!(TraceCtx::from_value(&ctx.to_value()), Some(ctx));
        assert!(TraceCtx::from_value(&Value::Null).is_none());
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new();
        {
            let mut s = t.span("noop");
            assert!(!s.is_recording());
            s.attr("k", Value::Number(1.0));
        }
        t.event("nothing", None, &[]);
        assert!(t.ring().is_empty());
        assert!(!t.enabled());
    }

    #[test]
    fn spans_record_schema_links_and_order() {
        let t = Tracer::new();
        t.enable_ring("unit-test");
        let parent_ctx;
        {
            let mut root = t.span("root");
            assert!(root.is_recording());
            root.attr("points", Value::Number(12.0));
            parent_ctx = root.ctx();
            {
                let child = t.child_span("child", parent_ctx);
                assert_eq!(child.ctx().trace_id, parent_ctx.trace_id);
                assert_ne!(child.ctx().span_id, parent_ctx.span_id);
            } // child drops (records) first
        } // then root
        let ring = t.ring();
        assert_eq!(ring.len(), 2);
        let child = parse_json(&ring[0]).unwrap();
        let root = parse_json(&ring[1]).unwrap();
        assert_eq!(child.require_str("ev").unwrap(), "span");
        assert_eq!(child.require_str("name").unwrap(), "child");
        assert_eq!(child.require_str("proc").unwrap(), "unit-test");
        assert_eq!(
            child.require_str("parent").unwrap(),
            hex16(parent_ctx.span_id),
            "child links to its parent span"
        );
        assert_eq!(child.require_str("trace").unwrap(), hex16(parent_ctx.trace_id));
        assert!(child.require_f64("t_us").unwrap() >= 0.0);
        assert!(child.require_f64("dur_us").unwrap() >= 0.0);
        assert!(child.require_f64("tid").unwrap() >= 1.0);
        assert!(root.get("parent").is_none(), "roots carry no parent");
        assert_eq!(root.require_f64("attrs.points").unwrap(), 12.0);
    }

    #[test]
    fn instant_events_and_ring_bound() {
        let t = Tracer::new();
        t.enable_ring("ring");
        let root = t.span("anchor");
        let ctx = root.ctx();
        for i in 0..(RING_CAPACITY + 10) {
            t.event("tick", Some(ctx), &[("i", Value::Number(i as f64))]);
        }
        drop(root);
        let ring = t.ring();
        assert_eq!(ring.len(), RING_CAPACITY, "ring is bounded");
        let last = parse_json(ring.last().unwrap()).unwrap();
        assert_eq!(last.require_str("ev").unwrap(), "span");
        let ev = parse_json(&ring[0]).unwrap();
        assert_eq!(ev.require_str("ev").unwrap(), "event");
        assert!(ev.get("dur_us").is_none(), "instant events carry no duration");
    }

    #[test]
    fn ids_are_unique_and_nonzero() {
        let t = Tracer::new();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..10_000 {
            let id = t.fresh_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate id {id:#x}");
        }
    }

    #[test]
    fn file_sink_writes_parseable_ndjson() {
        let dir = std::env::temp_dir().join(format!("cimdse-obs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.ndjson");
        let t = Tracer::new();
        t.enable_file(path.to_str().unwrap(), "file-test").unwrap();
        {
            let mut s = t.span("write");
            s.attr("n", Value::Number(3.0));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1);
        let doc = parse_json(lines[0]).unwrap();
        assert_eq!(doc.require_str("name").unwrap(), "write");
        assert_eq!(doc.require_str("proc").unwrap(), "file-test");
        std::fs::remove_dir_all(&dir).ok();
    }
}
