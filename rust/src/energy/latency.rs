//! Latency / bottleneck model for a mapped layer.
//!
//! The mapper's base latency is ADC-bound (converts / total ADC
//! throughput); this module adds the other pipeline stages so an
//! exploration can see *which* resource limits a configuration — the
//! "picking the number of ADCs" question (Fig. 5) is exactly about
//! moving the ADC off the critical path at acceptable area cost.

use crate::arch::CimArch;
use crate::mapper::Mapping;

/// Per-resource latency estimates (seconds) for one layer inference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyBreakdown {
    /// ADC conversion time: converts / total ADC throughput.
    pub adc_s: f64,
    /// DAC / row-drive time: one bit-plane per row-cycle.
    pub dac_s: f64,
    /// Digital shift-add time.
    pub shift_add_s: f64,
    /// Local SRAM streaming time.
    pub sram_s: f64,
}

/// Default digital clock for the non-ADC pipeline stages (cycles/s).
/// ISAAC/RAELLA-class tiles clock around 1 GHz at 32 nm; scaled with
/// node in [`latency_of_mapping`].
pub const DIGITAL_CLOCK_32NM_HZ: f64 = 1.0e9;

/// SRAM streaming bandwidth at 32 nm (bytes/s): a 32-byte port at clock.
pub const SRAM_BYTES_PER_S_32NM: f64 = 32.0 * DIGITAL_CLOCK_32NM_HZ;

impl LatencyBreakdown {
    /// The critical-path latency (stages overlap; the slowest dominates).
    pub fn critical_s(&self) -> f64 {
        self.adc_s.max(self.dac_s).max(self.shift_add_s).max(self.sram_s)
    }

    /// Name of the bottleneck resource.
    pub fn bottleneck(&self) -> &'static str {
        let c = self.critical_s();
        if c == self.adc_s {
            "adc"
        } else if c == self.dac_s {
            "dac"
        } else if c == self.shift_add_s {
            "shift-add"
        } else {
            "sram"
        }
    }

    /// Whether the ADC is on the critical path.
    pub fn adc_bound(&self) -> bool {
        self.bottleneck() == "adc"
    }
}

/// Latency estimate for a mapped layer on an architecture.
pub fn latency_of_mapping(arch: &CimArch, m: &Mapping) -> LatencyBreakdown {
    // Digital stages slow down linearly with node size.
    let clock = DIGITAL_CLOCK_32NM_HZ * 32.0 / arch.tech_nm;
    let sram_bw = SRAM_BYTES_PER_S_32NM * 32.0 / arch.tech_nm;
    let c = &m.counts;

    // DACs drive all occupied rows of a chunk in parallel; the serial
    // dimension is (positions x planes x chunks) row-cycles, which equals
    // adc_converts / cols_used (every column sees every cycle).
    let row_cycles = c.adc_converts / (m.cols_used as f64).max(1.0);
    // One shift-add per convert, but n_adcs shift-adders run in parallel.
    let shift_add_cycles = c.shift_add_ops / arch.adc.n_adcs as f64;

    LatencyBreakdown {
        adc_s: c.adc_converts / arch.adc.total_throughput,
        dac_s: row_cycles / clock,
        shift_add_s: shift_add_cycles / clock,
        sram_s: c.sram_bytes / sram_bw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::raella::{RaellaVariant, raella};
    use crate::mapper::map_layer;
    use crate::workload::resnet18::large_tensor_layer;

    fn mapping(n_adcs: u32, total: f64) -> (CimArch, Mapping) {
        let mut arch = raella(RaellaVariant::Medium);
        arch.adc.n_adcs = n_adcs;
        arch.adc.total_throughput = total;
        let m = map_layer(&arch, &large_tensor_layer()).unwrap();
        (arch, m)
    }

    #[test]
    fn adc_bound_at_low_adc_throughput() {
        let (arch, m) = mapping(1, 1e8);
        let lat = latency_of_mapping(&arch, &m);
        assert!(lat.adc_bound(), "{lat:?}");
        assert_eq!(lat.critical_s(), lat.adc_s);
    }

    #[test]
    fn adc_leaves_critical_path_at_high_throughput() {
        let (arch, m) = mapping(16, 4e13);
        let lat = latency_of_mapping(&arch, &m);
        assert!(!lat.adc_bound(), "{lat:?}");
    }

    #[test]
    fn more_adc_throughput_never_slows_down() {
        let (arch_lo, m) = mapping(4, 1.3e9);
        let (arch_hi, _) = mapping(4, 1.3e10);
        let lo = latency_of_mapping(&arch_lo, &m);
        let hi = latency_of_mapping(&arch_hi, &m);
        assert!(hi.critical_s() <= lo.critical_s());
        // Non-ADC stages are untouched by the ADC knob.
        assert_eq!(lo.dac_s, hi.dac_s);
        assert_eq!(lo.sram_s, hi.sram_s);
    }

    #[test]
    fn bigger_node_is_slower_digitally() {
        let (mut arch, m) = mapping(4, 1.3e9);
        let lat32 = latency_of_mapping(&arch, &m);
        arch.tech_nm = 65.0;
        let lat65 = latency_of_mapping(&arch, &m);
        assert!(lat65.dac_s > lat32.dac_s);
        assert!(lat65.sram_s > lat32.sram_s);
        assert_eq!(lat65.adc_s, lat32.adc_s); // ADC rate is an input, not derived
    }

    #[test]
    fn parallel_shift_adders_help() {
        let (a1, m) = mapping(1, 1.3e9);
        let (a8, _) = mapping(8, 1.3e9);
        let l1 = latency_of_mapping(&a1, &m);
        let l8 = latency_of_mapping(&a8, &m);
        assert!((l1.shift_add_s / l8.shift_add_s - 8.0).abs() < 1e-9);
    }
}
