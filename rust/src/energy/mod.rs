//! Full-accelerator energy / area / EAP rollup.
//!
//! Combines the ADC model (the paper's contribution) with the component
//! library and the mapper's action counts into per-layer and per-network
//! energy, architecture area, and the energy-area product that Fig. 5
//! optimizes.

pub mod latency;

pub use latency::{LatencyBreakdown, latency_of_mapping};

use crate::adc::{AdcModel, AdcQuery};
use crate::arch::CimArch;
use crate::components::{self, AdcComponent};
use crate::error::Result;
use crate::mapper::{Mapping, map_layer};
use crate::workload::{Layer, Workload};

/// Per-component energy breakdown for one layer inference (picojoules).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// ADC conversion energy.
    pub adc_pj: f64,
    /// DAC / wordline drive energy.
    pub dac_pj: f64,
    /// Crossbar cell read energy.
    pub crossbar_pj: f64,
    /// Sample-and-hold energy.
    pub sample_hold_pj: f64,
    /// Shift-add energy.
    pub shift_add_pj: f64,
    /// Register traffic energy.
    pub register_pj: f64,
    /// Local SRAM energy.
    pub sram_pj: f64,
    /// Global eDRAM energy.
    pub edram_pj: f64,
    /// NoC energy.
    pub router_pj: f64,
}

impl EnergyBreakdown {
    /// Total energy in picojoules.
    pub fn total_pj(&self) -> f64 {
        self.adc_pj
            + self.dac_pj
            + self.crossbar_pj
            + self.sample_hold_pj
            + self.shift_add_pj
            + self.register_pj
            + self.sram_pj
            + self.edram_pj
            + self.router_pj
    }

    /// ADC share of total energy, in [0, 1].
    pub fn adc_fraction(&self) -> f64 {
        self.adc_pj / self.total_pj()
    }

    /// Element-wise sum.
    pub fn add(&self, other: &EnergyBreakdown) -> EnergyBreakdown {
        EnergyBreakdown {
            adc_pj: self.adc_pj + other.adc_pj,
            dac_pj: self.dac_pj + other.dac_pj,
            crossbar_pj: self.crossbar_pj + other.crossbar_pj,
            sample_hold_pj: self.sample_hold_pj + other.sample_hold_pj,
            shift_add_pj: self.shift_add_pj + other.shift_add_pj,
            register_pj: self.register_pj + other.register_pj,
            sram_pj: self.sram_pj + other.sram_pj,
            edram_pj: self.edram_pj + other.edram_pj,
            router_pj: self.router_pj + other.router_pj,
        }
    }
}

/// Per-component area breakdown (µm²).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AreaBreakdown {
    /// All ADCs.
    pub adc_um2: f64,
    /// Crossbar arrays (cells).
    pub arrays_um2: f64,
    /// Row DACs.
    pub dac_um2: f64,
    /// Column sample-and-holds.
    pub sample_hold_um2: f64,
    /// Shift-add units (one per ADC).
    pub shift_add_um2: f64,
    /// Local SRAM.
    pub sram_um2: f64,
    /// Global eDRAM.
    pub edram_um2: f64,
    /// Router.
    pub router_um2: f64,
}

impl AreaBreakdown {
    /// Total area in µm².
    pub fn total_um2(&self) -> f64 {
        self.adc_um2
            + self.arrays_um2
            + self.dac_um2
            + self.sample_hold_um2
            + self.shift_add_um2
            + self.sram_um2
            + self.edram_um2
            + self.router_um2
    }

    /// ADC share of total area, in [0, 1].
    pub fn adc_fraction(&self) -> f64 {
        self.adc_um2 / self.total_um2()
    }
}

/// Scope of the area rollup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AreaScope {
    /// One CiM array group + its converters (paper Fig. 5's "RAELLA CiM
    /// arrays" granularity: arrays, DACs, S+H, ADCs, shift-adds).
    ArrayGroup { n_arrays: usize },
    /// A full tile: array group plus SRAM, eDRAM share, and router
    /// (Fig. 4's full-accelerator granularity).
    Tile { n_arrays: usize },
}

/// The ADC query implied by an architecture's ADC config.
pub fn adc_query(arch: &CimArch) -> AdcQuery {
    AdcQuery {
        enob: arch.adc.enob,
        total_throughput: arch.adc.total_throughput,
        tech_nm: arch.tech_nm,
        n_adcs: arch.adc.n_adcs,
    }
}

/// Price one layer's mapped action counts (energy rollup).
pub fn layer_energy(arch: &CimArch, model: &AdcModel, layer: &Layer) -> Result<EnergyBreakdown> {
    let mapping = map_layer(arch, layer)?;
    Ok(energy_of_mapping(arch, model, &mapping))
}

/// Price an existing mapping.
pub fn energy_of_mapping(arch: &CimArch, model: &AdcModel, m: &Mapping) -> EnergyBreakdown {
    let t = arch.tech_nm;
    let adc = AdcComponent { model: *model, query: adc_query(arch) };
    let c = &m.counts;
    EnergyBreakdown {
        adc_pj: adc.energy_pj(c.adc_converts),
        dac_pj: components::dac(t).energy_pj(c.dac_drives),
        crossbar_pj: components::crossbar_cell(t).energy_pj(c.cell_reads),
        sample_hold_pj: components::sample_hold(t).energy_pj(c.sh_samples),
        shift_add_pj: components::shift_add(t).energy_pj(c.shift_add_ops),
        register_pj: components::register(t).energy_pj(c.register_bits),
        sram_pj: components::sram(t).energy_pj(c.sram_bytes),
        edram_pj: components::edram(t).energy_pj(c.edram_bytes),
        router_pj: components::router(t).energy_pj(c.noc_flits),
    }
}

/// Whole-workload energy (sum over layers).
pub fn workload_energy(
    arch: &CimArch,
    model: &AdcModel,
    workload: &Workload,
) -> Result<EnergyBreakdown> {
    let mut total = EnergyBreakdown::default();
    for layer in &workload.layers {
        total = total.add(&layer_energy(arch, model, layer)?);
    }
    Ok(total)
}

/// Architecture area under the given scope.
pub fn accel_area(arch: &CimArch, model: &AdcModel, scope: AreaScope) -> AreaBreakdown {
    let t = arch.tech_nm;
    let (n_arrays, with_buffers) = match scope {
        AreaScope::ArrayGroup { n_arrays } => (n_arrays, false),
        AreaScope::Tile { n_arrays } => (n_arrays, true),
    };
    let adc = AdcComponent { model: *model, query: adc_query(arch) };
    let cells = (arch.array_rows * arch.array_cols) as f64;
    let mut area = AreaBreakdown {
        adc_um2: adc.total_area_um2(),
        arrays_um2: n_arrays as f64 * cells * components::crossbar_cell(t).area_um2,
        dac_um2: n_arrays as f64 * arch.array_rows as f64 * components::dac(t).area_um2,
        sample_hold_um2: n_arrays as f64
            * arch.array_cols as f64
            * components::sample_hold(t).area_um2,
        shift_add_um2: arch.adc.n_adcs as f64 * components::shift_add(t).area_um2,
        ..Default::default()
    };
    if with_buffers {
        area.sram_um2 = arch.sram_bytes as f64 * components::sram(t).area_um2;
        area.edram_um2 = arch.edram_bytes as f64 * components::edram(t).area_um2;
        area.router_um2 = components::router(t).area_um2;
    }
    area
}

/// Energy-area product: energy (pJ) x area (µm²) — the Fig. 5 objective.
/// Absolute units are arbitrary; only ratios across design points matter.
pub fn eap(energy: &EnergyBreakdown, area: &AreaBreakdown) -> f64 {
    energy.total_pj() * area.total_um2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::raella::{RaellaVariant, raella};
    use crate::workload::resnet18::{large_tensor_layer, resnet18, small_tensor_layer};

    #[test]
    fn breakdown_total_is_sum_of_parts() {
        let arch = raella(RaellaVariant::Medium);
        let e = layer_energy(&arch, &AdcModel::default(), &large_tensor_layer()).unwrap();
        let manual = e.adc_pj
            + e.dac_pj
            + e.crossbar_pj
            + e.sample_hold_pj
            + e.shift_add_pj
            + e.register_pj
            + e.sram_pj
            + e.edram_pj
            + e.router_pj;
        assert!((e.total_pj() - manual).abs() < 1e-9);
        assert!(e.total_pj() > 0.0);
    }

    #[test]
    fn adc_is_a_significant_but_not_total_fraction() {
        // The premise of the paper: ADCs consume significant energy/area.
        let arch = raella(RaellaVariant::Medium);
        let model = AdcModel::default();
        let e = layer_energy(&arch, &model, &large_tensor_layer()).unwrap();
        let frac = e.adc_fraction();
        assert!(frac > 0.1 && frac < 0.95, "ADC energy fraction {frac}");
        let a = accel_area(&arch, &model, AreaScope::ArrayGroup { n_arrays: 1 });
        let afrac = a.adc_fraction();
        assert!(afrac > 0.05 && afrac < 0.9, "ADC area fraction {afrac}");
    }

    #[test]
    fn large_layer_prefers_bigger_sums() {
        // Fig. 4 large-tensor mechanism: XL's 36x fewer converts beat its
        // ~5.6x per-convert energy premium.
        let model = AdcModel::default();
        let l = large_tensor_layer();
        let e_s = layer_energy(&raella(RaellaVariant::Small), &model, &l).unwrap();
        let e_xl = layer_energy(&raella(RaellaVariant::ExtraLarge), &model, &l).unwrap();
        assert!(e_xl.adc_pj < e_s.adc_pj, "XL {} vs S {}", e_xl.adc_pj, e_s.adc_pj);
    }

    #[test]
    fn small_layer_prefers_small_sums() {
        // Fig. 4 small-tensor mechanism: converts equal, per-convert
        // energy grows with ENOB => monotone in variant size.
        let model = AdcModel::default();
        let l = small_tensor_layer();
        let adc: Vec<f64> = RaellaVariant::ALL
            .iter()
            .map(|&v| layer_energy(&raella(v), &model, &l).unwrap().adc_pj)
            .collect();
        assert!(adc.windows(2).all(|w| w[0] < w[1]), "{adc:?}");
    }

    #[test]
    fn workload_energy_sums_layers() {
        let arch = raella(RaellaVariant::Medium);
        let model = AdcModel::default();
        let net = resnet18();
        let total = workload_energy(&arch, &model, &net).unwrap();
        let manual: f64 = net
            .layers
            .iter()
            .map(|l| layer_energy(&arch, &model, l).unwrap().total_pj())
            .sum();
        assert!((total.total_pj() - manual).abs() / manual < 1e-12);
    }

    #[test]
    fn tile_scope_is_larger_than_array_group() {
        let arch = raella(RaellaVariant::Medium);
        let model = AdcModel::default();
        let g = accel_area(&arch, &model, AreaScope::ArrayGroup { n_arrays: 4 });
        let t = accel_area(&arch, &model, AreaScope::Tile { n_arrays: 4 });
        assert!(t.total_um2() > g.total_um2());
        assert_eq!(g.sram_um2, 0.0);
        assert!(t.sram_um2 > 0.0);
    }

    #[test]
    fn eap_is_product() {
        let arch = raella(RaellaVariant::Medium);
        let model = AdcModel::default();
        let e = layer_energy(&arch, &model, &large_tensor_layer()).unwrap();
        let a = accel_area(&arch, &model, AreaScope::ArrayGroup { n_arrays: 1 });
        assert!((eap(&e, &a) - e.total_pj() * a.total_um2()).abs() < 1e-3);
    }
}
