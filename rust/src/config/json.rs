//! Minimal recursive-descent JSON parser (subset sufficient for
//! `artifacts/manifest.json`: objects, arrays, strings with standard
//! escapes, numbers, booleans, null).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

use super::Value;

/// Parse a JSON document into a [`Value`].
pub fn parse_json(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Table(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Table(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u escape"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8: back up and take the char.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    self.pos = start + ch.len_utf8();
                    let _ = c;
                    out.push(ch);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(&format!("bad number `{text}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "adc_model": {"file": "adc_model.hlo.txt", "batch": 4096,
                          "default_coefs": [-2.301, 0.25, 1.0]},
            "ok": true, "nothing": null
        }"#;
        let v = parse_json(doc).unwrap();
        assert_eq!(v.get("adc_model.batch").unwrap().as_usize(), Some(4096));
        assert_eq!(v.get("adc_model.file").unwrap().as_str(), Some("adc_model.hlo.txt"));
        let coefs = v.get("adc_model.default_coefs").unwrap().as_array().unwrap();
        assert_eq!(coefs.len(), 3);
        assert_eq!(coefs[0].as_f64(), Some(-2.301));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("nothing"), Some(&Value::Null));
    }

    #[test]
    fn parses_nested_arrays_and_escapes() {
        let v = parse_json(r#"[[1, 2], ["a\n\"bA"]]"#).unwrap();
        let outer = v.as_array().unwrap();
        assert_eq!(outer[0].as_array().unwrap()[1].as_f64(), Some(2.0));
        assert_eq!(outer[1].as_array().unwrap()[0].as_str(), Some("a\n\"bA"));
    }

    #[test]
    fn scientific_numbers() {
        let v = parse_json("[1.3e9, -4.0E-2]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.3e9));
        assert_eq!(a[1].as_f64(), Some(-0.04));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse_json("{} extra").is_err());
        assert!(parse_json("{,}").is_err());
        assert!(parse_json("[1,").is_err());
        assert!(parse_json("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse_json("{}").unwrap(), Value::Table(Default::default()));
        assert_eq!(parse_json("[]").unwrap(), Value::Array(vec![]));
    }
}
