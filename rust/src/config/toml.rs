//! TOML-subset parser for architecture / workload spec files.
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! strings (including the basic escapes `\"`, `\\`, `\n`, `\t`), numbers
//! (including `1.3e9`), booleans, flat arrays, and `#` comments. This
//! covers the `configs/*.toml` shipped with the crate; anything fancier
//! (dates, inline tables, multi-line strings, `\u` escapes) is rejected
//! with a line-numbered error.
//!
//! Compatibility note: `\` inside a string is now always an escape
//! introducer, exactly as in real TOML basic strings. Earlier revisions
//! of this subset kept backslashes verbatim, so a pre-escape document
//! holding `"C:\temp"` decodes differently today (`\t` → tab) — and
//! unknown escapes like `\x` are hard errors rather than silently kept.
//! No spec shipped in `configs/` contains a backslash; hand-written
//! files that do must double them (`"C:\\temp"`).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

use super::Value;

/// Parse a TOML-subset document into a [`Value::Table`].
pub fn parse_toml(input: &str) -> Result<Value> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if header.is_empty() {
                return Err(err(lineno, "empty section header"));
            }
            current_path = header.split('.').map(|s| s.trim().to_string()).collect();
            // Materialize the section table.
            table_at(&mut root, &current_path, lineno)?;
            continue;
        }
        let (key, value_text) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected `key = value`"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(value_text.trim(), lineno)?;
        let table = table_at(&mut root, &current_path, lineno)?;
        if table.insert(key.to_string(), value).is_some() {
            return Err(err(lineno, &format!("duplicate key `{key}`")));
        }
    }
    Ok(Value::Table(root))
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("toml parse error on line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting string literals (including escaped
/// quotes inside them — `"\""` does not close the string).
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else {
            match c {
                '"' => in_str = true,
                '#' => return &line[..i],
                _ => {}
            }
        }
    }
    line
}

/// Get (creating as needed) the table at `path` under `root`.
fn table_at<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Value>> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        match entry {
            Value::Table(map) => cur = map,
            _ => return Err(err(lineno, &format!("`{part}` is not a table"))),
        }
    }
    Ok(cur)
}

fn parse_value(text: &str, lineno: usize) -> Result<Value> {
    if text.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for piece in split_array_items(inner) {
            let piece = piece.trim();
            if !piece.is_empty() {
                items.push(parse_value(piece, lineno)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(Value::String(unescape(inner, lineno)?));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Numbers, including underscores (1_000) and scientific notation.
    let cleaned: String = text.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Value::Number)
        .map_err(|_| err(lineno, &format!("cannot parse value `{text}`")))
}

/// Decode the subset's string escapes (`\"`, `\\`, `\n`, `\t`). A bare
/// `"` cannot reach here from a well-formed line, but tampered input can
/// produce one (e.g. via a comment-stripped fragment), so it is rejected
/// rather than silently kept; so are unknown escapes and a trailing `\`.
fn unescape(s: &str, lineno: usize) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        match c {
            '\\' => match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some(other) => {
                    return Err(err(lineno, &format!("unsupported string escape `\\{other}`")));
                }
                None => return Err(err(lineno, "trailing `\\` in string")),
            },
            '"' => return Err(err(lineno, "unescaped `\"` inside string")),
            c => out.push(c),
        }
    }
    Ok(out)
}

/// Split a flat array body on commas outside string literals (escaped
/// quotes do not close a literal).
fn split_array_items(body: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in body.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else {
            match c {
                '"' => in_str = true,
                ',' => {
                    items.push(&body[start..i]);
                    start = i + 1;
                }
                _ => {}
            }
        }
    }
    items.push(&body[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# RAELLA-like architecture
name = "raella-m"   # inline comment
tech_nm = 32
sum_size = 512

[adc]
enob_bits = 7
throughput = 1.3e9
n_adcs = 2

[array.dims]
rows = 512
cols = 512
levels = [1, 2, 4]
tags = ["a", "b,c"]
enabled = true
"#;

    #[test]
    fn parses_sections_and_values() {
        let v = parse_toml(DOC).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("raella-m"));
        assert_eq!(v.get("tech_nm").unwrap().as_f64(), Some(32.0));
        assert_eq!(v.get("adc.enob_bits").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("adc.throughput").unwrap().as_f64(), Some(1.3e9));
        assert_eq!(v.get("array.dims.rows").unwrap().as_usize(), Some(512));
        assert_eq!(v.get("array.dims.enabled").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn arrays_and_quoted_commas() {
        let v = parse_toml(DOC).unwrap();
        let levels = v.get("array.dims.levels").unwrap().as_array().unwrap();
        assert_eq!(levels.len(), 3);
        let tags = v.get("array.dims.tags").unwrap().as_array().unwrap();
        assert_eq!(tags[1].as_str(), Some("b,c"));
    }

    #[test]
    fn underscored_numbers() {
        let v = parse_toml("x = 1_000_000").unwrap();
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1e6));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("a = 1\nb = ").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        let e = parse_toml("[sec\nx = 1").unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse_toml("a = 1\na = 2").is_err());
    }

    #[test]
    fn key_with_same_name_as_section_rejected() {
        assert!(parse_toml("a = 1\n[a]\nb = 2").is_err());
    }

    #[test]
    fn string_escapes_decode() {
        let v = parse_toml(r#"s = "say \"hi\"\n\ttab \\ done""#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("say \"hi\"\n\ttab \\ done"));
        // Escaped quotes do not end the literal for comment stripping...
        let v = parse_toml(r##"s = "a\"b" # comment with " quote"##).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\"b"));
        // ...nor for array splitting.
        let v = parse_toml(r#"a = ["x\",y", "z"]"#).unwrap();
        let items = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(items[0].as_str(), Some("x\",y"));
        assert_eq!(items[1].as_str(), Some("z"));
    }

    #[test]
    fn bad_escapes_are_line_numbered_errors() {
        for doc in [
            "s = \"bad \\x escape\"",
            "s = \"trailing slash \\\"",
            "s = \"unterminated \\\" tail",
        ] {
            let e = parse_toml(doc).unwrap_err().to_string();
            assert!(e.contains("line 1"), "{doc:?}: {e}");
        }
    }
}
