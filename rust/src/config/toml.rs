//! TOML-subset parser for architecture / workload spec files.
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! strings, numbers (including `1.3e9`), booleans, flat arrays, and `#`
//! comments. This covers the `configs/*.toml` shipped with the crate;
//! anything fancier (dates, inline tables, multi-line strings) is
//! rejected with a line-numbered error.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

use super::Value;

/// Parse a TOML-subset document into a [`Value::Table`].
pub fn parse_toml(input: &str) -> Result<Value> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if header.is_empty() {
                return Err(err(lineno, "empty section header"));
            }
            current_path = header.split('.').map(|s| s.trim().to_string()).collect();
            // Materialize the section table.
            table_at(&mut root, &current_path, lineno)?;
            continue;
        }
        let (key, value_text) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected `key = value`"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(value_text.trim(), lineno)?;
        let table = table_at(&mut root, &current_path, lineno)?;
        if table.insert(key.to_string(), value).is_some() {
            return Err(err(lineno, &format!("duplicate key `{key}`")));
        }
    }
    Ok(Value::Table(root))
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("toml parse error on line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Get (creating as needed) the table at `path` under `root`.
fn table_at<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Value>> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        match entry {
            Value::Table(map) => cur = map,
            _ => return Err(err(lineno, &format!("`{part}` is not a table"))),
        }
    }
    Ok(cur)
}

fn parse_value(text: &str, lineno: usize) -> Result<Value> {
    if text.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        for piece in split_array_items(inner) {
            let piece = piece.trim();
            if !piece.is_empty() {
                items.push(parse_value(piece, lineno)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(Value::String(inner.to_string()));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Numbers, including underscores (1_000) and scientific notation.
    let cleaned: String = text.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Value::Number)
        .map_err(|_| err(lineno, &format!("cannot parse value `{text}`")))
}

/// Split a flat array body on commas outside string literals.
fn split_array_items(body: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&body[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# RAELLA-like architecture
name = "raella-m"   # inline comment
tech_nm = 32
sum_size = 512

[adc]
enob_bits = 7
throughput = 1.3e9
n_adcs = 2

[array.dims]
rows = 512
cols = 512
levels = [1, 2, 4]
tags = ["a", "b,c"]
enabled = true
"#;

    #[test]
    fn parses_sections_and_values() {
        let v = parse_toml(DOC).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("raella-m"));
        assert_eq!(v.get("tech_nm").unwrap().as_f64(), Some(32.0));
        assert_eq!(v.get("adc.enob_bits").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("adc.throughput").unwrap().as_f64(), Some(1.3e9));
        assert_eq!(v.get("array.dims.rows").unwrap().as_usize(), Some(512));
        assert_eq!(v.get("array.dims.enabled").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn arrays_and_quoted_commas() {
        let v = parse_toml(DOC).unwrap();
        let levels = v.get("array.dims.levels").unwrap().as_array().unwrap();
        assert_eq!(levels.len(), 3);
        let tags = v.get("array.dims.tags").unwrap().as_array().unwrap();
        assert_eq!(tags[1].as_str(), Some("b,c"));
    }

    #[test]
    fn underscored_numbers() {
        let v = parse_toml("x = 1_000_000").unwrap();
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1e6));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("a = 1\nb = ").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        let e = parse_toml("[sec\nx = 1").unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse_toml("a = 1\na = 2").is_err());
    }

    #[test]
    fn key_with_same_name_as_section_rejected() {
        assert!(parse_toml("a = 1\n[a]\nb = 2").is_err());
    }
}
