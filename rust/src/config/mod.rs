//! Configuration substrate: a small self-contained value model with JSON
//! and TOML-subset parsers (no serde in the offline registry).
//!
//! [`json`] parses `artifacts/manifest.json` (the shape contract emitted
//! by `python/compile/aot.py`). [`toml`] parses the architecture /
//! workload spec files under `configs/`.

pub mod json;
pub mod toml;

pub use json::parse_json;
pub use toml::parse_toml;

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Encode an `f64` as its 16-hex-digit IEEE-754 bit pattern
/// (`"3ff0000000000000"` for `1.0`). Unlike decimal [`Value::Number`]
/// serialization this is total: NaN and ±inf encode too, and decoding via
/// [`f64_from_bits_hex`] is bit-exact by construction — the shard
/// artifacts use it for every payload float so merged results stay
/// bit-identical across process boundaries.
pub fn f64_to_bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Decode a 16-hex-digit bit pattern produced by [`f64_to_bits_hex`].
pub fn f64_from_bits_hex(s: &str) -> Result<f64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(Error::Config(format!(
            "bad f64 bit pattern `{s}` (want exactly 16 hex digits)"
        )));
    }
    let bits = u64::from_str_radix(s, 16)
        .map_err(|_| Error::Config(format!("bad f64 bit pattern `{s}`")))?;
    Ok(f64::from_bits(bits))
}

/// A dynamically-typed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// null / absent.
    Null,
    /// Boolean.
    Bool(bool),
    /// All numbers are kept as f64 (adequate for config use).
    Number(f64),
    /// String.
    String(String),
    /// Ordered list.
    Array(Vec<Value>),
    /// Key-value table (sorted for deterministic output).
    Table(BTreeMap<String, Value>),
}

impl Value {
    /// Navigate a dotted path like `"adc_model.batch"`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for key in path.split('.') {
            match cur {
                Value::Table(map) => cur = map.get(key)?,
                _ => return None,
            }
        }
        Some(cur)
    }

    /// The value as f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as usize, if a non-negative integral number.
    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None }
    }

    /// The value as &str, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    /// The value as bool, if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as a slice, if an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Required numeric field with a config-error message.
    pub fn require_f64(&self, path: &str) -> Result<f64> {
        self.get(path)
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::Config(format!("missing/non-numeric field `{path}`")))
    }

    /// Required usize field.
    pub fn require_usize(&self, path: &str) -> Result<usize> {
        self.get(path)
            .and_then(Value::as_usize)
            .ok_or_else(|| Error::Config(format!("missing/non-integer field `{path}`")))
    }

    /// Required string field.
    pub fn require_str(&self, path: &str) -> Result<&str> {
        self.get(path)
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Config(format!("missing/non-string field `{path}`")))
    }

    /// Serialize to a JSON document that [`parse_json`] round-trips
    /// losslessly (f64 `Display` prints the shortest digits that parse
    /// back to the identical bits; tables stay sorted). Errors on
    /// non-finite numbers, which JSON cannot represent.
    pub fn to_json_string(&self) -> Result<String> {
        fn escape(s: &str) -> String {
            let mut out = String::with_capacity(s.len() + 2);
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        Ok(match self {
            Value::Null => "null".to_string(),
            Value::Bool(b) => b.to_string(),
            Value::Number(n) => {
                if !n.is_finite() {
                    return Err(Error::Config(format!(
                        "json serialize: non-finite number {n} is not representable"
                    )));
                }
                n.to_string()
            }
            Value::String(s) => format!("\"{}\"", escape(s)),
            Value::Array(items) => format!(
                "[{}]",
                items
                    .iter()
                    .map(Value::to_json_string)
                    .collect::<Result<Vec<_>>>()?
                    .join(", ")
            ),
            Value::Table(map) => format!(
                "{{{}}}",
                map.iter()
                    .map(|(k, v)| Ok(format!("\"{}\": {}", escape(k), v.to_json_string()?)))
                    .collect::<Result<Vec<_>>>()?
                    .join(", ")
            ),
        })
    }

    /// Serialize a table to a TOML-subset document that [`parse_toml`]
    /// round-trips losslessly: scalar / array keys first, then one
    /// `[dotted.section]` block per nested table (recursively). Strings
    /// are emitted with the subset's escapes (`\"`, `\\`, `\n`, `\t`).
    ///
    /// Errors on shapes the subset parser cannot represent: a non-table
    /// root, `null`, non-finite numbers, tables inside arrays, nested
    /// arrays, strings containing control characters with no escape
    /// (anything below 0x20 other than `\n`/`\t`, e.g. `\r` — the parser
    /// is line-oriented and would mangle them), and keys using characters
    /// outside `[A-Za-z0-9_-]` (the parser would split on `.`/`=`/`#`).
    pub fn to_toml_string(&self) -> Result<String> {
        fn checked_key(k: &str) -> Result<&str> {
            let bare = !k.is_empty()
                && k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-');
            if bare {
                Ok(k)
            } else {
                Err(Error::Config(format!(
                    "toml serialize: key `{k}` is not a bare [A-Za-z0-9_-]+ key"
                )))
            }
        }

        fn scalar(v: &Value) -> Result<String> {
            match v {
                Value::Bool(b) => Ok(b.to_string()),
                Value::Number(n) => {
                    if !n.is_finite() {
                        return Err(Error::Config(format!(
                            "toml serialize: non-finite number {n} is not representable"
                        )));
                    }
                    Ok(n.to_string())
                }
                Value::String(s) => {
                    let mut out = String::with_capacity(s.len() + 2);
                    out.push('"');
                    for c in s.chars() {
                        match c {
                            '"' => out.push_str("\\\""),
                            '\\' => out.push_str("\\\\"),
                            '\n' => out.push_str("\\n"),
                            '\t' => out.push_str("\\t"),
                            c if (c as u32) < 0x20 => {
                                return Err(Error::Config(format!(
                                    "toml serialize: string {s:?} contains control \
                                     character {c:?} the subset cannot escape"
                                )));
                            }
                            c => out.push(c),
                        }
                    }
                    out.push('"');
                    Ok(out)
                }
                Value::Array(items) => {
                    let parts = items
                        .iter()
                        .map(|item| match item {
                            Value::Array(_) | Value::Table(_) | Value::Null => {
                                Err(Error::Config(
                                    "toml serialize: arrays may only hold scalars".into(),
                                ))
                            }
                            other => scalar(other),
                        })
                        .collect::<Result<Vec<_>>>()?;
                    Ok(format!("[{}]", parts.join(", ")))
                }
                Value::Null => {
                    Err(Error::Config("toml serialize: null is not representable".into()))
                }
                Value::Table(_) => unreachable!("tables are emitted as sections"),
            }
        }

        fn emit(map: &BTreeMap<String, Value>, path: &[&str], out: &mut String) -> Result<()> {
            if !path.is_empty() {
                out.push_str(&format!("\n[{}]\n", path.join(".")));
            }
            // Scalars first so they land in this section, not a child's.
            for (key, v) in map {
                if !matches!(v, Value::Table(_)) {
                    out.push_str(&format!("{} = {}\n", checked_key(key)?, scalar(v)?));
                }
            }
            for (key, v) in map {
                if let Value::Table(child) = v {
                    let mut child_path: Vec<&str> = path.to_vec();
                    child_path.push(checked_key(key)?);
                    emit(child, &child_path, out)?;
                }
            }
            Ok(())
        }

        match self {
            Value::Table(map) => {
                let mut out = String::new();
                emit(map, &[], &mut out)?;
                Ok(out)
            }
            _ => Err(Error::Config("toml serialize: root must be a table".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(pairs: &[(&str, Value)]) -> Value {
        Value::Table(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
    }

    #[test]
    fn dotted_path_navigation() {
        let v = table(&[("a", table(&[("b", Value::Number(3.0))]))]);
        assert_eq!(v.get("a.b").unwrap().as_f64(), Some(3.0));
        assert!(v.get("a.c").is_none());
        assert!(v.get("x").is_none());
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert_eq!(Value::Number(4.0).as_usize(), Some(4));
        assert_eq!(Value::Number(4.5).as_usize(), None);
        assert_eq!(Value::Number(-1.0).as_usize(), None);
    }

    #[test]
    fn require_errors_mention_path() {
        let v = table(&[]);
        let err = v.require_f64("missing.key").unwrap_err().to_string();
        assert!(err.contains("missing.key"), "{err}");
    }

    #[test]
    fn json_serialize_roundtrips() {
        let v = table(&[
            ("n", Value::Number(1.3e9)),
            ("frac", Value::Number(-0.051)),
            ("flag", Value::Bool(true)),
            ("none", Value::Null),
            (
                "s",
                Value::String("quote \" slash \\ newline \n tab \t".into()),
            ),
            (
                "nested",
                table(&[(
                    "arr",
                    Value::Array(vec![Value::Number(1.0), Value::String("x".into())]),
                )]),
            ),
        ]);
        let text = v.to_json_string().unwrap();
        assert_eq!(parse_json(&text).unwrap(), v, "{text}");
    }

    #[test]
    fn serializers_reject_non_finite_numbers() {
        let bad = table(&[("x", Value::Number(f64::NAN))]);
        assert!(bad.to_json_string().is_err());
        assert!(bad.to_toml_string().is_err());
        let inf = table(&[("x", Value::Number(f64::INFINITY))]);
        assert!(inf.to_json_string().is_err());
        assert!(inf.to_toml_string().is_err());
    }

    #[test]
    fn toml_serialize_rejects_non_bare_keys() {
        for key in ["a.b", "a=b", "a#b", "a b", "", "a[0]"] {
            let v = table(&[(key, Value::Number(1.0))]);
            assert!(v.to_toml_string().is_err(), "key `{key}` should be rejected");
            let nested = table(&[(key, table(&[("inner", Value::Number(1.0))]))]);
            assert!(nested.to_toml_string().is_err(), "section `{key}` should be rejected");
        }
    }

    #[test]
    fn toml_serialize_roundtrips_nested_sections() {
        let v = table(&[
            ("name", Value::String("raella-m".into())),
            ("tech_nm", Value::Number(32.0)),
            (
                "array",
                table(&[
                    ("rows", Value::Number(512.0)),
                    ("levels", Value::Array(vec![Value::Number(1.0), Value::Number(4.0)])),
                    ("dims", table(&[("inner", Value::Bool(false))])),
                ]),
            ),
        ]);
        let text = v.to_toml_string().unwrap();
        assert_eq!(parse_toml(&text).unwrap(), v, "{text}");
    }

    #[test]
    fn toml_serialize_rejects_unrepresentable_shapes() {
        assert!(Value::Number(1.0).to_toml_string().is_err());
        let null_val = table(&[("x", Value::Null)]);
        assert!(null_val.to_toml_string().is_err());
        let nested_arr = table(&[("x", Value::Array(vec![Value::Array(vec![])]))]);
        assert!(nested_arr.to_toml_string().is_err());
        // \r has no escape in the subset (the parser is line-oriented).
        let bad_string = table(&[("x", Value::String("has \r return".into()))]);
        assert!(bad_string.to_toml_string().is_err());
    }

    #[test]
    fn toml_serialize_escapes_roundtrip() {
        let v = table(&[
            ("quoted", Value::String("say \"hi\"".into())),
            ("slashes", Value::String("a\\b\\\\c".into())),
            ("multiline", Value::String("line1\nline2\ttabbed".into())),
            ("hashy", Value::String("not # a comment".into())),
            (
                "arr",
                Value::Array(vec![
                    Value::String("x\"y,z".into()),
                    Value::String("\\".into()),
                ]),
            ),
        ]);
        let text = v.to_toml_string().unwrap();
        assert_eq!(parse_toml(&text).unwrap(), v, "{text}");
    }

    #[test]
    fn f64_bits_hex_roundtrips_every_class() {
        for x in [
            0.0,
            -0.0,
            1.0,
            -1.3e9,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ] {
            let hex = f64_to_bits_hex(x);
            assert_eq!(hex.len(), 16);
            let back = f64_from_bits_hex(&hex).unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} via {hex}");
        }
        assert!(f64_from_bits_hex("").is_err());
        assert!(f64_from_bits_hex("zzzzzzzzzzzzzzzz").is_err());
        assert!(f64_from_bits_hex("3ff").is_err());
        assert!(f64_from_bits_hex("3ff00000000000000").is_err());
    }
}
