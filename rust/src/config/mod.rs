//! Configuration substrate: a small self-contained value model with JSON
//! and TOML-subset parsers (no serde in the offline registry).
//!
//! [`json`] parses `artifacts/manifest.json` (the shape contract emitted
//! by `python/compile/aot.py`). [`toml`] parses the architecture /
//! workload spec files under `configs/`.

pub mod json;
pub mod toml;

pub use json::parse_json;
pub use toml::parse_toml;

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A dynamically-typed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// null / absent.
    Null,
    /// Boolean.
    Bool(bool),
    /// All numbers are kept as f64 (adequate for config use).
    Number(f64),
    /// String.
    String(String),
    /// Ordered list.
    Array(Vec<Value>),
    /// Key-value table (sorted for deterministic output).
    Table(BTreeMap<String, Value>),
}

impl Value {
    /// Navigate a dotted path like `"adc_model.batch"`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for key in path.split('.') {
            match cur {
                Value::Table(map) => cur = map.get(key)?,
                _ => return None,
            }
        }
        Some(cur)
    }

    /// The value as f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as usize, if a non-negative integral number.
    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        if n >= 0.0 && n.fract() == 0.0 { Some(n as usize) } else { None }
    }

    /// The value as &str, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    /// The value as bool, if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as a slice, if an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Required numeric field with a config-error message.
    pub fn require_f64(&self, path: &str) -> Result<f64> {
        self.get(path)
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::Config(format!("missing/non-numeric field `{path}`")))
    }

    /// Required usize field.
    pub fn require_usize(&self, path: &str) -> Result<usize> {
        self.get(path)
            .and_then(Value::as_usize)
            .ok_or_else(|| Error::Config(format!("missing/non-integer field `{path}`")))
    }

    /// Required string field.
    pub fn require_str(&self, path: &str) -> Result<&str> {
        self.get(path)
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Config(format!("missing/non-string field `{path}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(pairs: &[(&str, Value)]) -> Value {
        Value::Table(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
    }

    #[test]
    fn dotted_path_navigation() {
        let v = table(&[("a", table(&[("b", Value::Number(3.0))]))]);
        assert_eq!(v.get("a.b").unwrap().as_f64(), Some(3.0));
        assert!(v.get("a.c").is_none());
        assert!(v.get("x").is_none());
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert_eq!(Value::Number(4.0).as_usize(), Some(4));
        assert_eq!(Value::Number(4.5).as_usize(), None);
        assert_eq!(Value::Number(-1.0).as_usize(), None);
    }

    #[test]
    fn require_errors_mention_path() {
        let v = table(&[]);
        let err = v.require_f64("missing.key").unwrap_err().to_string();
        assert!(err.contains("missing.key"), "{err}");
    }
}
