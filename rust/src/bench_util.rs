//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Provides warm-up, calibrated iteration counts, and robust statistics
//! (median / mean / stddev / min, kept as f64 seconds — per-iteration
//! times can be sub-nanosecond, below `Duration` resolution) with
//! human-readable reporting. Bench targets are `harness = false` binaries
//! that call [`Bench::run`].
//!
//! ## Quick mode
//!
//! Setting `CIMDSE_BENCH_QUICK` (to anything but `0` or empty) shrinks
//! every bench: [`Bench::auto`] / [`Bench::auto_slow`] cut the warm-up /
//! measurement budgets ~10x and the bench binaries use [`scale`] to pick
//! smaller grids. `ci.sh` runs `perf_hotpaths` this way on every run, so
//! the perf trajectory artifact ([`JsonReport`] → `BENCH_sweep.json`)
//! stays fresh without figure-bench runtimes.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::config::Value;
use crate::error::Result;

/// Environment variable that switches all benches to quick mode.
pub const QUICK_ENV: &str = "CIMDSE_BENCH_QUICK";

/// Whether quick mode is active (`CIMDSE_BENCH_QUICK` set, non-empty,
/// and not `0`).
pub fn quick() -> bool {
    std::env::var(QUICK_ENV).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Pick a size knob by mode: `full` normally, `quick_value` under
/// [`quick`]. Bench binaries route every grid/iteration choice through
/// this so quick mode shrinks them all.
pub fn scale(full: usize, quick_value: usize) -> usize {
    if quick() { quick_value } else { full }
}

/// Measurement statistics for one benchmark case (all times in seconds
/// per iteration).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Iterations per sample batch.
    pub iters_per_sample: u64,
    /// Number of sample batches.
    pub samples: usize,
    /// Median time per iteration (seconds).
    pub median_s: f64,
    /// Mean time per iteration (seconds).
    pub mean_s: f64,
    /// Standard deviation of per-sample means (seconds).
    pub stddev_s: f64,
    /// Fastest sample (seconds per iteration).
    pub min_s: f64,
}

impl Stats {
    /// Throughput in iterations/second based on the median.
    pub fn iters_per_sec(&self) -> f64 {
        1.0 / self.median_s
    }
}

/// Format a seconds-per-iteration value with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    /// Wall-clock budget for warm-up.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub measure: Duration,
    /// Number of sample batches to split the measurement into.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(1),
            samples: 20,
        }
    }
}

impl Bench {
    /// Quick preset for slow (>10 ms/iter) cases.
    pub fn slow() -> Self {
        Bench {
            warmup: Duration::from_millis(100),
            measure: Duration::from_secs(2),
            samples: 10,
        }
    }

    /// The default budget, shrunk ~10x when [`quick`] mode is active.
    pub fn auto() -> Self {
        if quick() {
            Bench {
                warmup: Duration::from_millis(30),
                measure: Duration::from_millis(120),
                samples: 6,
            }
        } else {
            Bench::default()
        }
    }

    /// The slow-case budget, shrunk ~10x when [`quick`] mode is active.
    pub fn auto_slow() -> Self {
        if quick() {
            Bench {
                warmup: Duration::from_millis(30),
                measure: Duration::from_millis(250),
                samples: 5,
            }
        } else {
            Bench::slow()
        }
    }

    /// Run `f` repeatedly and return statistics. `f` should include any
    /// per-iteration state internally; use `std::hint::black_box` on
    /// inputs/outputs to defeat const-folding.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // Warm-up and calibration: find iters/sample so one sample ~=
        // measure/samples wall time.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter =
            (warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64).max(1e-12);
        let target_sample = self.measure.as_secs_f64() / self.samples as f64;
        let iters_per_sample = ((target_sample / per_iter) as u64).clamp(1, 1 << 28);

        let mut sample_means: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            sample_means.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        sample_means.sort_by(|a, b| a.total_cmp(b));

        let mean = sample_means.iter().sum::<f64>() / sample_means.len() as f64;
        let var = sample_means
            .iter()
            .map(|m| (m - mean) * (m - mean))
            .sum::<f64>()
            / sample_means.len() as f64;
        let stats = Stats {
            iters_per_sample,
            samples: self.samples,
            median_s: sample_means[sample_means.len() / 2],
            mean_s: mean,
            stddev_s: var.sqrt(),
            min_s: sample_means[0],
        };
        println!(
            "bench {name:<44} median {:>12}  mean {:>12}  sd {:>10}  ({} iters x {} samples)",
            fmt_secs(stats.median_s),
            fmt_secs(stats.mean_s),
            fmt_secs(stats.stddev_s),
            iters_per_sample,
            self.samples
        );
        stats
    }
}

/// Machine-readable bench report, serialized as `BENCH_<name>.json` so
/// every future perf PR has a trajectory to compare against.
///
/// Schema (all numbers f64; `cases.<name>` keys come from
/// [`JsonReport::case`], `derived.<name>` from [`JsonReport::metric`]):
///
/// ```json
/// {
///   "schema": 2,
///   "bench": "sweep",
///   "quick": false,
///   "workers": 8,
///   "tiers": { "exact": "scalar", "fast": "avx2", "simd_feature": true },
///   "cases": {
///     "<case>": {
///       "median_s": 1.1e-3, "mean_s": 1.2e-3, "stddev_s": 1e-5,
///       "min_s": 1.0e-3, "iters_per_sample": 40, "samples": 20,
///       "points": 7776, "mpts_per_s": 7.07
///     }
///   },
///   "derived": { "<metric>": 5.2 }
/// }
/// ```
///
/// Schema 2 added the `tiers` table: which numeric tier each backend
/// resolved to on the measuring host (`exact` is always `"scalar"`;
/// `fast` is `"avx2"` or `"portable"` per
/// [`crate::util::fastmath::fast_backend`]; `simd_feature` records
/// whether the `simd` cargo feature was compiled in). Without it, fast-
/// tier numbers from different hosts are not comparable.
#[derive(Clone, Debug)]
pub struct JsonReport {
    bench: String,
    cases: BTreeMap<String, Value>,
    derived: BTreeMap<String, Value>,
}

impl JsonReport {
    /// Start a report for the named bench.
    pub fn new(bench: &str) -> JsonReport {
        JsonReport { bench: bench.to_string(), cases: BTreeMap::new(), derived: BTreeMap::new() }
    }

    /// Record one measured case; `points` is the work size per iteration
    /// (used to derive Mpoints/s throughput).
    pub fn case(&mut self, name: &str, stats: &Stats, points: usize) {
        let mut t = BTreeMap::new();
        t.insert("median_s".to_string(), Value::Number(stats.median_s));
        t.insert("mean_s".to_string(), Value::Number(stats.mean_s));
        t.insert("stddev_s".to_string(), Value::Number(stats.stddev_s));
        t.insert("min_s".to_string(), Value::Number(stats.min_s));
        t.insert(
            "iters_per_sample".to_string(),
            Value::Number(stats.iters_per_sample as f64),
        );
        t.insert("samples".to_string(), Value::Number(stats.samples as f64));
        t.insert("points".to_string(), Value::Number(points as f64));
        t.insert(
            "mpts_per_s".to_string(),
            Value::Number(points as f64 / stats.median_s / 1e6),
        );
        self.cases.insert(name.to_string(), Value::Table(t));
    }

    /// Record a derived scalar (speedup ratio, scaling factor, ...).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.derived.insert(name.to_string(), Value::Number(value));
    }

    /// The report as a config [`Value`] tree.
    pub fn to_value(&self) -> Value {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Value::Number(2.0));
        root.insert("bench".to_string(), Value::String(self.bench.clone()));
        root.insert("quick".to_string(), Value::Bool(quick()));
        let mut tiers = BTreeMap::new();
        tiers.insert("exact".to_string(), Value::String("scalar".to_string()));
        tiers.insert(
            "fast".to_string(),
            Value::String(crate::util::fastmath::fast_backend().to_string()),
        );
        tiers.insert("simd_feature".to_string(), Value::Bool(cfg!(feature = "simd")));
        root.insert("tiers".to_string(), Value::Table(tiers));
        root.insert(
            "workers".to_string(),
            Value::Number(crate::exec::default_workers() as f64),
        );
        root.insert("cases".to_string(), Value::Table(self.cases.clone()));
        root.insert("derived".to_string(), Value::Table(self.derived.clone()));
        Value::Table(root)
    }

    /// Serialize and write the report (path default: `BENCH_<name>.json`
    /// in the working directory, overridden by `CIMDSE_BENCH_OUT`).
    pub fn write(&self) -> Result<String> {
        let path = std::env::var("CIMDSE_BENCH_OUT")
            .unwrap_or_else(|_| format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.to_value().to_json_string()? + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_busy_loop() {
        let bench = Bench {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            samples: 5,
        };
        let mut acc = 0u64;
        let stats = bench.run("busy", || {
            acc = acc.wrapping_add(std::hint::black_box((0..1000u64).sum::<u64>()));
        });
        std::hint::black_box(acc);
        assert!(stats.median_s > 0.0);
        assert!(stats.min_s <= stats.median_s);
        assert!(stats.iters_per_sample >= 1);
    }

    #[test]
    fn throughput_is_inverse_of_median() {
        let s = Stats {
            iters_per_sample: 1,
            samples: 1,
            median_s: 0.01,
            mean_s: 0.01,
            stddev_s: 0.0,
            min_s: 0.01,
        };
        assert!((s.iters_per_sec() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert_eq!(fmt_secs(2e-3), "2.000 ms");
        assert_eq!(fmt_secs(2e-6), "2.000 µs");
        assert_eq!(fmt_secs(2e-9), "2.0 ns");
    }

    #[test]
    fn json_report_round_trips_and_has_required_keys() {
        let stats = Stats {
            iters_per_sample: 40,
            samples: 20,
            median_s: 1.1e-3,
            mean_s: 1.2e-3,
            stddev_s: 1e-5,
            min_s: 1.0e-3,
        };
        let mut report = JsonReport::new("sweep");
        report.case("sweep: native serial", &stats, 7776);
        report.metric("speedup_prepared_vs_serial", 5.2);
        let text = report.to_value().to_json_string().unwrap();
        let doc = crate::config::parse_json(&text).unwrap();
        assert_eq!(doc.require_usize("schema").unwrap(), 2);
        assert_eq!(doc.require_str("bench").unwrap(), "sweep");
        assert_eq!(doc.require_str("tiers.exact").unwrap(), "scalar");
        let fast = doc.require_str("tiers.fast").unwrap();
        assert!(fast == "avx2" || fast == "portable", "unknown fast backend {fast:?}");
        assert!(doc.get("tiers.simd_feature").is_some());
        assert!(doc.get("cases.sweep: native serial.median_s").is_some());
        let mpts = doc
            .require_f64("cases.sweep: native serial.mpts_per_s")
            .unwrap();
        assert!((mpts - 7776.0 / 1.1e-3 / 1e6).abs() < 1e-9);
        assert_eq!(
            doc.require_f64("derived.speedup_prepared_vs_serial").unwrap(),
            5.2
        );
        assert!(doc.get("workers").is_some() && doc.get("quick").is_some());
    }

    #[test]
    fn scale_picks_by_mode() {
        // The env knob is process-global; just exercise the non-quick
        // branch deterministically when the variable is unset.
        if std::env::var(QUICK_ENV).is_err() {
            assert!(!quick());
            assert_eq!(scale(40, 12), 40);
            assert_eq!(Bench::auto().samples, Bench::default().samples);
        } else {
            assert_eq!(scale(40, 12), if quick() { 12 } else { 40 });
        }
    }
}
