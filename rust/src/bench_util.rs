//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Provides warm-up, calibrated iteration counts, and robust statistics
//! (median / mean / stddev / min, kept as f64 seconds — per-iteration
//! times can be sub-nanosecond, below `Duration` resolution) with
//! human-readable reporting. Bench targets are `harness = false` binaries
//! that call [`Bench::run`].

use std::time::{Duration, Instant};

/// Measurement statistics for one benchmark case (all times in seconds
/// per iteration).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Iterations per sample batch.
    pub iters_per_sample: u64,
    /// Number of sample batches.
    pub samples: usize,
    /// Median time per iteration (seconds).
    pub median_s: f64,
    /// Mean time per iteration (seconds).
    pub mean_s: f64,
    /// Standard deviation of per-sample means (seconds).
    pub stddev_s: f64,
    /// Fastest sample (seconds per iteration).
    pub min_s: f64,
}

impl Stats {
    /// Throughput in iterations/second based on the median.
    pub fn iters_per_sec(&self) -> f64 {
        1.0 / self.median_s
    }
}

/// Format a seconds-per-iteration value with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    /// Wall-clock budget for warm-up.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub measure: Duration,
    /// Number of sample batches to split the measurement into.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(1),
            samples: 20,
        }
    }
}

impl Bench {
    /// Quick preset for slow (>10 ms/iter) cases.
    pub fn slow() -> Self {
        Bench {
            warmup: Duration::from_millis(100),
            measure: Duration::from_secs(2),
            samples: 10,
        }
    }

    /// Run `f` repeatedly and return statistics. `f` should include any
    /// per-iteration state internally; use `std::hint::black_box` on
    /// inputs/outputs to defeat const-folding.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // Warm-up and calibration: find iters/sample so one sample ~=
        // measure/samples wall time.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter =
            (warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64).max(1e-12);
        let target_sample = self.measure.as_secs_f64() / self.samples as f64;
        let iters_per_sample = ((target_sample / per_iter) as u64).clamp(1, 1 << 28);

        let mut sample_means: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            sample_means.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        sample_means.sort_by(|a, b| a.total_cmp(b));

        let mean = sample_means.iter().sum::<f64>() / sample_means.len() as f64;
        let var = sample_means
            .iter()
            .map(|m| (m - mean) * (m - mean))
            .sum::<f64>()
            / sample_means.len() as f64;
        let stats = Stats {
            iters_per_sample,
            samples: self.samples,
            median_s: sample_means[sample_means.len() / 2],
            mean_s: mean,
            stddev_s: var.sqrt(),
            min_s: sample_means[0],
        };
        println!(
            "bench {name:<44} median {:>12}  mean {:>12}  sd {:>10}  ({} iters x {} samples)",
            fmt_secs(stats.median_s),
            fmt_secs(stats.mean_s),
            fmt_secs(stats.stddev_s),
            iters_per_sample,
            self.samples
        );
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_busy_loop() {
        let bench = Bench {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            samples: 5,
        };
        let mut acc = 0u64;
        let stats = bench.run("busy", || {
            acc = acc.wrapping_add(std::hint::black_box((0..1000u64).sum::<u64>()));
        });
        std::hint::black_box(acc);
        assert!(stats.median_s > 0.0);
        assert!(stats.min_s <= stats.median_s);
        assert!(stats.iters_per_sample >= 1);
    }

    #[test]
    fn throughput_is_inverse_of_median() {
        let s = Stats {
            iters_per_sample: 1,
            samples: 1,
            median_s: 0.01,
            mean_s: 0.01,
            stddev_s: 0.0,
            min_s: 0.01,
        };
        assert!((s.iters_per_sec() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert_eq!(fmt_secs(2e-3), "2.000 ms");
        assert_eq!(fmt_secs(2e-6), "2.000 µs");
        assert_eq!(fmt_secs(2e-9), "2.0 ns");
    }
}
