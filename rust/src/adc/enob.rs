//! ENOB / SNDR semantics (paper §II: "ADC resolution measured as the
//! effective number of bits (ENOB), which measures effective ADC
//! resolution after considering nonidealities such as noise and
//! nonlinearity").
//!
//! Conversions between ENOB, SNDR, and quantization noise, plus the
//! composition rules the functional simulation uses to translate a
//! measured SQNR into "effective bits" and to budget how much ENOB a
//! given analog sum size actually needs.

/// SNDR (dB) of an ideal `bits`-bit quantizer driven at full scale:
/// `6.02·bits + 1.76`.
pub fn ideal_sndr_db(bits: f64) -> f64 {
    6.02 * bits + 1.76
}

/// ENOB implied by a measured SNDR (dB): the inverse of [`ideal_sndr_db`].
pub fn enob_from_sndr_db(sndr_db: f64) -> f64 {
    (sndr_db - 1.76) / 6.02
}

/// Combine independent noise sources given as SNDRs (dB) against the same
/// signal: noise powers add.
///
/// Total on every input (the compute-SNR metric feeds it request-derived
/// values, so panics are not an option):
/// - an empty slice is the zero-noise identity and returns `+∞` dB;
/// - a `+∞` dB source contributes zero noise power (same identity);
/// - a `−∞` dB source (infinite noise) forces `−∞` dB out;
/// - NaN propagates to a NaN result.
pub fn combine_sndr_db(sndrs_db: &[f64]) -> f64 {
    let total_noise: f64 = sndrs_db.iter().map(|s| 10f64.powf(-s / 10.0)).sum();
    -10.0 * total_noise.log10()
}

/// `2^k` as an `f64`, total for any `k`. For `k < 64` this is the integer
/// shift `(1u64 << k) as f64` (bit-identical to the pre-existing shift
/// path); for `64 <= k <= 1023` the power of two is bit-constructed from
/// the IEEE-754 exponent field (still exact — every such power is
/// representable); beyond 1023 it saturates to `+∞`, where `2^k`
/// overflows f64 anyway. No libm call, so results are identical on every
/// host. This replaces the raw `1u64 << bits` idiom, which panics in
/// debug / wraps in release once a user-supplied bit count reaches 64.
pub fn pow2_f64(k: u32) -> f64 {
    if k < 64 {
        (1u64 << k) as f64
    } else if k <= 1023 {
        f64::from_bits((1023u64 + k as u64) << 52)
    } else {
        f64::INFINITY
    }
}

/// Bits needed to read an analog sum of `n_sum` values stored in
/// `cell_bits`-bit cells losslessly: `log2(n_sum · (2^cell_bits - 1) + 1)`.
///
/// Total for any input: an empty sum needs no bits (one level), and for
/// `cell_bits >= 1024` the per-cell level count saturates to `+∞`
/// ([`pow2_f64`]), so the result is `+∞` rather than a panic or a wrapped
/// shift.
pub fn lossless_bits(n_sum: usize, cell_bits: u32) -> f64 {
    if n_sum == 0 {
        return 0.0;
    }
    ((n_sum as f64) * (pow2_f64(cell_bits) - 1.0) + 1.0).log2()
}

/// Effective resolution degradation (in bits) when an ADC with
/// `adc_bits` reads a sum that needs [`lossless_bits`]: the clipped /
/// truncated bits the architecture must recover digitally (RAELLA-style
/// speculation) or absorb as error.
pub fn clipped_bits(n_sum: usize, cell_bits: u32, adc_bits: f64) -> f64 {
    (lossless_bits(n_sum, cell_bits) - adc_bits).max(0.0)
}

/// Expected SQNR (dB) of reading a full-scale column sum through a
/// uniform quantizer with `adc_bits`: `6.02·min(adc_bits, lossless) +
/// 1.76`. Each bit the ADC is short of lossless doubles the quantization
/// step (−6.02 dB) — the fidelity the functional sim converges to for
/// large random workloads (EXPERIMENTS.md's ~12 dB per 2 ADC bits).
pub fn expected_read_sqnr_db(n_sum: usize, cell_bits: u32, adc_bits: f64) -> f64 {
    ideal_sndr_db(adc_bits.min(lossless_bits(n_sum, cell_bits)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sndr_enob_roundtrip() {
        for bits in [4.0, 6.5, 8.0, 12.0] {
            let sndr = ideal_sndr_db(bits);
            assert!((enob_from_sndr_db(sndr) - bits).abs() < 1e-12);
        }
        // The canonical anchor: 8 bits ~ 49.9 dB.
        assert!((ideal_sndr_db(8.0) - 49.92).abs() < 0.01);
    }

    #[test]
    fn combining_equal_sources_costs_half_a_bit() {
        // Two equal independent noise sources: +3 dB noise = -0.5 ENOB.
        let combined = combine_sndr_db(&[50.0, 50.0]);
        assert!((combined - (50.0 - 10.0 * 2f64.log10())).abs() < 1e-9);
        let enob_drop = enob_from_sndr_db(50.0) - enob_from_sndr_db(combined);
        assert!((enob_drop - 0.5).abs() < 0.001, "{enob_drop}");
    }

    #[test]
    fn combining_with_much_better_source_is_noop() {
        let combined = combine_sndr_db(&[50.0, 110.0]);
        assert!((combined - 50.0).abs() < 0.01);
    }

    #[test]
    fn combine_is_total_on_degenerate_inputs() {
        // Empty slice: the zero-noise identity, not a panic.
        assert_eq!(combine_sndr_db(&[]), f64::INFINITY);
        // A +inf source is the same identity element.
        assert_eq!(combine_sndr_db(&[f64::INFINITY]), f64::INFINITY);
        assert_eq!(combine_sndr_db(&[50.0, f64::INFINITY]).to_bits(), combine_sndr_db(&[50.0]).to_bits());
        // A -inf source (infinite noise) dominates everything.
        assert_eq!(combine_sndr_db(&[50.0, f64::NEG_INFINITY]), f64::NEG_INFINITY);
        // NaN propagates instead of silently poisoning downstream math.
        assert!(combine_sndr_db(&[f64::NAN]).is_nan());
        assert!(combine_sndr_db(&[50.0, f64::NAN]).is_nan());
    }

    #[test]
    fn pow2_is_exact_and_saturating() {
        // Below 64: bit-identical to the integer-shift path.
        for k in [0u32, 1, 2, 10, 52, 53, 63] {
            assert_eq!(pow2_f64(k).to_bits(), ((1u64 << k) as f64).to_bits(), "k={k}");
        }
        // 64..=1023: exact powers of two, monotone, no panic.
        assert_eq!(pow2_f64(64), 2f64.powi(64));
        assert_eq!(pow2_f64(100), 2f64.powi(100));
        assert_eq!(pow2_f64(1023), 2f64.powi(1023));
        // Beyond the f64 exponent range: saturate, never wrap.
        assert_eq!(pow2_f64(1024), f64::INFINITY);
        assert_eq!(pow2_f64(u32::MAX), f64::INFINITY);
    }

    #[test]
    fn lossless_bits_is_total_for_huge_cell_bits() {
        // The old `1u64 << cell_bits` panicked (debug) / wrapped (release)
        // from 64 up; now the level count saturates cleanly.
        assert!(lossless_bits(128, 64).is_finite());
        assert!((lossless_bits(128, 64) - (128.0 * 2f64.powi(64)).log2()).abs() < 1e-9);
        assert!(lossless_bits(128, 1023).is_finite());
        assert_eq!(lossless_bits(128, 1024), f64::INFINITY);
        assert_eq!(lossless_bits(1, u32::MAX), f64::INFINITY);
        // An empty sum needs no bits, regardless of cell width.
        assert_eq!(lossless_bits(0, 2), 0.0);
        assert_eq!(lossless_bits(0, 5000), 0.0);
        // And clipped_bits stays total on the same inputs.
        assert_eq!(clipped_bits(1, u32::MAX, 8.0), f64::INFINITY);
        assert_eq!(clipped_bits(0, 5000, 8.0), 0.0);
    }

    #[test]
    fn lossless_bits_matches_arch() {
        use crate::arch::raella::{RaellaVariant, raella};
        for v in RaellaVariant::ALL {
            let arch = raella(v);
            assert!(
                (lossless_bits(arch.sum_size, arch.cell_bits) - arch.lossless_enob()).abs()
                    < 1e-12
            );
        }
        // RAELLA-S: 128 x 3 + 1 = 385 levels ~ 8.59 bits.
        assert!((lossless_bits(128, 2) - 385f64.log2()).abs() < 1e-12);
    }

    #[test]
    fn clipped_bits_grow_with_sum_at_fixed_adc() {
        let c128 = clipped_bits(128, 2, 6.0);
        let c512 = clipped_bits(512, 2, 6.0);
        assert!(c512 > c128);
        // An over-provisioned ADC clips nothing.
        assert_eq!(clipped_bits(16, 2, 12.0), 0.0);
    }

    #[test]
    fn raella_variants_clip_progressively_more() {
        // S/M/L/XL trade +2 lossless bits per step for +1 ADC bit: the
        // clipped-bit budget grows ~1 bit per step (the speculation debt).
        use crate::arch::raella::{RaellaVariant, raella};
        let clips: Vec<f64> = RaellaVariant::ALL
            .iter()
            .map(|&v| {
                let a = raella(v);
                clipped_bits(a.sum_size, a.cell_bits, a.adc.enob)
            })
            .collect();
        for w in clips.windows(2) {
            // ~1.0 bit per step (the +1 level in lossless_bits keeps it
            // from being exact).
            assert!((w[1] - w[0] - 1.0).abs() < 0.01, "{clips:?}");
        }
    }
}
