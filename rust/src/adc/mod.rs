//! The paper's contribution: the architecture-level ADC energy/area model.
//!
//! Given four architecture-level attributes — number of ADCs, total
//! throughput, technology node, and resolution (ENOB) — [`AdcModel`]
//! estimates best-case per-convert energy (two-bound piecewise power law,
//! §II-A) and per-ADC area (Eq. 1 with lowest-10% calibration, §II-B).
//!
//! The model is obtained either from the built-in defaults
//! ([`AdcModel::default`]), from a survey fit ([`fit::fit_model`]), or by
//! tuning an existing model to a known ADC design point
//! ([`AdcModel::tuned_to`], §II "users may tune...").

pub mod coeffs;
pub mod enob;
pub mod fit;
pub mod plugin;
pub mod prepared;
pub mod tuning;

pub use coeffs::Coefficients;
pub use fit::{FitReport, fit_model};
pub use plugin::Estimator;
pub use prepared::{PreparedModel, PreparedRow, PreparedRowLanes};
pub use tuning::TuningPoint;

use crate::util::logspace::{log10, pow10};

/// Architecture-level query: the model's four inputs (paper Fig. 1).
///
/// `Default` is the all-zero query — an invalid placeholder (it fails
/// [`AdcQuery::validate`]) used only to pre-fill output buffers that
/// workers overwrite in place.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AdcQuery {
    /// Effective number of bits (resolution after nonidealities).
    pub enob: f64,
    /// Aggregate converts/second across all ADCs.
    pub total_throughput: f64,
    /// Technology node in nanometers.
    pub tech_nm: f64,
    /// Number of ADCs operating in parallel.
    pub n_adcs: u32,
}

impl AdcQuery {
    /// Per-ADC throughput (total / n).
    pub fn throughput_per_adc(&self) -> f64 {
        self.total_throughput / self.n_adcs as f64
    }

    /// Validate physical ranges; the model extrapolates, but garbage
    /// queries (non-positive values) are caller bugs.
    pub fn validate(&self) -> crate::Result<()> {
        if !(self.enob > 0.0 && self.enob < 24.0) {
            return Err(crate::Error::Numeric(format!("ENOB {} out of range", self.enob)));
        }
        if !(self.total_throughput > 0.0) {
            return Err(crate::Error::Numeric("non-positive throughput".into()));
        }
        if !(self.tech_nm >= 1.0 && self.tech_nm <= 1000.0) {
            return Err(crate::Error::Numeric(format!("tech {}nm out of range", self.tech_nm)));
        }
        if self.n_adcs == 0 {
            return Err(crate::Error::Numeric("n_adcs must be >= 1".into()));
        }
        Ok(())
    }
}

/// Model outputs for one query.
///
/// `Default` is the all-zero record — a placeholder the sweep engine
/// pre-fills output buffers with so workers can overwrite disjoint slices
/// in place (see `exec::Pool::fill_with`), never a meaningful result.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AdcMetrics {
    /// Energy per convert, picojoules.
    pub energy_pj_per_convert: f64,
    /// Area of one ADC, square micrometers.
    pub area_um2_per_adc: f64,
    /// Aggregate power across all ADCs, watts.
    pub total_power_w: f64,
    /// Aggregate area across all ADCs, square micrometers.
    pub total_area_um2: f64,
}

impl AdcMetrics {
    /// The four metrics as raw IEEE-754 bit patterns, in field order —
    /// the comparison key for the *bit-identity* contract between
    /// [`AdcModel::eval`] and the prepared sweep kernel (equality here is
    /// stricter than `==`, which would accept e.g. `0.0 == -0.0`).
    pub fn to_bits(&self) -> [u64; 4] {
        [
            self.energy_pj_per_convert.to_bits(),
            self.area_um2_per_adc.to_bits(),
            self.total_power_w.to_bits(),
            self.total_area_um2.to_bits(),
        ]
    }
}

/// The ADC energy/area model: fitted coefficients plus optional user tuning.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdcModel {
    /// The fitted coefficient set.
    pub coefs: Coefficients,
    /// Additive log10-energy offset from user tuning (0 = untuned).
    pub energy_offset_decades: f64,
    /// Additive log10-area offset from user tuning (0 = untuned).
    pub area_offset_decades: f64,
}

impl Default for AdcModel {
    /// Model with the built-in default coefficients (the generator truth —
    /// i.e. what a fit of the synthetic survey converges to).
    fn default() -> Self {
        AdcModel::new(Coefficients::generator_truth())
    }
}

impl AdcModel {
    /// Model from a coefficient set with no user tuning.
    pub fn new(coefs: Coefficients) -> Self {
        AdcModel { coefs, energy_offset_decades: 0.0, area_offset_decades: 0.0 }
    }

    /// Energy per convert in picojoules for a query.
    pub fn energy_pj_per_convert(&self, q: &AdcQuery) -> f64 {
        let log_t = log10(q.tech_nm / 32.0);
        let log_f = log10(q.throughput_per_adc());
        pow10(self.coefs.log_energy_pj(q.enob, log_t, log_f) + self.energy_offset_decades)
    }

    /// Area of one ADC in µm² for a query (Eq. 1; depends on energy).
    pub fn area_um2_per_adc(&self, q: &AdcQuery) -> f64 {
        let log_t = log10(q.tech_nm / 32.0);
        let log_f = log10(q.throughput_per_adc());
        let log_e =
            self.coefs.log_energy_pj(q.enob, log_t, log_f) + self.energy_offset_decades;
        pow10(self.coefs.log_area_um2(log_t, log_f, log_e) + self.area_offset_decades)
    }

    /// Full metric set for a query.
    ///
    /// Computes the shared log-space terms once (the separate
    /// `energy_pj_per_convert` / `area_um2_per_adc` entry points each
    /// re-derive them; this fused path is what the DSE hot loop calls —
    /// see EXPERIMENTS.md §Perf).
    pub fn eval(&self, q: &AdcQuery) -> AdcMetrics {
        let log_t = log10(q.tech_nm / 32.0);
        let log_f = log10(q.throughput_per_adc());
        let log_e =
            self.coefs.log_energy_pj(q.enob, log_t, log_f) + self.energy_offset_decades;
        let log_area = self.coefs.log_area_um2(log_t, log_f, log_e) + self.area_offset_decades;
        let energy_pj = pow10(log_e);
        let area = pow10(log_area);
        AdcMetrics {
            energy_pj_per_convert: energy_pj,
            area_um2_per_adc: area,
            total_power_w: energy_pj * 1e-12 * q.total_throughput,
            total_area_um2: area * q.n_adcs as f64,
        }
    }

    /// Throughput (converts/s) at which the tradeoff bound overtakes the
    /// minimum-energy bound for this (enob, tech) — the knee in Fig. 2.
    pub fn crossover_throughput(&self, enob: f64, tech_nm: f64) -> f64 {
        let c = &self.coefs;
        let log_t = log10(tech_nm / 32.0);
        let num = (c.a0 + c.a1 * enob + c.a2 * log_t) - (c.b0 + c.b1 * enob + c.b2 * log_t);
        pow10(num / c.b3)
    }

    /// Coefficients with the tuning offsets folded in: the energy offset
    /// shifts both bound intercepts and the area offset shifts d0. The
    /// folded set evaluates identically to this model, which is how tuned
    /// models ride through the AOT artifact (it only takes coefficients).
    pub fn folded_coefficients(&self) -> Coefficients {
        Coefficients {
            a0: self.coefs.a0 + self.energy_offset_decades,
            b0: self.coefs.b0 + self.energy_offset_decades,
            // Area reads log E *with* the energy offset already applied via
            // the shifted intercepts, so only the explicit area offset
            // remains to fold into d0.
            d0: self.coefs.d0 + self.area_offset_decades,
            ..self.coefs
        }
    }

    /// Tune the model so it reproduces a known ADC design point exactly
    /// (paper §II: "users may tune the tool's estimated area and energy to
    /// match that of the ADC of interest"), preserving all trends for
    /// interpolation around that point.
    pub fn tuned_to(&self, point: &tuning::TuningPoint) -> AdcModel {
        tuning::tune(self, point)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(enob: f64, total: f64, tech: f64, n: u32) -> AdcQuery {
        AdcQuery { enob, total_throughput: total, tech_nm: tech, n_adcs: n }
    }

    #[test]
    fn eval_matches_components() {
        let m = AdcModel::default();
        let query = q(8.0, 2e9, 32.0, 4);
        let metrics = m.eval(&query);
        assert!((metrics.energy_pj_per_convert - m.energy_pj_per_convert(&query)).abs() < 1e-12);
        assert!((metrics.total_area_um2 - 4.0 * metrics.area_um2_per_adc).abs() < 1e-9);
        let expect_power = metrics.energy_pj_per_convert * 1e-12 * 2e9;
        assert!((metrics.total_power_w - expect_power).abs() / expect_power < 1e-12);
    }

    #[test]
    fn more_adcs_at_fixed_total_never_raise_energy() {
        let m = AdcModel::default();
        let mut prev = f64::MAX;
        for n in [1u32, 2, 4, 8, 16] {
            let e = m.energy_pj_per_convert(&q(7.0, 1.3e9, 32.0, n));
            assert!(e <= prev + 1e-15, "n={n}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn crossover_matches_bound_equality() {
        let m = AdcModel::default();
        for enob in [4.0, 8.0, 12.0] {
            let f = m.crossover_throughput(enob, 32.0);
            let lo = m.energy_pj_per_convert(&q(enob, f * 0.99, 32.0, 1));
            let hi = m.energy_pj_per_convert(&q(enob, f * 1.01, 32.0, 1));
            let flat = m.energy_pj_per_convert(&q(enob, f * 0.01, 32.0, 1));
            assert!((lo - flat).abs() / flat < 1e-6, "below knee should be flat");
            assert!(hi > lo, "above knee must rise");
        }
    }

    #[test]
    fn crossover_decreases_with_enob() {
        let m = AdcModel::default();
        assert!(
            m.crossover_throughput(12.0, 32.0) < m.crossover_throughput(8.0, 32.0)
        );
        assert!(
            m.crossover_throughput(8.0, 32.0) < m.crossover_throughput(4.0, 32.0)
        );
    }

    #[test]
    fn smaller_node_is_cheaper() {
        let m = AdcModel::default();
        let e16 = m.energy_pj_per_convert(&q(8.0, 1e8, 16.0, 1));
        let e65 = m.energy_pj_per_convert(&q(8.0, 1e8, 65.0, 1));
        assert!(e16 < e65);
        let a16 = m.area_um2_per_adc(&q(8.0, 1e8, 16.0, 1));
        let a65 = m.area_um2_per_adc(&q(8.0, 1e8, 65.0, 1));
        assert!(a16 < a65);
    }

    #[test]
    fn validate_rejects_garbage() {
        assert!(q(0.0, 1e9, 32.0, 1).validate().is_err());
        assert!(q(8.0, -1.0, 32.0, 1).validate().is_err());
        assert!(q(8.0, 1e9, 0.5, 1).validate().is_err());
        assert!(q(8.0, 1e9, 32.0, 0).validate().is_err());
        assert!(q(8.0, 1e9, 32.0, 1).validate().is_ok());
    }

    #[test]
    fn per_adc_throughput() {
        assert_eq!(q(8.0, 4e9, 32.0, 4).throughput_per_adc(), 1e9);
    }

    #[test]
    fn folded_coefficients_reproduce_tuned_model() {
        let point = tuning::TuningPoint {
            query: q(7.0, 1e9, 32.0, 1),
            energy_pj_per_convert: 3.3,
            area_um2: Some(5e4),
        };
        let tuned = AdcModel::default().tuned_to(&point);
        let folded = AdcModel::new(tuned.folded_coefficients());
        for query in [q(5.0, 1e8, 65.0, 2), q(9.0, 1e10, 16.0, 8), point.query] {
            let et = tuned.energy_pj_per_convert(&query);
            let ef = folded.energy_pj_per_convert(&query);
            assert!((et - ef).abs() / et < 1e-12);
            let at = tuned.area_um2_per_adc(&query);
            let af = folded.area_um2_per_adc(&query);
            assert!((at - af).abs() / at < 1e-12);
        }
    }
}
