//! The survey-fit pipeline (paper Fig. 1): survey → regression →
//! [`Coefficients`].
//!
//! Energy: two-bound envelope fit ([`crate::stats::piecewise`]) at the 5%
//! residual quantile (best-case bounds). Area: log-space OLS of Eq. 1's
//! form on (tech, throughput, energy), then the paper's "optimistically
//! reduce the estimated area to match the lowest-area 10% of ADCs"
//! — an intercept shift to the 10% residual quantile.

use crate::error::Result;
use crate::stats::corr::pearson_r;
use crate::stats::ols::ols;
use crate::stats::piecewise::{EnergyPoint, TwoBoundFit, fit_two_bound_envelope};
use crate::stats::quantile::envelope_shift;
use crate::survey::SurveyDataset;
use crate::util::logspace::log10;

use super::Coefficients;

/// Residual quantile for the best-case energy envelope.
pub const ENERGY_ENVELOPE_Q: f64 = 0.05;
/// Residual quantile for the area calibration (paper: lowest 10%).
pub const AREA_ENVELOPE_Q: f64 = 0.10;

/// Everything the fit pipeline produces, for reporting and tests.
#[derive(Clone, Debug)]
pub struct FitReport {
    /// The fitted coefficient set (consumed by [`super::AdcModel`]).
    pub coefs: Coefficients,
    /// The raw two-bound energy fit.
    pub energy_fit: TwoBoundFit,
    /// Pearson r of the area regression using *energy* as a predictor
    /// (the paper's improved model, r ≈ 0.75).
    pub area_r_energy: f64,
    /// Pearson r of the area regression using *ENOB* instead
    /// (the prior-work baseline, r ≈ 0.66).
    pub area_r_enob: f64,
    /// R² of the area regression (energy form) in log space.
    pub area_r2: f64,
    /// Number of survey records used.
    pub n_records: usize,
}

/// Fit the full model to a survey.
pub fn fit_model(survey: &SurveyDataset) -> Result<FitReport> {
    let energy_points: Vec<EnergyPoint> = survey
        .records
        .iter()
        .map(|r| EnergyPoint {
            enob: r.enob,
            log_t: r.log_tech_ratio(),
            log_f: log10(r.throughput),
            log_e: log10(r.energy_pj),
        })
        .collect();
    let energy_fit = fit_two_bound_envelope(&energy_points, ENERGY_ENVELOPE_Q)?;

    // --- Area regression: log A ~ log T + log f + log E  (paper's form) ---
    let xs_energy: Vec<Vec<f64>> = survey
        .records
        .iter()
        .map(|r| vec![r.log_tech_ratio(), log10(r.throughput), log10(r.energy_pj)])
        .collect();
    let log_area: Vec<f64> = survey.records.iter().map(|r| log10(r.area_um2)).collect();
    let area_fit = ols(&xs_energy, &log_area)?;

    // Pearson r of predicted-vs-observed log area, energy form.
    let pred_energy: Vec<f64> = xs_energy.iter().map(|x| area_fit.predict(x)).collect();
    let area_r_energy = pearson_r(&log_area, &pred_energy);

    // Prior-work baseline: ENOB in place of energy (r should be lower —
    // the paper's 0.66 -> 0.75 comparison).
    let xs_enob: Vec<Vec<f64>> = survey
        .records
        .iter()
        .map(|r| vec![r.log_tech_ratio(), log10(r.throughput), r.enob])
        .collect();
    let enob_fit = ols(&xs_enob, &log_area)?;
    let pred_enob: Vec<f64> = xs_enob.iter().map(|x| enob_fit.predict(x)).collect();
    let area_r_enob = pearson_r(&log_area, &pred_enob);

    // p10 calibration: shift the intercept to the lowest-area-10% envelope.
    let d0 = area_fit.coefs[0] + envelope_shift(&area_fit.residuals, AREA_ENVELOPE_Q);

    let coefs = Coefficients {
        a0: energy_fit.flat[0],
        a1: energy_fit.flat[1],
        a2: energy_fit.flat[2],
        b0: energy_fit.trade[0],
        b1: energy_fit.trade[1],
        b2: energy_fit.trade[2],
        b3: energy_fit.trade[3],
        d0,
        d1: area_fit.coefs[1],
        d2: area_fit.coefs[2],
        d3: area_fit.coefs[3],
    };

    Ok(FitReport {
        coefs,
        energy_fit,
        area_r_energy,
        area_r_enob,
        area_r2: area_fit.r2,
        n_records: survey.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::survey::generator::{SurveyConfig, generate_survey};

    fn fit() -> FitReport {
        fit_model(&generate_survey(&SurveyConfig::default())).unwrap()
    }

    #[test]
    fn recovers_generator_truth_slopes() {
        let truth = Coefficients::generator_truth();
        let report = fit();
        let c = report.coefs;
        assert!((c.a1 - truth.a1).abs() < 0.05, "a1={} vs {}", c.a1, truth.a1);
        assert!((c.a2 - truth.a2).abs() < 0.15, "a2={}", c.a2);
        assert!((c.b3 - truth.b3).abs() < 0.25, "b3={}", c.b3);
        assert!((c.d1 - truth.d1).abs() < 0.1, "d1={}", c.d1);
        assert!((c.d2 - truth.d2).abs() < 0.05, "d2={}", c.d2);
        assert!((c.d3 - truth.d3).abs() < 0.05, "d3={}", c.d3);
        // Calibrated intercept lands near the truth's kappa-adjusted d0.
        assert!((c.d0 - truth.d0).abs() < 0.15, "d0={} vs {}", c.d0, truth.d0);
    }

    #[test]
    fn energy_predictor_beats_enob_predictor() {
        // The paper's §II-B observation: r improves when energy replaces
        // ENOB in the area regression (0.66 -> 0.75 on the real survey).
        let report = fit();
        assert!(
            report.area_r_energy > report.area_r_enob,
            "r_energy={} <= r_enob={}",
            report.area_r_energy,
            report.area_r_enob
        );
        assert!(report.area_r_energy > 0.6, "r_energy={}", report.area_r_energy);
    }

    #[test]
    fn fitted_model_is_a_lower_envelope() {
        let survey = generate_survey(&SurveyConfig::default());
        let report = fit_model(&survey).unwrap();
        let below = survey
            .records
            .iter()
            .filter(|r| {
                let le = report.coefs.log_energy_pj(
                    r.enob,
                    r.log_tech_ratio(),
                    log10(r.throughput),
                );
                log10(r.energy_pj) < le
            })
            .count();
        let frac = below as f64 / survey.len() as f64;
        assert!(frac <= 0.10, "below-envelope fraction {frac}");
    }

    #[test]
    fn crossover_structure_preserved() {
        // b1 > a1 must survive the fit (the paper's "tradeoff bound kicks
        // in earlier at high ENOB" requires it).
        let c = fit().coefs;
        assert!(c.b1 > c.a1, "b1={} a1={}", c.b1, c.a1);
        assert!(c.b3 > 0.5, "b3={}", c.b3);
    }
}
