//! User tuning: calibrate the model to a specific published ADC.
//!
//! Paper §II: "To model a particular ADC, users may tune the tool's
//! estimated area and energy to match that of the ADC of interest. Users
//! may then use the tool to estimate how the area and energy of that ADC
//! would change given a change in throughput, ENOB, or technology node."
//!
//! Tuning is a pair of additive log10 offsets (multiplicative factors on
//! energy and area) chosen so the model passes exactly through the
//! reference design point while preserving every slope for interpolation.

use crate::util::logspace::log10;

use super::{AdcModel, AdcQuery};

/// A known ADC design point to tune to.
#[derive(Clone, Copy, Debug)]
pub struct TuningPoint {
    /// The architecture-level query describing the reference ADC.
    pub query: AdcQuery,
    /// Its published energy per convert (picojoules).
    pub energy_pj_per_convert: f64,
    /// Its published per-ADC area (µm²). `None` tunes energy only.
    pub area_um2: Option<f64>,
}

/// Produce a tuned copy of `model` passing through `point` exactly.
pub fn tune(model: &AdcModel, point: &TuningPoint) -> AdcModel {
    let base_e = model.energy_pj_per_convert(&point.query);
    let mut tuned = *model;
    tuned.energy_offset_decades += log10(point.energy_pj_per_convert) - log10(base_e);

    if let Some(area) = point.area_um2 {
        // Area depends on energy through d3·log E; tune area *after* the
        // energy offset is applied so the net model hits the point exactly.
        let base_a = tuned.area_um2_per_adc(&point.query);
        tuned.area_offset_decades += log10(area) - log10(base_a);
    }
    tuned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adc::AdcQuery;

    fn reference() -> TuningPoint {
        TuningPoint {
            query: AdcQuery {
                enob: 7.0,
                total_throughput: 1e9,
                tech_nm: 32.0,
                n_adcs: 1,
            },
            energy_pj_per_convert: 2.5,
            area_um2: Some(4.2e4),
        }
    }

    #[test]
    fn tuned_model_hits_the_point_exactly() {
        let tuned = AdcModel::default().tuned_to(&reference());
        let p = reference();
        let e = tuned.energy_pj_per_convert(&p.query);
        let a = tuned.area_um2_per_adc(&p.query);
        assert!((e - 2.5).abs() / 2.5 < 1e-9, "energy {e}");
        assert!((a - 4.2e4).abs() / 4.2e4 < 1e-9, "area {a}");
    }

    #[test]
    fn tuning_preserves_trends() {
        let base = AdcModel::default();
        let tuned = base.tuned_to(&reference());
        // Ratios against the untuned model are constant across queries:
        // slopes (trends) are untouched.
        let q1 = AdcQuery { enob: 6.0, total_throughput: 1e8, tech_nm: 65.0, n_adcs: 2 };
        let q2 = AdcQuery { enob: 10.0, total_throughput: 4e9, tech_nm: 16.0, n_adcs: 8 };
        let r1 = tuned.energy_pj_per_convert(&q1) / base.energy_pj_per_convert(&q1);
        let r2 = tuned.energy_pj_per_convert(&q2) / base.energy_pj_per_convert(&q2);
        assert!((r1 - r2).abs() / r1 < 1e-9, "{r1} vs {r2}");
    }

    #[test]
    fn energy_only_tuning_leaves_area_offset_partially_coupled() {
        // Tuning energy alone still moves area through Eq. 1's E^d3 term —
        // that is physical (lower-energy designs are smaller) and the
        // paper's rationale for using energy in the area model.
        let base = AdcModel::default();
        let point = TuningPoint { area_um2: None, ..reference() };
        let tuned = base.tuned_to(&point);
        let q = reference().query;
        let base_e = base.energy_pj_per_convert(&q);
        assert!(point.energy_pj_per_convert > base_e, "fixture: tune upward");
        assert!(tuned.area_um2_per_adc(&q) > base.area_um2_per_adc(&q));
        assert_eq!(tuned.area_offset_decades, 0.0);
    }

    #[test]
    fn interpolation_around_tuned_point_follows_model_shape() {
        let tuned = AdcModel::default().tuned_to(&reference());
        // Doubling throughput above the knee raises energy by ~2^b3.
        let q = AdcQuery { enob: 7.0, total_throughput: 8e9, tech_nm: 32.0, n_adcs: 1 };
        let q2 = AdcQuery { total_throughput: 16e9, ..q };
        let ratio = tuned.energy_pj_per_convert(&q2) / tuned.energy_pj_per_convert(&q);
        let b3 = tuned.coefs.b3;
        assert!((ratio - 2f64.powf(b3)).abs() / ratio < 1e-9);
    }
}
