//! Invariant-hoisted ADC model kernel for sweep hot loops.
//!
//! [`AdcModel::eval`] recomputes, for every design point, quantities that
//! are constant along a sweep's throughput axis: `log10(tech_nm/32)`, the
//! per-ENOB coefficient partials `a0 + a1·enob + a2·log_t` and
//! `b0 + b1·enob + b2·log_t`, the area partial `d0 + d1·log_t`, and the
//! tuning offsets. [`PreparedModel::row`] hoists all of them into a
//! [`PreparedRow`], reducing the per-point cost to a few multiply-adds
//! plus the two unavoidable `pow10` calls — and, when the caller already
//! knows the log-domain throughput (log-spaced axes do; see
//! [`crate::dse::sweep::SweepSpec`]), zero `log10` calls in the inner
//! loop.
//!
//! ## Bitwise equivalence
//!
//! The hoisted expressions keep the *exact* operation order and
//! association of [`AdcModel::eval`] (each partial is a left-associated
//! prefix of the original expression), and Rust never re-associates or
//! fuses float arithmetic, so given the same `log_f` bits a
//! [`PreparedRow`] produces bit-identical [`AdcMetrics`] — asserted by
//! the tests below and the `sweep_stream_properties` integration suite,
//! which require exact bit equality (stronger than the 1-ulp contract).

//! ## Fast tier
//!
//! [`PreparedRow::eval_log_f_fast`] and [`PreparedRowLanes`] are the
//! opt-in approximate tier (`SweepTier::Fast`): identical log-domain
//! arithmetic, but the two `pow10` calls go through
//! [`crate::util::fastmath`]'s range-reduced polynomial instead of
//! libm. Their results are ULP-bounded, not bit-exact, so the
//! `determinism` lint bans them from fingerprinted paths; see
//! `rust/docs/numeric_tiers.md`.

use super::{AdcMetrics, AdcModel, AdcQuery};
use crate::util::fastmath;
use crate::util::logspace::{log10, pow10};

/// A model prepared for row-major sweep evaluation.
///
/// Thin wrapper that owns a copy of the [`AdcModel`] and mints
/// [`PreparedRow`]s; keeping it a distinct type makes the intended
/// call shape explicit (prepare once, mint one row per (ENOB, tech),
/// evaluate many throughput points per row).
#[derive(Clone, Copy, Debug)]
pub struct PreparedModel {
    model: AdcModel,
}

impl PreparedModel {
    /// Prepare a model for row evaluation.
    pub fn new(model: &AdcModel) -> PreparedModel {
        PreparedModel { model: *model }
    }

    /// The wrapped model.
    pub fn model(&self) -> &AdcModel {
        &self.model
    }

    /// Hoist everything constant for one (ENOB, tech node) row.
    pub fn row(&self, enob: f64, tech_nm: f64) -> PreparedRow {
        let c = &self.model.coefs;
        let log_t = log10(tech_nm / 32.0);
        PreparedRow {
            // Left-associated prefixes of the expressions in
            // `Coefficients::{log_energy_pj, log_area_um2}` — do not
            // re-group, bitwise equivalence depends on it.
            e_min: c.a0 + c.a1 * enob + c.a2 * log_t,
            trade_base: c.b0 + c.b1 * enob + c.b2 * log_t,
            b3: c.b3,
            area_base: c.d0 + c.d1 * log_t,
            d2: c.d2,
            d3: c.d3,
            energy_offset: self.model.energy_offset_decades,
            area_offset: self.model.area_offset_decades,
        }
    }
}

/// Per-(ENOB, tech) constants for the model's throughput axis.
#[derive(Clone, Copy, Debug)]
pub struct PreparedRow {
    /// Minimum-energy bound `a0 + a1·enob + a2·log_t` (log10 pJ, untuned).
    e_min: f64,
    /// Tradeoff bound sans throughput term `b0 + b1·enob + b2·log_t`.
    trade_base: f64,
    /// Tradeoff bound throughput slope.
    b3: f64,
    /// Area partial `d0 + d1·log_t`.
    area_base: f64,
    /// Area throughput exponent.
    d2: f64,
    /// Area energy exponent.
    d3: f64,
    /// Tuning offset added to log-energy (after the two-bound max).
    energy_offset: f64,
    /// Tuning offset added to log-area.
    area_offset: f64,
}

impl PreparedRow {
    /// Evaluate one point of the row given the log10 *per-ADC* throughput
    /// plus the raw totals the aggregate metrics need. `log_f` must equal
    /// `log10(total_throughput / n_adcs)` bit-for-bit for the result to
    /// be bit-identical to [`AdcModel::eval`]; sweep drivers cache those
    /// values once per (throughput, n_adcs) pair.
    #[inline]
    pub fn eval_log_f(&self, log_f: f64, total_throughput: f64, n_adcs: u32) -> AdcMetrics {
        let log_e = self.e_min.max(self.trade_base + self.b3 * log_f) + self.energy_offset;
        let log_area = self.area_base + self.d2 * log_f + self.d3 * log_e + self.area_offset;
        let energy_pj = pow10(log_e);
        let area = pow10(log_area);
        AdcMetrics {
            energy_pj_per_convert: energy_pj,
            area_um2_per_adc: area,
            total_power_w: energy_pj * 1e-12 * total_throughput,
            total_area_um2: area * n_adcs as f64,
        }
    }

    /// Evaluate a full query through the row (computes `log_f` the same
    /// way [`AdcModel::eval`] does). The query's ENOB / tech node must be
    /// the ones this row was prepared for.
    #[inline]
    pub fn eval_query(&self, q: &AdcQuery) -> AdcMetrics {
        self.eval_log_f(log10(q.throughput_per_adc()), q.total_throughput, q.n_adcs)
    }

    /// log10 energy (pJ/convert) at the given log10 per-ADC throughput —
    /// the row's scalar core, exposed for rollups that never need areas.
    #[inline]
    pub fn log_energy_pj(&self, log_f: f64) -> f64 {
        self.e_min.max(self.trade_base + self.b3 * log_f) + self.energy_offset
    }

    /// Fast-tier scalar evaluation: the same hoisted log-domain
    /// arithmetic as [`PreparedRow::eval_log_f`] (those intermediates
    /// stay bit-identical) with the two `pow10` calls replaced by
    /// [`fastmath::pow10_fast`]. Results are within
    /// [`fastmath::MAX_ULP`] of the exact tier; inputs outside the fast
    /// region (extreme or non-finite `log_f`) fall back to libm inside
    /// `pow10_fast` and are bit-identical. This is also the tail path
    /// the lane driver uses for remainders, so quad and tail agree.
    #[inline]
    pub fn eval_log_f_fast(&self, log_f: f64, total_throughput: f64, n_adcs: u32) -> AdcMetrics {
        let log_e = self.e_min.max(self.trade_base + self.b3 * log_f) + self.energy_offset;
        let log_area = self.area_base + self.d2 * log_f + self.d3 * log_e + self.area_offset;
        let energy_pj = fastmath::pow10_fast(log_e);
        let area = fastmath::pow10_fast(log_area);
        AdcMetrics {
            energy_pj_per_convert: energy_pj,
            area_um2_per_adc: area,
            total_power_w: energy_pj * 1e-12 * total_throughput,
            total_area_um2: area * n_adcs as f64,
        }
    }
}

/// Four [`PreparedRow`]s transposed into structure-of-arrays lanes for
/// the fast sweep tier: one [`PreparedRowLanes::eval4`] call evaluates
/// four grid points per iteration.
///
/// Consecutive sweep grid points generally live on *different* rows
/// (the grid is throughput-minor only within a row; `n_adcs` varies
/// fastest), so the lane struct carries per-lane row constants rather
/// than assuming one shared row.
///
/// Fast tier only — never reference this from fingerprinted code (the
/// `determinism` lint enforces that). Lane results are bit-identical
/// to four [`PreparedRow::eval_log_f_fast`] calls on every host and
/// backend, which is what `tests/simd_equivalence.rs` pins.
#[derive(Clone, Copy, Debug)]
pub struct PreparedRowLanes {
    e_min: [f64; 4],
    trade_base: [f64; 4],
    b3: [f64; 4],
    area_base: [f64; 4],
    d2: [f64; 4],
    d3: [f64; 4],
    energy_offset: [f64; 4],
    area_offset: [f64; 4],
}

impl PreparedRowLanes {
    /// Transpose four rows into lanes (lane `l` = `rows[l]`).
    pub fn gather(rows: [&PreparedRow; 4]) -> PreparedRowLanes {
        let pick = |field: fn(&PreparedRow) -> f64| {
            [field(rows[0]), field(rows[1]), field(rows[2]), field(rows[3])]
        };
        PreparedRowLanes {
            e_min: pick(|r| r.e_min),
            trade_base: pick(|r| r.trade_base),
            b3: pick(|r| r.b3),
            area_base: pick(|r| r.area_base),
            d2: pick(|r| r.d2),
            d3: pick(|r| r.d3),
            energy_offset: pick(|r| r.energy_offset),
            area_offset: pick(|r| r.area_offset),
        }
    }

    /// Evaluate four grid points, one per lane. Bit-identical to four
    /// [`PreparedRow::eval_log_f_fast`] calls: the log-domain part
    /// below is the same scalar arithmetic per lane, and
    /// [`fastmath::pow10x4`] is bit-identical to four `pow10_fast`
    /// calls by construction.
    #[inline]
    pub fn eval4(
        &self,
        log_f: [f64; 4],
        total_throughput: [f64; 4],
        n_adcs: [u32; 4],
    ) -> [AdcMetrics; 4] {
        let mut log_e = [0.0f64; 4];
        let mut log_area = [0.0f64; 4];
        for l in 0..4 {
            let e = self.e_min[l].max(self.trade_base[l] + self.b3[l] * log_f[l])
                + self.energy_offset[l];
            log_e[l] = e;
            log_area[l] =
                self.area_base[l] + self.d2[l] * log_f[l] + self.d3[l] * e + self.area_offset[l];
        }
        let energy_pj = fastmath::pow10x4(log_e);
        let area = fastmath::pow10x4(log_area);
        std::array::from_fn(|l| AdcMetrics {
            energy_pj_per_convert: energy_pj[l],
            area_um2_per_adc: area[l],
            total_power_w: energy_pj[l] * 1e-12 * total_throughput[l],
            total_area_um2: area[l] * n_adcs[l] as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adc::tuning::TuningPoint;

    fn bits(m: &AdcMetrics) -> [u64; 4] {
        m.to_bits()
    }

    #[test]
    fn row_matches_eval_bit_for_bit() {
        let model = AdcModel::default();
        let prepared = PreparedModel::new(&model);
        for enob in [2.0, 4.5, 7.0, 8.0, 12.0, 13.9] {
            for tech in [16.0, 32.0, 65.0, 130.0] {
                let row = prepared.row(enob, tech);
                for total in [1e4, 3.3e6, 1.3e9, 4e10] {
                    for n in [1u32, 3, 8, 32] {
                        let q = AdcQuery {
                            enob,
                            total_throughput: total,
                            tech_nm: tech,
                            n_adcs: n,
                        };
                        assert_eq!(
                            bits(&row.eval_query(&q)),
                            bits(&model.eval(&q)),
                            "enob={enob} tech={tech} total={total} n={n}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tuned_model_offsets_ride_through() {
        let point = TuningPoint {
            query: AdcQuery { enob: 7.0, total_throughput: 1e9, tech_nm: 32.0, n_adcs: 1 },
            energy_pj_per_convert: 3.3,
            area_um2: Some(5e4),
        };
        let tuned = AdcModel::default().tuned_to(&point);
        assert!(tuned.energy_offset_decades != 0.0);
        let prepared = PreparedModel::new(&tuned);
        for (enob, tech, total, n) in
            [(5.0, 65.0, 1e8, 2u32), (9.0, 16.0, 1e10, 8), (7.0, 32.0, 1e9, 1)]
        {
            let q = AdcQuery { enob, total_throughput: total, tech_nm: tech, n_adcs: n };
            let row = prepared.row(enob, tech);
            assert_eq!(bits(&row.eval_query(&q)), bits(&tuned.eval(&q)));
        }
    }

    #[test]
    fn cached_log_f_equals_evals_log_f_bits() {
        // The sweep caches log10(total/n) per (throughput, n_adcs) pair;
        // that cache entry must be the exact value eval derives.
        for total in [1.3e9, 7.7e5, 4e10] {
            for n in [1u32, 2, 16] {
                let q = AdcQuery { enob: 8.0, total_throughput: total, tech_nm: 32.0, n_adcs: n };
                let cached = log10(total / n as f64);
                assert_eq!(cached.to_bits(), log10(q.throughput_per_adc()).to_bits());
            }
        }
    }

    #[test]
    fn fast_scalar_is_ulp_bounded_and_shares_log_domain() {
        let model = AdcModel::default();
        let prepared = PreparedModel::new(&model);
        for enob in [2.0, 7.0, 13.9] {
            for tech in [16.0, 65.0] {
                let row = prepared.row(enob, tech);
                for total in [1e4, 3.3e6, 4e10] {
                    for n in [1u32, 8] {
                        let log_f = log10(total / n as f64);
                        let exact = row.eval_log_f(log_f, total, n);
                        let fast = row.eval_log_f_fast(log_f, total, n);
                        for (e, f) in exact.to_bits().iter().zip(fast.to_bits().iter()) {
                            let d = fastmath::ulp_distance(
                                f64::from_bits(*e),
                                f64::from_bits(*f),
                            );
                            assert!(
                                d <= fastmath::MAX_ULP,
                                "enob={enob} tech={tech} total={total} n={n} ulp={d}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn lanes_match_fast_scalar_bitwise() {
        let model = AdcModel::default();
        let prepared = PreparedModel::new(&model);
        let rows = [
            prepared.row(2.0, 16.0),
            prepared.row(7.5, 32.0),
            prepared.row(11.0, 65.0),
            prepared.row(13.9, 130.0),
        ];
        let lanes = PreparedRowLanes::gather([&rows[0], &rows[1], &rows[2], &rows[3]]);
        let totals = [1e4, 3.3e6, 1.3e9, 4e10];
        let ns = [1u32, 3, 8, 32];
        let log_f: [f64; 4] =
            std::array::from_fn(|l| log10(totals[l] / ns[l] as f64));
        let quad = lanes.eval4(log_f, totals, ns);
        for l in 0..4 {
            let scalar = rows[l].eval_log_f_fast(log_f[l], totals[l], ns[l]);
            assert_eq!(quad[l].to_bits(), scalar.to_bits(), "lane {l}");
        }
    }

    #[test]
    fn log_energy_matches_full_eval() {
        let model = AdcModel::default();
        let row = PreparedModel::new(&model).row(8.0, 32.0);
        for total in [1e5, 1e9] {
            let q = AdcQuery { enob: 8.0, total_throughput: total, tech_nm: 32.0, n_adcs: 1 };
            let log_f = log10(q.throughput_per_adc());
            let e = pow10(row.log_energy_pj(log_f));
            assert_eq!(e.to_bits(), model.eval(&q).energy_pj_per_convert.to_bits());
        }
    }
}
