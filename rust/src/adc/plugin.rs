//! Accelergy-style plug-in interface.
//!
//! The paper's released artifact is an Accelergy plug-in: an estimator
//! that answers `(class_name, attributes, action_name)` queries with
//! energy/area numbers and a confidence ("accuracy") score, so that a
//! architecture description can name an `adc` component and have this
//! model price it. This module reproduces that interface shape so the
//! crate slots into an Accelergy-like flow:
//!
//! * [`Estimator::primitive_classes`] — the classes this plug-in serves.
//! * [`Estimator::estimate_energy`] / [`Estimator::estimate_area`] —
//!   attribute-map queries returning picojoules / µm².
//!
//! Attribute names follow the published plug-in: `resolution` (ENOB),
//! `throughput` (total converts/s), `n_adcs`, `technology` (nm).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

use super::{AdcModel, AdcQuery};

/// An attribute map, as an Accelergy component description would carry.
pub type Attributes = BTreeMap<String, f64>;

/// Estimation confidence reported with each answer (Accelergy protocol:
/// estimators bid with an accuracy percentage).
pub const ACCURACY: f64 = 70.0;

/// One estimation answer.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    /// The estimated value (pJ per action, or µm² per instance).
    pub value: f64,
    /// Confidence score in [0, 100].
    pub accuracy: f64,
}

/// The ADC estimator plug-in.
#[derive(Clone, Debug)]
pub struct Estimator {
    model: AdcModel,
}

impl Estimator {
    /// Wrap a (fitted / tuned) model as an estimator.
    pub fn new(model: AdcModel) -> Self {
        Estimator { model }
    }

    /// Primitive component classes served by this plug-in.
    pub fn primitive_classes(&self) -> &'static [&'static str] {
        &["adc", "sar_adc", "pipeline_adc", "flash_adc"]
    }

    /// Whether a class/action pair is supported.
    pub fn supports(&self, class_name: &str, action_name: &str) -> bool {
        self.primitive_classes().contains(&class_name)
            && matches!(action_name, "convert" | "read" | "sample")
    }

    fn query_from(&self, attributes: &Attributes) -> Result<AdcQuery> {
        let get = |names: &[&str], default: Option<f64>| -> Result<f64> {
            for n in names {
                if let Some(v) = attributes.get(*n) {
                    return Ok(*v);
                }
            }
            default.ok_or_else(|| {
                Error::Config(format!("adc plugin: missing attribute {names:?}"))
            })
        };
        let query = AdcQuery {
            enob: get(&["resolution", "enob"], None)?,
            total_throughput: get(&["throughput", "total_throughput"], None)?,
            tech_nm: get(&["technology", "tech_nm"], Some(32.0))?,
            n_adcs: get(&["n_adcs", "n_instances"], Some(1.0))? as u32,
        };
        query.validate()?;
        Ok(query)
    }

    /// Energy per `convert` action, picojoules.
    pub fn estimate_energy(
        &self,
        class_name: &str,
        attributes: &Attributes,
        action_name: &str,
    ) -> Result<Estimate> {
        if !self.supports(class_name, action_name) {
            return Err(Error::Config(format!(
                "adc plugin: unsupported query {class_name}/{action_name}"
            )));
        }
        let q = self.query_from(attributes)?;
        Ok(Estimate { value: self.model.energy_pj_per_convert(&q), accuracy: ACCURACY })
    }

    /// Area per ADC instance, µm².
    pub fn estimate_area(&self, class_name: &str, attributes: &Attributes) -> Result<Estimate> {
        if !self.primitive_classes().contains(&class_name) {
            return Err(Error::Config(format!(
                "adc plugin: unsupported class {class_name}"
            )));
        }
        let q = self.query_from(attributes)?;
        Ok(Estimate { value: self.model.area_um2_per_adc(&q), accuracy: ACCURACY })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attrs(pairs: &[(&str, f64)]) -> Attributes {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn energy_query_matches_model() {
        let model = AdcModel::default();
        let est = Estimator::new(model);
        let a = attrs(&[("resolution", 7.0), ("throughput", 1e9), ("technology", 32.0)]);
        let e = est.estimate_energy("adc", &a, "convert").unwrap();
        let q = AdcQuery { enob: 7.0, total_throughput: 1e9, tech_nm: 32.0, n_adcs: 1 };
        assert!((e.value - model.energy_pj_per_convert(&q)).abs() < 1e-12);
        assert_eq!(e.accuracy, ACCURACY);
    }

    #[test]
    fn attribute_aliases_work() {
        let est = Estimator::new(AdcModel::default());
        let a = attrs(&[("enob", 8.0), ("total_throughput", 1e8), ("tech_nm", 65.0)]);
        let b = attrs(&[("resolution", 8.0), ("throughput", 1e8), ("technology", 65.0)]);
        let ea = est.estimate_energy("adc", &a, "convert").unwrap().value;
        let eb = est.estimate_energy("adc", &b, "convert").unwrap().value;
        assert_eq!(ea, eb);
    }

    #[test]
    fn defaults_applied_for_optional_attributes() {
        let est = Estimator::new(AdcModel::default());
        // technology defaults to 32 nm, n_adcs to 1.
        let a = attrs(&[("resolution", 7.0), ("throughput", 1e9)]);
        assert!(est.estimate_area("adc", &a).is_ok());
    }

    #[test]
    fn missing_required_attribute_errors() {
        let est = Estimator::new(AdcModel::default());
        let a = attrs(&[("throughput", 1e9)]);
        let err = est.estimate_energy("adc", &a, "convert").unwrap_err().to_string();
        assert!(err.contains("resolution"), "{err}");
    }

    #[test]
    fn unsupported_class_or_action_rejected() {
        let est = Estimator::new(AdcModel::default());
        let a = attrs(&[("resolution", 7.0), ("throughput", 1e9)]);
        assert!(est.estimate_energy("dac", &a, "convert").is_err());
        assert!(est.estimate_energy("adc", &a, "multiply").is_err());
        assert!(est.supports("sar_adc", "convert"));
    }

    #[test]
    fn n_adcs_divides_per_adc_throughput() {
        let est = Estimator::new(AdcModel::default());
        // 8 ADCs at the same total throughput -> lower per-ADC rate -> the
        // per-convert energy cannot be higher.
        let one = attrs(&[("resolution", 7.0), ("throughput", 4e9), ("n_adcs", 1.0)]);
        let eight = attrs(&[("resolution", 7.0), ("throughput", 4e9), ("n_adcs", 8.0)]);
        let e1 = est.estimate_energy("adc", &one, "convert").unwrap().value;
        let e8 = est.estimate_energy("adc", &eight, "convert").unwrap().value;
        assert!(e8 <= e1);
    }
}
