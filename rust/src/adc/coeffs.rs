//! Model coefficients — the fitted parameters of the paper's §II model.
//!
//! Layout mirrors `python/compile/coeffs.py` exactly (the same 11-float
//! vector is fed to the AOT-compiled Pallas kernel at runtime):
//!
//! ```text
//! log10 E_min   = a0 + a1·ENOB + a2·t            t = log10(tech_nm / 32)
//! log10 E_trade = b0 + b1·ENOB + b2·t + b3·log10 f
//! log10 E       = max(E_min, E_trade)                         [pJ/convert]
//! log10 Area    = d0 + d1·t + d2·log10 f + d3·log10 E         [µm², Eq. 1]
//! ```

/// The 11 model coefficients (see module docs for the functional form).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Coefficients {
    /// Minimum-energy bound intercept (log10 pJ at ENOB=0, 32 nm).
    pub a0: f64,
    /// Minimum-energy bound ENOB slope (decades per bit).
    pub a1: f64,
    /// Minimum-energy bound tech slope (decades per decade of node).
    pub a2: f64,
    /// Tradeoff bound intercept.
    pub b0: f64,
    /// Tradeoff bound ENOB slope (> a1: crossover falls with ENOB).
    pub b1: f64,
    /// Tradeoff bound tech slope.
    pub b2: f64,
    /// Tradeoff bound throughput slope (decades per decade of f).
    pub b3: f64,
    /// Area intercept: log10(kappa · 21.1 · 32^d1).
    pub d0: f64,
    /// Area tech exponent (Eq. 1: 1.0).
    pub d1: f64,
    /// Area throughput exponent (Eq. 1: 0.2).
    pub d2: f64,
    /// Area energy exponent (Eq. 1: 0.3).
    pub d3: f64,
}

/// The paper's Eq. 1 leading constant (before p10 calibration).
pub const EQ1_CONSTANT: f64 = 21.1;

/// The p10 area calibration factor baked into the generator truth.
/// Consistent with the generator's 0.55-decade area scatter:
/// `10^(-1.2816 * 0.55) ~= 0.20` (the lowest-area-10% envelope).
pub const TRUTH_KAPPA: f64 = 0.20;

impl Coefficients {
    /// Ground-truth constants the synthetic survey is generated from, and
    /// the defaults baked into the AOT artifact. Matches
    /// `python/compile/coeffs.py` (asserted by an integration test).
    pub fn generator_truth() -> Self {
        Coefficients {
            a0: -2.301, // 4b @ 32nm: 0.05 pJ/convert
            a1: 0.250,  // x10 energy per 4 ENOB bits
            a2: 1.000,
            b0: -14.840, // anchors the 8b corner at ~2.8e8 conv/s @ 32nm
            b1: 0.550,   // crossover falls 0.25 decades/bit
            b2: 1.000,
            b3: 1.200,
            d0: (TRUTH_KAPPA * EQ1_CONSTANT).log10() + 32f64.log10(),
            d1: 1.0,
            d2: 0.2,
            d3: 0.3,
        }
    }

    /// Raw Eq. 1 (kappa = 1) variant of the truth, used by the survey
    /// generator to scatter area around the *uncalibrated* law.
    pub fn log_area_raw_um2(&self, log_t: f64, log_f: f64, log_e_pj: f64) -> f64 {
        EQ1_CONSTANT.log10() + self.d1 * (log_t + 32f64.log10()) + self.d2 * log_f
            + self.d3 * log_e_pj
    }

    /// log10 energy per convert (pJ): max of the two bounds.
    pub fn log_energy_pj(&self, enob: f64, log_t: f64, log_f: f64) -> f64 {
        let e_min = self.a0 + self.a1 * enob + self.a2 * log_t;
        let e_trade = self.b0 + self.b1 * enob + self.b2 * log_t + self.b3 * log_f;
        e_min.max(e_trade)
    }

    /// log10 area (µm², Eq. 1 with the calibrated d0).
    pub fn log_area_um2(&self, log_t: f64, log_f: f64, log_e_pj: f64) -> f64 {
        self.d0 + self.d1 * log_t + self.d2 * log_f + self.d3 * log_e_pj
    }

    /// Flat f32 vector in the artifact's layout
    /// `[a0,a1,a2, b0,b1,b2,b3, d0,d1,d2,d3]`.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        vec![
            self.a0 as f32,
            self.a1 as f32,
            self.a2 as f32,
            self.b0 as f32,
            self.b1 as f32,
            self.b2 as f32,
            self.b3 as f32,
            self.d0 as f32,
            self.d1 as f32,
            self.d2 as f32,
            self.d3 as f32,
        ]
    }

    /// Inverse of [`Self::to_f32_vec`].
    pub fn from_slice(v: &[f64]) -> Self {
        assert_eq!(v.len(), 11, "coefficient vector must have 11 entries");
        Coefficients {
            a0: v[0],
            a1: v[1],
            a2: v[2],
            b0: v[3],
            b1: v[4],
            b2: v[5],
            b3: v[6],
            d0: v[7],
            d1: v[8],
            d2: v[9],
            d3: v[10],
        }
    }

    /// Flat f64 vector (same layout).
    pub fn to_vec(&self) -> Vec<f64> {
        vec![
            self.a0, self.a1, self.a2, self.b0, self.b1, self.b2, self.b3, self.d0,
            self.d1, self.d2, self.d3,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_anchors() {
        let c = Coefficients::generator_truth();
        // 4b @ 32nm, low throughput: 0.05 pJ.
        let e4 = 10f64.powf(c.log_energy_pj(4.0, 0.0, 4.0));
        assert!((e4 - 0.05).abs() < 1e-3, "{e4}");
        // Bounds meet exactly at the analytic crossover.
        let cross = (c.a0 - c.b0 + (c.a1 - c.b1) * 4.0) / c.b3;
        let flat = c.a0 + c.a1 * 4.0;
        let trade = c.b0 + c.b1 * 4.0 + c.b3 * cross;
        assert!((flat - trade).abs() < 1e-9);
        // The 8b corner sits in the high-1e8 range at 32 nm.
        let cross8 = 10f64.powf((c.a0 - c.b0 + (c.a1 - c.b1) * 8.0) / c.b3);
        assert!((1e8..1e9).contains(&cross8), "{cross8}");
    }

    #[test]
    fn roundtrip_vec() {
        let c = Coefficients::generator_truth();
        let v = c.to_vec();
        assert_eq!(Coefficients::from_slice(&v), c);
        assert_eq!(v.len(), 11);
    }

    #[test]
    fn energy_monotone_in_enob_and_throughput() {
        let c = Coefficients::generator_truth();
        let mut prev = f64::MIN;
        for enob in [2.0, 4.0, 6.0, 8.0, 10.0, 12.0] {
            let e = c.log_energy_pj(enob, 0.0, 8.0);
            assert!(e > prev);
            prev = e;
        }
        let mut prev = f64::MIN;
        for log_f in [4.0, 6.0, 8.0, 9.0, 10.0] {
            let e = c.log_energy_pj(8.0, 0.0, log_f);
            assert!(e >= prev);
            prev = e;
        }
    }

    #[test]
    fn calibrated_area_below_raw() {
        let c = Coefficients::generator_truth();
        let raw = c.log_area_raw_um2(0.0, 8.0, 0.0);
        let cal = c.log_area_um2(0.0, 8.0, 0.0);
        assert!((raw - cal - (-(TRUTH_KAPPA.log10()))).abs() < 1e-12);
        assert!(cal < raw);
    }
}
