//! Deterministic pseudo-random number generation.
//!
//! The offline registry carries no `rand` crate, so this module implements
//! xoshiro256++ (Blackman & Vigna) seeded through SplitMix64, plus the
//! distributions the survey generator and property tests need. All
//! generation in the crate is seed-deterministic so every experiment is
//! exactly reproducible.

/// xoshiro256++ PRNG with Box-Muller gaussian support.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare_gauss: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_gauss: None }
    }

    /// Next raw 64-bit output (xoshiro256++).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi) (hi exclusive). Panics if lo >= hi.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        // Lemire-style rejection-free-enough for modeling use.
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.range(0, n as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare_gauss = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal with mean `mu`, standard deviation `sigma`.
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.gauss()
    }

    /// Log10-normal multiplicative factor: `10^N(mu, sigma)` (decades).
    pub fn log10_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        10f64.powf(self.normal(mu, sigma))
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Pick one element of a slice (uniform).
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.index(items.len())]
    }

    /// Pick one element with the given (unnormalized) weights.
    pub fn weighted_choice<'a, T>(&mut self, items: &'a [(T, f64)]) -> &'a T {
        let total: f64 = items.iter().map(|(_, w)| w).sum();
        let mut x = self.f64() * total;
        for (item, w) in items {
            x -= w;
            if x <= 0.0 {
                return item;
            }
        }
        &items[items.len() - 1].0
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(19);
        for _ in 0..10_000 {
            let x = r.range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(23);
        let items = [("a", 1.0), ("b", 9.0)];
        let n = 20_000;
        let b_count = (0..n)
            .filter(|_| *r.weighted_choice(&items) == "b")
            .count();
        let frac = b_count as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
