//! Small numeric / formatting substrates shared across the crate.

pub mod fastmath;
pub mod logspace;
pub mod rng;
pub mod units;

// `fastmath` items are deliberately not re-exported: call sites must
// spell out the module (the determinism lint bans that token from
// fingerprinted paths, so approximate math stays greppable).
pub use logspace::{linspace, log10, logspace, pow10};
pub use rng::Rng;
