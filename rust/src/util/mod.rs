//! Small numeric / formatting substrates shared across the crate.

pub mod logspace;
pub mod rng;
pub mod units;

pub use logspace::{linspace, log10, logspace, pow10};
pub use rng::Rng;
