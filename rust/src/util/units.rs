//! Human-readable formatting of physical quantities used in reports.

/// Format a throughput in converts/second with SI prefix (e.g. "1.3 GS/s").
pub fn fmt_throughput(converts_per_s: f64) -> String {
    fmt_si(converts_per_s, "S/s")
}

/// Format an energy given in picojoules with an appropriate prefix.
pub fn fmt_energy_pj(pj: f64) -> String {
    fmt_si(pj * 1e-12, "J")
}

/// Format an area given in square micrometers.
pub fn fmt_area_um2(um2: f64) -> String {
    if um2 >= 1e6 {
        format!("{:.3} mm²", um2 / 1e6)
    } else {
        format!("{um2:.1} µm²")
    }
}

/// Format a power in watts.
pub fn fmt_power_w(w: f64) -> String {
    fmt_si(w, "W")
}

/// Generic SI-prefixed formatter.
pub fn fmt_si(value: f64, unit: &str) -> String {
    const PREFIXES: &[(f64, &str)] = &[
        (1e12, "T"),
        (1e9, "G"),
        (1e6, "M"),
        (1e3, "k"),
        (1.0, ""),
        (1e-3, "m"),
        (1e-6, "µ"),
        (1e-9, "n"),
        (1e-12, "p"),
        (1e-15, "f"),
    ];
    if value == 0.0 {
        return format!("0 {unit}");
    }
    let mag = value.abs();
    for &(scale, prefix) in PREFIXES {
        if mag >= scale {
            return format!("{:.3} {}{}", value / scale, prefix, unit);
        }
    }
    format!("{value:.3e} {unit}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_prefixes() {
        assert_eq!(fmt_si(1.3e9, "S/s"), "1.300 GS/s");
        assert_eq!(fmt_si(2.5e-12, "J"), "2.500 pJ");
        assert_eq!(fmt_si(0.0, "W"), "0 W");
    }

    #[test]
    fn energy_pico_input() {
        assert_eq!(fmt_energy_pj(1.0), "1.000 pJ");
        assert_eq!(fmt_energy_pj(1500.0), "1.500 nJ");
    }

    #[test]
    fn area_switches_to_mm2() {
        assert_eq!(fmt_area_um2(100.0), "100.0 µm²");
        assert_eq!(fmt_area_um2(2.5e6), "2.500 mm²");
    }
}
