//! Zero-dependency fast math for the sweep **fast tier**: a
//! range-reduced `exp2` rational polynomial and a decade-split `pow10`
//! built on it, in scalar and 4-lane batch form.
//!
//! # Two-tier policy
//!
//! Everything here is **ULP-bounded, not bit-exact** — it exists only
//! for the opt-in fast sweep tier (`cimdse sweep --tier fast`,
//! [`crate::dse::SweepTier::Fast`]). Fingerprinted or golden-pinned
//! outputs (shard artifacts, served responses, golden figures, sweep
//! summaries) stay on the libm-backed exact tier by construction, and
//! the `determinism` lint rule bans any reference to this module from
//! those paths. See `rust/docs/numeric_tiers.md` for the full policy
//! and the derivation below.
//!
//! # Algorithm
//!
//! `pow10(x)` is split as `10^x = 10^k · 10^f` with `k = round(x)` and
//! `f = x - k ∈ [-0.5, 0.5]`:
//!
//! * `10^k` comes from a 31-entry table of correctly-rounded decade
//!   constants (`1e-15 ..= 1e15`, the model's full dynamic range with
//!   margin). `k = round(x)` uses the classic magic-number trick: add
//!   `1.5·2^52`, which in round-to-nearest-even forces the fraction
//!   bits to hold the rounded integer; subtracting the magic bits
//!   recovers `k` as an `i64` with no float→int conversion.
//! * `10^f = 2^(f·log2(10))` with `|f·log2(10)| ≤ 1.661`, evaluated by
//!   a second magic-number range reduction to `r ∈ [-0.5, 0.5]` and
//!   the classic Cephes `exp2` rational approximation
//!   (`P(r²)·r / (Q(r²) - P(r²)·r)`, then `1 + 2t`), with the final
//!   `2^k₂` applied by direct exponent-bit construction.
//!
//! Inputs where `|round(x)| > 15` — including NaN, infinities, and
//! anything that would leave the table — fall back to the libm-backed
//! [`pow10`](crate::util::logspace::pow10) and are therefore
//! **bit-identical** to the exact tier there.
//!
//! # Accuracy
//!
//! Measured against libm `10f64.powf` over 10⁷ uniform samples in
//! `[-15.5, 15.5]` (the fast region): max **4 ULP** (distribution:
//! 61% exact, 38% at 1 ULP, tail ≤ 4). Derived sweep metrics
//! (`energy·1e-12·throughput`, `area·n_adcs`) measured ≤ 5 ULP. The
//! property suite (`tests/simd_equivalence.rs`) asserts the
//! conservative bound [`MAX_ULP`] = 8.
//!
//! # Lane batching
//!
//! [`pow10x4`] evaluates four inputs per call. With the `simd` cargo
//! feature on an x86_64 host that reports AVX2 at runtime it runs a
//! vectorized transcription of the scalar fast path (same IEEE ops in
//! the same order, no FMA contraction on either side), so its results
//! are **bit-identical to four [`pow10_fast`] calls on every host** —
//! the fast tier's output does not depend on the backend. A quad with
//! any out-of-range lane drops whole to the scalar path, which
//! per-lane falls back to libm exactly as above.

use crate::util::logspace::pow10;

/// Property-tested ULP bound of the fast tier vs. the exact tier
/// (measured max: 4 for raw `pow10`, 5 for derived sweep metrics).
pub const MAX_ULP: u64 = 8;

/// `1.5 · 2^52` — adding this to `x` (|x| < 2^51) rounds `x` to the
/// nearest integer (ties-to-even) in the float's low mantissa bits.
const SHIFT: f64 = 6_755_399_441_055_744.0;

/// Nearest `f64` to `log2(10)`.
const LOG2_10: f64 = 3.321928094887362;

/// Largest decade magnitude handled by the fast path; beyond it (or on
/// non-finite input) `pow10_fast` defers to libm bit-identically.
const DECADE_MAX: f64 = 15.0;

// Cephes exp2 rational-approximation coefficients
// (`2^r = 1 + 2·px/(q - px)` with `px = r·P(r²)`, `q = Q(r²)`,
// accurate to < 1 ULP for `r ∈ [-0.5, 0.5]`).
const P0: f64 = 2.309_334_770_573_452_25e-2;
const P1: f64 = 2.020_206_566_931_653_08e1;
const P2: f64 = 1.513_906_801_156_150_96e3;
const Q0: f64 = 2.331_842_117_223_149_1e2;
const Q1: f64 = 4.368_211_668_792_106_1e3;

/// Correctly-rounded decade constants `10^k` for `k ∈ [-15, 15]`.
const P10: [f64; 31] = [
    1e-15, 1e-14, 1e-13, 1e-12, 1e-11, 1e-10, 1e-9, 1e-8, 1e-7, 1e-6,
    1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6,
    1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13, 1e14, 1e15,
];

/// Round to nearest (ties-to-even) via the magic-number trick.
///
/// Returns the rounded value as both `f64` and `i64`. The integer is
/// recovered by subtracting the magic constant's bit pattern, which is
/// only meaningful while `x + SHIFT` stays in `SHIFT`'s binade — true
/// whenever `|x| ≲ 2^51`. Callers must bound-check the *float* result
/// before trusting the integer.
#[inline]
fn round_magic(x: f64) -> (f64, i64) {
    let big = x + SHIFT;
    let kf = big - SHIFT;
    let ki = (big.to_bits() as i64).wrapping_sub(SHIFT.to_bits() as i64);
    (kf, ki)
}

/// Cephes exp2 core for reduced arguments.
///
/// Valid only for `|y|` small enough that `2^round(y)` is a normal
/// float (callers keep `|y| ≤ 512`); no range check of its own.
#[inline]
fn exp2_reduced(y: f64) -> f64 {
    let (k2f, k2) = round_magic(y);
    let r = y - k2f;
    let u = r * r;
    let px = r * ((P0 * u + P1) * u + P2);
    let q = (u + Q0) * u + Q1;
    let t = px / (q - px);
    let base = 1.0 + (t + t);
    // 2^k2 by direct exponent construction: k2 ∈ [-1022, 1023] here.
    let scale = f64::from_bits(((k2 + 1023) << 52) as u64);
    base * scale
}

/// Fast `2^y`, ≤ 1 ULP from libm `exp2` for `|y| ≤ 512`; defers to
/// libm (bit-identically) outside that range and for non-finite input.
#[inline]
pub fn exp2_fast(y: f64) -> f64 {
    // Negated comparison so NaN also takes the fallback.
    if !(y.abs() <= 512.0) {
        return y.exp2();
    }
    exp2_reduced(y)
}

/// Fast `10^x`, within [`MAX_ULP`] of libm `10f64.powf` for
/// `|round(x)| ≤ 15`; bit-identical to it everywhere else (including
/// NaN/±inf and the extreme magnitudes the fallback region covers).
#[inline]
pub fn pow10_fast(x: f64) -> f64 {
    let (kf, ki) = round_magic(x);
    // Negated comparison so NaN also takes the fallback.
    if !(kf.abs() <= DECADE_MAX) {
        return pow10(x);
    }
    let f = x - kf;
    let y = LOG2_10 * f;
    exp2_reduced(y) * P10[(ki + 15) as usize]
}

/// Four [`pow10_fast`] evaluations per call.
///
/// Bit-identical to calling [`pow10_fast`] on each lane, on every
/// host: the AVX2 path (compiled under the `simd` feature, taken only
/// when the CPU reports AVX2 at runtime) performs the same IEEE
/// operations in the same order as the scalar code, and any quad with
/// an out-of-range or non-finite lane is evaluated scalar-wise.
#[inline]
pub fn pow10x4(xs: [f64; 4]) -> [f64; 4] {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2_enabled() {
            // SAFETY: guarded by the cached runtime AVX2 detection
            // just above, so the target-feature contract holds.
            return unsafe { simd_x86::pow10x4_avx2(xs) };
        }
    }
    pow10x4_portable(xs)
}

/// Portable lane-batch fallback: plain scalar calls.
#[inline]
fn pow10x4_portable(xs: [f64; 4]) -> [f64; 4] {
    [
        pow10_fast(xs[0]),
        pow10_fast(xs[1]),
        pow10_fast(xs[2]),
        pow10_fast(xs[3]),
    ]
}

/// Which backend [`pow10x4`] resolves to on this host: `"avx2"` when
/// the `simd` feature is compiled in and the CPU reports AVX2,
/// `"portable"` otherwise. Recorded in `BENCH_sweep.json`'s `tiers`
/// table so bench artifacts are self-describing.
pub fn fast_backend() -> &'static str {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2_enabled() {
            return "avx2";
        }
    }
    "portable"
}

/// Cached runtime AVX2 detection (the OS-aware `is_x86_feature_detected!`
/// probe is too slow for a per-quad decision).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn avx2_enabled() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    // 0 = unprobed, 1 = absent, 2 = present. A racing first probe is
    // benign: both threads store the same answer.
    static AVX2: AtomicU8 = AtomicU8::new(0);
    match AVX2.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// Distance between two `f64`s in units-in-the-last-place steps,
/// walking the ordered integer encoding (sign-magnitude folded onto a
/// number line). `0` for bitwise-equal values and for `+0 == -0`;
/// `u64::MAX` when exactly one side is NaN; `0` when both are.
pub fn ulp_distance(a: f64, b: f64) -> u64 {
    if a == b {
        return 0;
    }
    if a.is_nan() || b.is_nan() {
        return if a.is_nan() && b.is_nan() { 0 } else { u64::MAX };
    }
    fn key(x: f64) -> i128 {
        let bits = x.to_bits();
        let mag = (bits & 0x7fff_ffff_ffff_ffff) as i128;
        if bits >> 63 == 0 { mag } else { -mag }
    }
    let d = (key(a) - key(b)).unsigned_abs();
    u64::try_from(d).unwrap_or(u64::MAX)
}

/// AVX2 transcription of the scalar fast path. Only compiled under the
/// `simd` feature on x86_64; only *called* after runtime detection.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd_x86 {
    use super::{pow10_fast, DECADE_MAX, LOG2_10, P0, P1, P2, P10, Q0, Q1, SHIFT};
    use core::arch::x86_64::*;

    /// Four `pow10_fast` lanes with AVX2.
    ///
    /// Bit-parity with the scalar path is by construction: every lane
    /// performs the identical sequence of IEEE add/sub/mul/div ops (no
    /// FMA on either side — rustc never contracts, and this code uses
    /// no `fmadd` intrinsics), the round-to-int uses the same
    /// magic-number bit trick, and `2^k` uses the same exponent-bit
    /// construction. Quads with any lane outside the fast region
    /// (`|round(x)| > 15`, or NaN — the ordered compare returns false)
    /// are evaluated scalar-wise, which matches the portable batch
    /// exactly, libm fallback included.
    ///
    /// # Safety
    ///
    /// Callers must ensure the host supports AVX2 (`pow10x4` gates on
    /// the cached `is_x86_feature_detected!("avx2")` probe).
    // SAFETY: `#[target_feature]` makes this fn unsafe-to-call; the
    // body itself upholds no extra invariants beyond plain loads and
    // stores of caller-owned stack arrays.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pow10x4_avx2(xs: [f64; 4]) -> [f64; 4] {
        let shift = _mm256_set1_pd(SHIFT);
        let shift_bits = _mm256_set1_epi64x(SHIFT.to_bits() as i64);

        let x = _mm256_loadu_pd(xs.as_ptr());
        // k = round(x) via the magic-number trick (same as round_magic).
        let big = _mm256_add_pd(x, shift);
        let kf = _mm256_sub_pd(big, shift);

        // All four lanes must satisfy |k| <= 15; the ordered compare is
        // false for NaN lanes, so those quads also drop to scalar.
        let abs_mask = _mm256_castsi256_pd(_mm256_set1_epi64x(0x7fff_ffff_ffff_ffff));
        let kabs = _mm256_and_pd(kf, abs_mask);
        let in_range = _mm256_cmp_pd::<_CMP_LE_OQ>(kabs, _mm256_set1_pd(DECADE_MAX));
        if _mm256_movemask_pd(in_range) != 0b1111 {
            return [
                pow10_fast(xs[0]),
                pow10_fast(xs[1]),
                pow10_fast(xs[2]),
                pow10_fast(xs[3]),
            ];
        }

        // Integer k via bit-pattern subtraction (valid: in-range lanes
        // keep `big` inside SHIFT's binade).
        let ki = _mm256_sub_epi64(_mm256_castpd_si256(big), shift_bits);

        // y = log2(10) * (x - k), then the Cephes exp2 core on y.
        let f = _mm256_sub_pd(x, kf);
        let y = _mm256_mul_pd(_mm256_set1_pd(LOG2_10), f);

        let big2 = _mm256_add_pd(y, shift);
        let k2f = _mm256_sub_pd(big2, shift);
        let k2 = _mm256_sub_epi64(_mm256_castpd_si256(big2), shift_bits);
        let r = _mm256_sub_pd(y, k2f);
        let u = _mm256_mul_pd(r, r);
        let px = _mm256_mul_pd(
            r,
            _mm256_add_pd(
                _mm256_mul_pd(
                    _mm256_add_pd(_mm256_mul_pd(_mm256_set1_pd(P0), u), _mm256_set1_pd(P1)),
                    u,
                ),
                _mm256_set1_pd(P2),
            ),
        );
        let q = _mm256_add_pd(
            _mm256_mul_pd(_mm256_add_pd(u, _mm256_set1_pd(Q0)), u),
            _mm256_set1_pd(Q1),
        );
        let t = _mm256_div_pd(px, _mm256_sub_pd(q, px));
        let base = _mm256_add_pd(_mm256_set1_pd(1.0), _mm256_add_pd(t, t));
        // 2^k2 by exponent-bit construction, as in the scalar core.
        let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(
            k2,
            _mm256_set1_epi64x(1023),
        )));
        let e = _mm256_mul_pd(base, scale);

        // Decade-table lookup: spill the four small indices and compose
        // (no gather — cheaper for 4 lanes and identical semantics).
        let mut kis = [0i64; 4];
        _mm256_storeu_si256(kis.as_mut_ptr() as *mut __m256i, ki);
        let tbl = _mm256_setr_pd(
            P10[(kis[0] + 15) as usize],
            P10[(kis[1] + 15) as usize],
            P10[(kis[2] + 15) as usize],
            P10[(kis[3] + 15) as usize],
        );

        let mut out = [0.0f64; 4];
        _mm256_storeu_pd(out.as_mut_ptr(), _mm256_mul_pd(e, tbl));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2_matches_libm_within_2_ulp() {
        let mut worst = 0u64;
        let mut i = 0;
        let mut y = -3.5f64;
        while y <= 3.5 {
            let d = ulp_distance(exp2_fast(y), y.exp2());
            worst = worst.max(d);
            i += 1;
            y = -3.5 + (i as f64) * 1.3e-4;
        }
        assert!(worst <= 2, "exp2_fast worst ULP {worst}");
    }

    #[test]
    fn exp2_extremes_are_bit_identical_to_libm() {
        for y in [600.0, -600.0, 1.0e308, -1.0e308, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(exp2_fast(y).to_bits(), y.exp2().to_bits(), "y={y}");
        }
        assert!(exp2_fast(f64::NAN).is_nan());
    }

    #[test]
    fn pow10_fast_within_bound_on_grid() {
        let mut worst = 0u64;
        let mut i = 0;
        let mut x = -15.5f64;
        while x <= 15.5 {
            let d = ulp_distance(pow10_fast(x), pow10(x));
            assert!(d <= MAX_ULP, "x={x} ulp={d}");
            worst = worst.max(d);
            i += 1;
            x = -15.5 + (i as f64) * 3.7e-4;
        }
        // the approximation should actually be tight, not just in-bound
        assert!(worst <= 4, "pow10_fast worst ULP {worst}");
    }

    #[test]
    fn pow10_fast_exact_at_integer_decades() {
        for k in -15..=15 {
            let got = pow10_fast(k as f64);
            assert_eq!(got.to_bits(), P10[(k + 15) as usize].to_bits(), "k={k}");
        }
    }

    #[test]
    fn fallback_region_is_bit_identical_to_libm() {
        for x in [
            15.6, -15.6, 16.0, -16.0, 200.3, -200.3, 308.0, -308.0, 320.0,
            -320.0, 1.0e18, -1.0e18, f64::INFINITY, f64::NEG_INFINITY,
        ] {
            assert_eq!(pow10_fast(x).to_bits(), pow10(x).to_bits(), "x={x}");
        }
        assert!(pow10_fast(f64::NAN).is_nan());
    }

    #[test]
    fn halfway_cases_round_ties_to_even() {
        // 15.5 rounds to 16 (even) -> fallback; 14.5 rounds to 14 -> fast.
        assert_eq!(pow10_fast(15.5).to_bits(), pow10(15.5).to_bits());
        let d = ulp_distance(pow10_fast(14.5), pow10(14.5));
        assert!(d <= MAX_ULP, "x=14.5 ulp={d}");
    }

    #[test]
    fn pow10x4_matches_scalar_bitwise() {
        // Mixed quads: all-fast, all-fallback, and straddling — the
        // batch must equal four scalar calls bit-for-bit regardless of
        // which backend runs it.
        let quads = [
            [0.25, -3.75, 9.1, 14.99],
            [16.0, -16.0, 300.5, -300.5],
            [1.5, -15.6, 7.25, f64::NAN],
            [0.0, -0.0, 15.0, -15.0],
        ];
        for xs in quads {
            let batch = pow10x4(xs);
            for l in 0..4 {
                let scalar = pow10_fast(xs[l]);
                if scalar.is_nan() {
                    assert!(batch[l].is_nan());
                } else {
                    assert_eq!(batch[l].to_bits(), scalar.to_bits(), "lane {l} of {xs:?}");
                }
            }
        }
    }

    #[test]
    fn fast_backend_names_a_known_backend() {
        assert!(matches!(fast_backend(), "avx2" | "portable"));
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(1.0, f64::from_bits(1.0f64.to_bits() + 3)), 3);
        assert_eq!(ulp_distance(f64::MIN_POSITIVE, -f64::MIN_POSITIVE), 2 * (1u64 << 52));
        assert_eq!(ulp_distance(1.0, f64::NAN), u64::MAX);
        assert_eq!(ulp_distance(f64::NAN, f64::NAN), 0);
    }
}
