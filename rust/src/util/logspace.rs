//! Log-space helpers used throughout the model (everything in the paper is
//! fit and plotted in log10 space).

/// `log10` that maps non-positive input to an error-signaling NaN-free floor.
///
/// The model operates on strictly positive physical quantities; a zero or
/// negative value is a caller bug, so we debug-assert and clamp in release
/// builds rather than propagating NaN through a whole sweep.
pub fn log10(x: f64) -> f64 {
    debug_assert!(x > 0.0, "log10 of non-positive value {x}");
    x.max(f64::MIN_POSITIVE).log10()
}

/// `10^x`.
pub fn pow10(x: f64) -> f64 {
    10f64.powf(x)
}

/// `n` points spaced linearly over [lo, hi] inclusive.
///
/// Degenerate axes are well-defined rather than a panic (they are
/// reachable from user-supplied sweep specs): `n == 0` yields an empty
/// axis and `n == 1` collapses to `[lo]`.
pub fn linspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    if n <= 1 {
        return (0..n).map(|_| lo).collect();
    }
    let step = (hi - lo) / (n - 1) as f64;
    (0..n).map(|i| lo + step * i as f64).collect()
}

/// `n` points spaced logarithmically over [lo, hi] inclusive (lo, hi > 0).
pub fn logspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    linspace(log10(lo), log10(hi), n)
        .into_iter()
        .map(pow10)
        .collect()
}

/// Geometric mean of a slice of positive numbers.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    pow10(xs.iter().map(|&x| log10(x)).sum::<f64>() / xs.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linspace_endpoints_and_count() {
        let v = linspace(0.0, 1.0, 5);
        assert_eq!(v.len(), 5);
        assert_eq!(v[0], 0.0);
        assert_eq!(v[4], 1.0);
        assert!((v[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn linspace_degenerate_axes() {
        assert!(linspace(2.0, 14.0, 0).is_empty());
        assert_eq!(linspace(2.0, 14.0, 1), vec![2.0]);
        // logspace inherits the same semantics
        assert!(logspace(1e3, 1e9, 0).is_empty());
        let one = logspace(1e3, 1e9, 1);
        assert_eq!(one.len(), 1);
        assert!((one[0] - 1e3).abs() / 1e3 < 1e-12);
    }

    #[test]
    fn logspace_endpoints() {
        let v = logspace(1e3, 1e9, 7);
        assert_eq!(v.len(), 7);
        assert!((v[0] - 1e3).abs() / 1e3 < 1e-12);
        assert!((v[6] - 1e9).abs() / 1e9 < 1e-12);
        // each step is exactly one decade
        for w in v.windows(2) {
            assert!((w[1] / w[0] - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn geomean_of_decades() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn log10_pow10_roundtrip() {
        for x in [1e-12, 0.5, 1.0, 3.7e9] {
            assert!((pow10(log10(x)) - x).abs() / x < 1e-12);
        }
    }
}
