//! Default runtime backend: a stub compiled when the `pjrt` feature is
//! off. Keeps the whole `runtime` API surface (and everything layered on
//! it — CLI `--backend pjrt`, `PjrtEvaluator`, the integration tests and
//! benches) compiling with zero external dependencies; any attempt to
//! actually compile an artifact fails at *runtime* with a typed error.

use std::path::Path;

use crate::error::{Error, Result};

use super::Literal;

fn unavailable() -> Error {
    Error::Runtime(
        "cimdse was built without the `pjrt` feature; the PJRT backend is a stub \
         (rebuild with `cargo build --features pjrt`)"
            .to_string(),
    )
}

/// Stub executable — never successfully constructed.
pub struct BackendExecutable {
    _private: (),
}

/// Stub compile: always the typed runtime error.
pub fn compile(_path: &Path) -> Result<BackendExecutable> {
    Err(unavailable())
}

impl BackendExecutable {
    /// Unreachable in practice (compile never succeeds); total anyway.
    pub fn run_f32(&self, _inputs: &[Literal<'_>]) -> Result<Vec<f32>> {
        Err(unavailable())
    }

    /// Backend name for diagnostics.
    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }
}
