//! Real PJRT runtime backend (feature `pjrt`): compile HLO text with the
//! `xla` crate's parser (which reassigns instruction ids — the reason
//! text, not serialized protos, is the interchange format), load it on
//! the PJRT CPU client, and execute.
//!
//! Offline builds compile this against the vendored API shim in
//! `vendor/xla`; swap in the real xla bindings (same API) to execute.

use std::path::Path;

use crate::error::{Error, Result};

use super::Literal;

/// A compiled HLO executable on the CPU PJRT client.
pub struct BackendExecutable {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

/// Load HLO text from `path` and compile it on the CPU client.
pub fn compile(path: &Path) -> Result<BackendExecutable> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    Ok(BackendExecutable { client, exe })
}

/// Marshal a host literal into an `xla::Literal`.
///
/// Uses `create_from_shape_and_untyped_data` (one memcpy) rather than
/// `vec1(..).reshape(..)` (copy + reshape) — and the [`Literal`] borrows
/// the caller's marshalled buffer, so this single memcpy is the only
/// copy on the DSE batch marshalling hot path (EXPERIMENTS.md §Perf).
fn to_xla(lit: &Literal<'_>) -> Result<xla::Literal> {
    let dims: Vec<usize> = lit.shape().iter().map(|&d| d as usize).collect();
    let data = lit.data();
    // SAFETY: `data` is a valid, initialized `&[f32]`, so viewing the
    // same region as bytes of length `size_of_val(data)` stays in
    // bounds for the borrow's lifetime, and `u8` has no alignment or
    // validity requirements.
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &dims,
        bytes,
    )?)
}

impl BackendExecutable {
    /// Execute with the given inputs; returns the unwrapped 1-tuple root
    /// as a flat f32 vector.
    pub fn run_f32(&self, inputs: &[Literal<'_>]) -> Result<Vec<f32>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_xla).collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let out = result[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// The PJRT platform name (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
