//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! `make artifacts` lowers the Layer-2 JAX graphs (which call the Layer-1
//! Pallas kernels) to HLO *text*; this module loads that text with the
//! `xla` crate's parser (which reassigns instruction ids — the reason
//! text, not serialized protos, is the interchange format), compiles it
//! on the PJRT CPU client once, and exposes typed entry points:
//!
//! * [`AdcModelEngine`] — batched ADC-model evaluation for the DSE sweep.
//! * [`CimMlpEngine`] / [`CrossbarEngine`] — the functional CiM datapath.
//!
//! Python never runs on this path; the Rust binary is self-contained
//! once `artifacts/` exists.

pub mod engines;

pub use engines::{AdcModelEngine, CimMlpEngine, CrossbarEngine};

use std::path::{Path, PathBuf};

use crate::config::{Value, parse_json};
use crate::error::{Error, Result};

/// Parsed `artifacts/manifest.json` plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// Parsed manifest document.
    pub doc: Value,
}

impl Manifest {
    /// Load the manifest from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Ok(Manifest { dir: dir.to_path_buf(), doc: parse_json(&text)? })
    }

    /// Locate the artifact directory: `$CIMDSE_ARTIFACTS` or `./artifacts`
    /// relative to the current dir or the crate root.
    pub fn locate() -> Result<Manifest> {
        if let Ok(dir) = std::env::var("CIMDSE_ARTIFACTS") {
            return Manifest::load(Path::new(&dir));
        }
        let candidates = [
            PathBuf::from("artifacts"),
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ];
        for dir in &candidates {
            if dir.join("manifest.json").exists() {
                return Manifest::load(dir);
            }
        }
        Err(Error::Runtime(
            "artifacts/manifest.json not found; run `make artifacts` \
             or set CIMDSE_ARTIFACTS"
                .into(),
        ))
    }

    /// Full path of an artifact file referenced by manifest key
    /// (e.g. `"adc_model"`).
    pub fn artifact_path(&self, key: &str) -> Result<PathBuf> {
        let file = self.doc.require_str(&format!("{key}.file"))?;
        Ok(self.dir.join(file))
    }
}

/// A compiled HLO executable on the CPU PJRT client.
pub struct Executable {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Load HLO text from `path` and compile it.
    pub fn compile(path: &Path) -> Result<Executable> {
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Executable { client, exe })
    }

    /// Execute with the given input literals; returns the unwrapped
    /// 1-tuple root (aot.py lowers every graph with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let out = result[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?)
    }

    /// The PJRT platform name (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Build an f32 literal of the given shape from a flat slice.
///
/// Uses `create_from_shape_and_untyped_data` (one memcpy) rather than
/// `vec1(..).reshape(..)` (copy + reshape) — this is the DSE batch
/// marshalling hot path (EXPERIMENTS.md §Perf).
pub fn literal_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = shape.iter().product();
    if expect != data.len() as i64 {
        return Err(Error::Runtime(format!(
            "literal shape {shape:?} needs {expect} elements, got {}",
            data.len()
        )));
    }
    let dims: Vec<usize> = shape.iter().map(|&d| d as usize).collect();
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &dims,
        bytes,
    )?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_mismatch_is_error() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    #[test]
    fn manifest_missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
