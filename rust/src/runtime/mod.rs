//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! `make artifacts` lowers the Layer-2 JAX graphs (which call the Layer-1
//! Pallas kernels) to HLO *text*; this module loads that text, compiles
//! it on the PJRT CPU client once, and exposes typed entry points:
//!
//! * [`AdcModelEngine`] — batched ADC-model evaluation for the DSE sweep.
//! * [`CimMlpEngine`] / [`CrossbarEngine`] — the functional CiM datapath.
//!
//! Python never runs on this path; the Rust binary is self-contained
//! once `artifacts/` exists.
//!
//! ## Backends
//!
//! The actual HLO compile/execute step lives behind a backend selected at
//! build time by the `pjrt` cargo feature:
//!
//! * **default (feature off)** — `stub`: everything compiles, but
//!   [`Executable::compile`] returns a typed
//!   `Error::Runtime("... built without the `pjrt` feature ...")`, so
//!   callers (CLI `--backend pjrt`, integration tests, benches) degrade
//!   gracefully at runtime.
//! * **`--features pjrt`** — `pjrt`: the real path through the `xla`
//!   crate's PJRT CPU client (offline builds see the vendored API shim in
//!   `vendor/xla`; swap in the real bindings to execute).
//!
//! [`Manifest`], [`Literal`], and the engine types are backend-independent.

pub mod engines;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(feature = "pjrt")]
use pjrt as backend;
#[cfg(not(feature = "pjrt"))]
use stub as backend;

pub use engines::{AdcModelEngine, CimMlpEngine, CrossbarEngine};

use std::path::{Path, PathBuf};

use crate::config::{Value, parse_json};
use crate::error::{Error, Result};

/// Environment variable naming the artifact directory.
pub const ARTIFACTS_ENV: &str = "CIMDSE_ARTIFACTS";

/// Parsed `artifacts/manifest.json` plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// Parsed manifest document.
    pub doc: Value,
}

impl Manifest {
    /// Load the manifest from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Ok(Manifest { dir: dir.to_path_buf(), doc: parse_json(&text)? })
    }

    /// The artifact directories [`Manifest::locate`] will probe, in
    /// priority order: `$CIMDSE_ARTIFACTS`, `./artifacts` relative to the
    /// current dir, and `artifacts` under the crate root when the binary
    /// was built with `CARGO_MANIFEST_DIR` available (`option_env!`, so a
    /// build without it still resolves the first two).
    pub fn candidate_dirs() -> Vec<PathBuf> {
        let mut candidates = Vec::new();
        if let Ok(dir) = std::env::var(ARTIFACTS_ENV) {
            candidates.push(PathBuf::from(dir));
        }
        candidates.push(PathBuf::from("artifacts"));
        if let Some(root) = option_env!("CARGO_MANIFEST_DIR") {
            let dir = Path::new(root).join("artifacts");
            if !candidates.contains(&dir) {
                candidates.push(dir);
            }
        }
        candidates
    }

    /// Locate the artifact directory.
    ///
    /// `$CIMDSE_ARTIFACTS`, when set, is authoritative: it is loaded
    /// directly and a missing/unreadable manifest there fails loudly
    /// rather than silently falling through to a stale default
    /// directory. Otherwise the first of [`Manifest::candidate_dirs`]
    /// holding a `manifest.json` wins, and the error message names
    /// every candidate path tried.
    pub fn locate() -> Result<Manifest> {
        let candidates = Manifest::candidate_dirs();
        if std::env::var(ARTIFACTS_ENV).is_ok() {
            return Manifest::load(&candidates[0]);
        }
        for dir in &candidates {
            if dir.join("manifest.json").exists() {
                return Manifest::load(dir);
            }
        }
        let tried: Vec<String> = candidates
            .iter()
            .map(|p| p.join("manifest.json").display().to_string())
            .collect();
        Err(Error::Runtime(format!(
            "artifacts/manifest.json not found (tried: {}); run `make artifacts` \
             or set {ARTIFACTS_ENV}",
            tried.join(", ")
        )))
    }

    /// Full path of an artifact file referenced by manifest key
    /// (e.g. `"adc_model"`).
    pub fn artifact_path(&self, key: &str) -> Result<PathBuf> {
        let file = self.doc.require_str(&format!("{key}.file"))?;
        Ok(self.dir.join(file))
    }
}

/// A host-side f32 literal: *borrowed* flat data plus shape. The data
/// buffer stays wherever the caller marshalled it; the pjrt backend
/// copies the borrowed slice straight into an `xla::Literal`, so the DSE
/// batch-marshalling hot path is a single memcpy (EXPERIMENTS.md §Perf —
/// the interim owned `Literal` cost a second slice → `Vec` copy here).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal<'a> {
    data: &'a [f32],
    shape: Vec<i64>,
}

impl<'a> Literal<'a> {
    /// The flat element buffer.
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// The literal's shape (row-major dims).
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }
}

/// Build an f32 literal of the given shape borrowing a flat slice.
pub fn literal_f32<'a>(data: &'a [f32], shape: &[i64]) -> Result<Literal<'a>> {
    let expect: i64 = shape.iter().product();
    if expect != data.len() as i64 {
        return Err(Error::Runtime(format!(
            "literal shape {shape:?} needs {expect} elements, got {}",
            data.len()
        )));
    }
    Ok(Literal { data, shape: shape.to_vec() })
}

/// A compiled HLO executable on the PJRT backend.
pub struct Executable {
    inner: backend::BackendExecutable,
}

impl Executable {
    /// Load HLO text from `path` and compile it. Without the `pjrt`
    /// feature this returns `Error::Runtime` and nothing is compiled.
    pub fn compile(path: &Path) -> Result<Executable> {
        Ok(Executable { inner: backend::compile(path)? })
    }

    /// Execute with the given input literals and return the flattened f32
    /// output (the unwrapped 1-tuple root — aot.py lowers every graph
    /// with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[Literal<'_>]) -> Result<Vec<f32>> {
        self.inner.run_f32(inputs)
    }

    /// The PJRT platform name (for diagnostics).
    pub fn platform(&self) -> String {
        self.inner.platform()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_mismatch_is_error() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    #[test]
    fn literal_exposes_data_and_shape() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.shape(), &[2, 2]);
    }

    #[test]
    fn manifest_missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn candidate_dirs_always_include_cwd_artifacts() {
        let candidates = Manifest::candidate_dirs();
        assert!(!candidates.is_empty());
        assert!(
            candidates.iter().any(|p| p == Path::new("artifacts")),
            "{candidates:?}"
        );
    }

    #[test]
    fn locate_error_names_all_candidates() {
        // With no artifacts built, locate must fail and its message must
        // name every candidate manifest path plus the env-var escape hatch.
        if std::env::var(ARTIFACTS_ENV).is_ok() {
            return; // env override active: locate reports only that path
        }
        match Manifest::locate() {
            Ok(_) => {} // artifacts exist in this checkout: nothing to assert
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains(ARTIFACTS_ENV), "{msg}");
                for dir in Manifest::candidate_dirs() {
                    let shown = dir.join("manifest.json").display().to_string();
                    assert!(msg.contains(&shown), "missing `{shown}` in `{msg}`");
                }
            }
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_backend_errors_with_typed_message() {
        let err = Executable::compile(Path::new("whatever.hlo.txt"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("built without the `pjrt` feature"), "{err}");
    }
}
