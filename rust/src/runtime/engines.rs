//! Typed entry points over the compiled artifacts.

use crate::adc::{AdcMetrics, AdcQuery, Coefficients};
use crate::error::{Error, Result};
use crate::util::logspace::log10;

use super::{Executable, Manifest, literal_f32};

/// Batched ADC-model evaluation through `adc_model.hlo.txt`.
///
/// The artifact computes the same math as [`crate::adc::AdcModel`] (the
/// Pallas kernel and the native path share the coefficient layout), at a
/// fixed compile-time batch; partial batches are padded and sliced.
pub struct AdcModelEngine {
    exe: Executable,
    batch: usize,
    n_params: usize,
    n_metrics: usize,
}

impl AdcModelEngine {
    /// Compile the engine from located artifacts.
    pub fn load(manifest: &Manifest) -> Result<AdcModelEngine> {
        let exe = Executable::compile(&manifest.artifact_path("adc_model")?)?;
        Ok(AdcModelEngine {
            exe,
            batch: manifest.doc.require_usize("adc_model.batch")?,
            n_params: manifest.doc.require_usize("adc_model.n_params")?,
            n_metrics: manifest.doc.require_usize("adc_model.n_metrics")?,
        })
    }

    /// Compile-time batch size of the artifact.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Evaluate a slice of queries, padding the tail batch.
    pub fn eval(&self, queries: &[AdcQuery], coefs: &Coefficients) -> Result<Vec<AdcMetrics>> {
        let coefs_vec = coefs.to_f32_vec();
        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(self.batch) {
            let mut flat = Vec::with_capacity(self.batch * self.n_params);
            for q in chunk {
                flat.push(q.enob as f32);
                flat.push(log10(q.throughput_per_adc()) as f32);
                flat.push(log10(q.tech_nm / 32.0) as f32);
                flat.push(q.n_adcs as f32);
            }
            // Pad with a copy of the last query (benign values).
            let pad = [
                flat[flat.len() - 4],
                flat[flat.len() - 3],
                flat[flat.len() - 2],
                flat[flat.len() - 1],
            ];
            while flat.len() < self.batch * self.n_params {
                flat.extend_from_slice(&pad);
            }
            let params =
                literal_f32(&flat, &[self.batch as i64, self.n_params as i64])?;
            let coefs_lit = literal_f32(&coefs_vec, &[coefs_vec.len() as i64])?;
            let values = self.exe.run_f32(&[params, coefs_lit])?;
            if values.len() != self.batch * self.n_metrics {
                return Err(Error::Runtime(format!(
                    "adc_model artifact returned {} values, expected {}",
                    values.len(),
                    self.batch * self.n_metrics
                )));
            }
            for row in values.chunks(self.n_metrics).take(chunk.len()) {
                out.push(AdcMetrics {
                    energy_pj_per_convert: row[0] as f64,
                    area_um2_per_adc: row[1] as f64,
                    total_power_w: row[2] as f64,
                    total_area_um2: row[3] as f64,
                });
            }
        }
        Ok(out)
    }
}

/// Single CiM crossbar layer through `crossbar.hlo.txt`.
pub struct CrossbarEngine {
    exe: Executable,
    /// (batch, in_dim, out_dim) compile-time shape.
    pub shape: (usize, usize, usize),
    /// Analog sum size baked into the artifact.
    pub n_sum: usize,
}

impl CrossbarEngine {
    /// Compile the engine from located artifacts.
    pub fn load(manifest: &Manifest) -> Result<CrossbarEngine> {
        let exe = Executable::compile(&manifest.artifact_path("crossbar")?)?;
        Ok(CrossbarEngine {
            exe,
            shape: (
                manifest.doc.require_usize("crossbar.batch")?,
                manifest.doc.require_usize("crossbar.in_dim")?,
                manifest.doc.require_usize("crossbar.out_dim")?,
            ),
            n_sum: manifest.doc.require_usize("crossbar.n_sum")?,
        })
    }

    /// Run `y = cim_matmul(x, w; adc_step)`; shapes must match the artifact.
    pub fn run(&self, x: &[f32], w: &[f32], adc_step: f32) -> Result<Vec<f32>> {
        let (b, i, o) = self.shape;
        // Literals borrow their buffers: scalars need named storage that
        // outlives the execute call.
        let step_buf = [adc_step];
        let x_lit = literal_f32(x, &[b as i64, i as i64])?;
        let w_lit = literal_f32(w, &[i as i64, o as i64])?;
        let step = literal_f32(&step_buf, &[1])?;
        self.exe.run_f32(&[x_lit, w_lit, step])
    }
}

/// Two-layer CiM MLP through `cim_mlp.hlo.txt`.
pub struct CimMlpEngine {
    exe: Executable,
    /// (batch, in, hidden, out) compile-time shape.
    pub shape: (usize, usize, usize, usize),
}

impl CimMlpEngine {
    /// Compile the engine from located artifacts.
    pub fn load(manifest: &Manifest) -> Result<CimMlpEngine> {
        let exe = Executable::compile(&manifest.artifact_path("cim_mlp")?)?;
        Ok(CimMlpEngine {
            exe,
            shape: (
                manifest.doc.require_usize("cim_mlp.batch")?,
                manifest.doc.require_usize("cim_mlp.in_dim")?,
                manifest.doc.require_usize("cim_mlp.hidden_dim")?,
                manifest.doc.require_usize("cim_mlp.out_dim")?,
            ),
        })
    }

    /// Forward pass: returns logits `[batch, out]` flattened.
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &self,
        x: &[f32],
        w1: &[f32],
        w2: &[f32],
        step1: f32,
        step2: f32,
        scale1: f32,
    ) -> Result<Vec<f32>> {
        let (b, i, h, o) = self.shape;
        // Literals borrow their buffers: scalars need named storage that
        // outlives the execute call.
        let (step1_buf, step2_buf, scale1_buf) = ([step1], [step2], [scale1]);
        let inputs = [
            literal_f32(x, &[b as i64, i as i64])?,
            literal_f32(w1, &[i as i64, h as i64])?,
            literal_f32(w2, &[h as i64, o as i64])?,
            literal_f32(&step1_buf, &[1])?,
            literal_f32(&step2_buf, &[1])?,
            literal_f32(&scale1_buf, &[1])?,
        ];
        self.exe.run_f32(&inputs)
    }
}
