//! Minimal quickcheck-style property testing substrate.
//!
//! The offline registry has no `proptest`/`quickcheck`, so this module
//! provides what the crate's invariant tests need: an [`Arbitrary`] trait
//! (generate + shrink), a [`check`] runner that reports the minimal
//! shrunk counterexample, and a [`quickcheck`] driver for typed properties.
//!
//! ```no_run
//! # // no_run: doctest binaries skip the crate's rpath to libxla_extension.
//! use cimdse::testing::{check, Config};
//! check(Config::default().cases(200), |rng| {
//!     let x = rng.uniform(0.0, 1e6);
//!     assert!(x >= 0.0);
//! });
//! ```

use crate::util::Rng;

/// Property test configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, base_seed: 0xC1_3D5E }
    }
}

impl Config {
    /// Set the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Set the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }
}

/// Run `property` over `config.cases` deterministic seeds; panics (with the
/// failing seed) on the first violated case so the failure is reproducible
/// by rerunning with that seed.
pub fn check<F: Fn(&mut Rng)>(config: Config, property: F) {
    for i in 0..config.cases {
        let seed = config.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {i} (seed={seed}): {msg}");
        }
    }
}

/// Values that can be generated and shrunk toward simpler counterexamples.
pub trait Arbitrary: Sized + Clone + std::fmt::Debug {
    /// Generate a random value.
    fn arbitrary(rng: &mut Rng) -> Self;
    /// Candidate simplifications (smaller magnitude / shorter).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Arbitrary for u64 {
    fn arbitrary(rng: &mut Rng) -> Self {
        // Bias toward small values: interesting edge cases live there.
        match rng.index(4) {
            0 => rng.range(0, 16),
            1 => rng.range(0, 1 << 12),
            2 => rng.range(0, 1 << 32),
            _ => rng.next_u64(),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Arbitrary for usize {
    fn arbitrary(rng: &mut Rng) -> Self {
        (u64::arbitrary(rng) % (usize::MAX as u64)) as usize
    }

    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Arbitrary for f64 {
    fn arbitrary(rng: &mut Rng) -> Self {
        match rng.index(5) {
            0 => 0.0,
            1 => rng.uniform(-1.0, 1.0),
            2 => rng.uniform(-1e6, 1e6),
            3 => rng.log10_normal(0.0, 3.0),
            _ => -rng.log10_normal(0.0, 3.0),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            out.push(self.trunc());
        }
        out
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn arbitrary(rng: &mut Rng) -> Self {
        let len = rng.index(17);
        (0..len).map(|_| T::arbitrary(rng)).collect()
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(Vec::new());
            out.push(self[..self.len() / 2].to_vec());
            let mut minus_first = self.clone();
            minus_first.remove(0);
            out.push(minus_first);
        }
        out
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn arbitrary(rng: &mut Rng) -> Self {
        (A::arbitrary(rng), B::arbitrary(rng))
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// quickcheck-style driver: generate `cases` values of `A`, run the
/// predicate, and on failure greedily shrink to a minimal counterexample.
pub fn quickcheck<A: Arbitrary, F: Fn(&A) -> bool>(cases: usize, seed: u64, prop: F) {
    for i in 0..cases {
        let mut rng = Rng::new(seed.wrapping_add(i as u64));
        let value = A::arbitrary(&mut rng);
        if !prop(&value) {
            let minimal = shrink_to_minimal(value, &prop);
            panic!("property failed; minimal counterexample: {minimal:?} (seed={})",
                   seed.wrapping_add(i as u64));
        }
    }
}

fn shrink_to_minimal<A: Arbitrary, F: Fn(&A) -> bool>(mut failing: A, prop: &F) -> A {
    // Greedy descent: repeatedly take the first shrink candidate that still fails.
    loop {
        let mut improved = false;
        for candidate in failing.shrink() {
            if !prop(&candidate) {
                failing = candidate;
                improved = true;
                break;
            }
        }
        if !improved {
            return failing;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0usize;
        // Count via a RefCell-free trick: the closure is Fn, so count by seed
        // side channel — simplest is just to run and rely on no panic.
        check(Config::default().cases(50), |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(Config::default().cases(50), |rng| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn quickcheck_passes_true_property() {
        quickcheck::<u64, _>(200, 1, |x| x.wrapping_add(0) == *x);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property "x < 100" fails for large x; shrinker should descend
        // to a value not much above the boundary.
        let res = std::panic::catch_unwind(|| {
            quickcheck::<u64, _>(500, 3, |x| *x < 100);
        });
        let msg = format!("{:?}", res.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("minimal counterexample"), "{msg}");
    }

    #[test]
    fn vec_shrink_shortens() {
        let v = vec![1u64, 2, 3, 4];
        let shrunk = v.shrink();
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }
}
