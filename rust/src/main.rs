//! `cimdse` — the command-line front end.
//!
//! Subcommands mirror the paper's pipeline:
//!
//! * `fit`     — synthesize the survey, fit the model, report coefficients.
//! * `model`   — evaluate one ADC design point (optionally tuned).
//! * `sweep`   — DSE over a design-point grid (native or PJRT backend);
//!   `--shard i/N` runs one index sub-range to a resumable JSON artifact;
//!   `--workers host:port,...` schedules every shard across `serve`
//!   daemons (retrying/reassigning on worker failure) and merges
//!   bit-identically to the single-process run.
//! * `merge-shards` — merge shard artifacts bit-identically to the
//!   single-process streaming sweep.
//! * `map`     — map a workload onto a RAELLA variant, report energy/area.
//! * `figures` — regenerate the paper's Figs. 2–5.
//! * `bench-report` — validate/summarize a `BENCH_*.json` perf artifact.
//! * `serve`   — long-lived daemon speaking the newline-delimited JSON
//!   protocol (rust/docs/protocol.md): prepared-model cache, shared
//!   persistent pool, graceful drain.
//! * `query`   — client for the daemon (`eval`/`sweep`/`accel`/
//!   `metrics`/`shutdown`); output matches the direct subcommands so
//!   served results can be diffed against library ones.
//! * `trace`   — analyze an NDJSON trace captured with `--trace-out`
//!   (per-op latency, per-process timeline, cross-process critical
//!   path; see rust/docs/observability.md).

use cimdse::adc::{AdcModel, AdcQuery, fit_model, tuning::TuningPoint};
use cimdse::arch::raella::{RaellaVariant, raella};
use cimdse::cli::Args;
use cimdse::dse::{
    NativeEvaluator, ObjectiveSet, PjrtEvaluator, ShardArtifact, ShardPlan, ShardSelector,
    SnrContext, SweepSpec, SweepSummary, SweepTier, figures, merge_shards, pareto_front,
    pareto_front_k, run_sweep, run_sweep_prepared_tier, sweep_fingerprint_with,
};
use cimdse::energy::{AreaScope, accel_area, layer_energy, workload_energy};
use cimdse::report::Table;
use cimdse::runtime::{AdcModelEngine, Manifest};
use cimdse::survey::generator::{SurveyConfig, generate_survey};
use cimdse::util::units::{fmt_area_um2, fmt_energy_pj, fmt_power_w, fmt_throughput};

use cimdse::{Error, Result};

const USAGE: &str = "\
cimdse — ADC energy/area modeling for CiM design-space exploration

USAGE: cimdse <subcommand> [options]

SUBCOMMANDS
  fit      [--n 700] [--seed 1997] [--csv PATH]
           [--survey-csv PATH]                    fit the model to a survey
  model    --enob B --throughput F [--tech 32] [--n-adcs 1]
           [--tune-energy PJ] [--tune-area UM2]   evaluate one design point
  estimate --class adc --resolution B --throughput F [...]
                                                  Accelergy-style plug-in query
  sweep    [--backend native|pjrt] [--spec dense|fig5] [--points 12]
           [--enob 7] [--tsteps 12]               dense DSE + Pareto front
           [--objectives power,area|energy,area,snr]
           [--snr-sum 512] [--snr-cell-bits 2]    energy,area,snr adds the compute-SNR
                                                  objective (rust/docs/snr_metric.md)
                                                  to the front; composes with
                                                  --summary-json / --shard / --workers
                                                  (classic power,area outputs are
                                                  byte-identical to omitting the flag)
           [--tier exact|fast]                    fast = lane-batched polynomial
                                                  kernel, ULP-bounded vs exact
                                                  (rust/docs/numeric_tiers.md);
                                                  incompatible with fingerprinted
                                                  outputs (--shard/--workers/
                                                  --summary-json)
           [--summary-json PATH]                  streamed fold/min-EAP/front summary
           [--shard i/N] [--out shard_i.json]     run one shard to a resumable artifact
           [--workers HOST:PORT,... [--shards N]
            [--out DIR] [--timeout-ms 60000]
            [--launch-json PATH]
            [--trace-out FILE]]                   distributed sweep over serve daemons
                                                  (resumable; summary byte-identical
                                                  to the single-process run; the
                                                  timeout bounds the gap between
                                                  frames, not compute — v2 workers
                                                  heartbeat while busy; 0 = wait
                                                  forever)
  merge-shards FILE... [--out merged.json]
           [--allow-partial]                      merge shard artifacts (bit-identical
                                                  to the single-process sweep)
  map      [--arch s|m|l|xl] [--arch-file TOML]
           [--workload resnet18|vgg16|lenet] [--workload-file TOML]
           [--layer NAME]                         map a DNN onto a CiM arch
  explore  [--workload NAME]                      accelerator-level DSE
  survey   [--n 700] [--seed 1997]                survey analytics (FoM trends)
  figures  [--fig 2|3|4|5|all]                    regenerate paper figures
  bench-report --path BENCH_sweep.json            validate + summarize a perf artifact
  serve    [--addr 127.0.0.1:0] [--cache 32]
           [--n 700] [--seed 1997]
           [--core event-loop|threads]            long-lived serving daemon (NDJSON
           [--max-sweep-points N]                 protocol v2; see rust/docs/protocol.md);
           [--progress-every N]                   sweep/shard requests over the point
           [--trace-out FILE]                     budget get a typed `over-budget`
                                                  error; --progress-every streams a
                                                  progress frame every N points to
                                                  v2 clients (event-loop core);
                                                  --trace-out records NDJSON spans
                                                  (rust/docs/observability.md)
  query    --addr HOST:PORT --op eval|sweep|accel|metrics|shutdown
           [eval: --enob B --throughput F --tech 32 --n-adcs 1]
           [sweep: --spec dense|fig5 --points N --out PATH
                   --objectives ... --snr-sum N --snr-cell-bits B]
           [accel: --workload NAME]
           [metrics: --format text|prometheus]    query a running daemon
  trace    FILE                                   analyze an NDJSON trace (--trace-out):
                                                  per-op latency, per-process timeline,
                                                  cross-process critical path
  lint     [PATH] [--json]                        static invariant checks over a crate
                                                  tree (default PATH: .); exits 1 on
                                                  findings (rules: rust/docs/lints.md)
";

/// Boolean flags across all subcommands: declaring them keeps the parser
/// from consuming a following positional as the flag's "value".
const BOOLEAN_FLAGS: &[&str] = &["allow-partial", "json"];

fn main() {
    let args = match Args::parse_with_flags(std::env::args().skip(1), BOOLEAN_FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("fit") => cmd_fit(&args),
        Some("model") => cmd_model(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("merge-shards") => cmd_merge_shards(&args),
        Some("map") => cmd_map(&args),
        Some("explore") => cmd_explore(&args),
        Some("survey") => cmd_survey(&args),
        Some("figures") => cmd_figures(&args),
        Some("bench-report") => cmd_bench_report(&args),
        Some("serve") => cmd_serve(&args),
        Some("query") => cmd_query(&args),
        Some("trace") => cmd_trace(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Model fitted to a fresh synthetic survey (the default model source).
fn fitted_model(n: usize, seed: u64) -> Result<AdcModel> {
    let survey = generate_survey(&SurveyConfig {
        n_records: n,
        seed,
        ..SurveyConfig::default()
    });
    Ok(AdcModel::new(fit_model(&survey)?.coefs))
}

fn cmd_fit(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 700)?;
    let seed = args.u64_or("seed", 1997)?;
    // Real-survey drop-in: --survey-csv fits user-provided data instead of
    // the synthetic survey.
    let survey = match args.opt("survey-csv") {
        Some(path) => {
            println!("loading survey from {path}");
            cimdse::survey::load_survey_csv(path)?
        }
        None => generate_survey(&SurveyConfig { n_records: n, seed, ..SurveyConfig::default() }),
    };
    if let Some(path) = args.opt("csv") {
        std::fs::write(path, survey.to_csv())?;
        println!("wrote survey CSV to {path}");
    }
    let report = fit_model(&survey)?;
    println!("fit over {} survey records (seed {seed})\n", report.n_records);

    let truth = cimdse::adc::Coefficients::generator_truth();
    let mut t = Table::new(vec!["coefficient", "fitted", "generator truth"]);
    let fitted = report.coefs.to_vec();
    let names = ["a0", "a1", "a2", "b0", "b1", "b2", "b3", "d0", "d1", "d2", "d3"];
    for (i, name) in names.iter().enumerate() {
        t.row(vec![
            name.to_string(),
            format!("{:+.4}", fitted[i]),
            format!("{:+.4}", truth.to_vec()[i]),
        ]);
    }
    println!("{}", t.render());
    println!(
        "energy fit: {} EM iterations, {:.0}% of points in tradeoff segment",
        report.energy_fit.iterations,
        100.0 * report.energy_fit.trade_fraction
    );
    println!(
        "area regression: r = {:.3} with energy predictor vs r = {:.3} with ENOB \
         (paper: 0.75 vs 0.66)",
        report.area_r_energy, report.area_r_enob
    );
    Ok(())
}

/// `--n-adcs` as a u32, rejecting values a plain `as` cast would
/// silently truncate (the wire and artifact parsers both enforce the
/// same bound).
fn n_adcs_arg(args: &Args) -> Result<u32> {
    let n = args.usize_or("n-adcs", 1)?;
    u32::try_from(n).map_err(|_| Error::Config(format!("--n-adcs {n} exceeds u32")))
}

fn cmd_model(args: &Args) -> Result<()> {
    let enob = args.f64_or("enob", 8.0)?;
    let throughput = args.f64_or("throughput", 1e9)?;
    let tech_nm = args.f64_or("tech", 32.0)?;
    let n_adcs = n_adcs_arg(args)?;
    let query = AdcQuery { enob, total_throughput: throughput, tech_nm, n_adcs };
    query.validate()?;

    let mut model = fitted_model(args.usize_or("n", 700)?, args.u64_or("seed", 1997)?)?;
    if let Some(e) = args.opt("tune-energy") {
        let energy: f64 = e
            .parse()
            .map_err(|_| Error::Config(format!("--tune-energy: bad number `{e}`")))?;
        let area = match args.opt("tune-area") {
            Some(a) => Some(a.parse().map_err(|_| {
                Error::Config(format!("--tune-area: bad number `{a}`"))
            })?),
            None => None,
        };
        model = model.tuned_to(&TuningPoint {
            query,
            energy_pj_per_convert: energy,
            area_um2: area,
        });
        println!("(model tuned to the given reference point)");
    }

    let m = model.eval(&query);
    print_model_point(&query, &m, model.crossover_throughput(enob, tech_nm));
    Ok(())
}

/// The `model` subcommand's output block — shared with `query --op eval`
/// so a served evaluation can be `diff`ed against the direct one
/// (ci.sh's serve smoke test does exactly that).
fn print_model_point(query: &AdcQuery, m: &cimdse::adc::AdcMetrics, crossover: f64) {
    let AdcQuery { enob, total_throughput, tech_nm, n_adcs } = *query;
    println!("ADC design point:");
    println!("  ENOB             {enob}");
    println!("  total throughput {}", fmt_throughput(total_throughput));
    println!("  tech node        {tech_nm} nm");
    println!(
        "  n ADCs           {n_adcs}  (per-ADC {})",
        fmt_throughput(query.throughput_per_adc())
    );
    println!();
    println!("  energy/convert   {}", fmt_energy_pj(m.energy_pj_per_convert));
    println!("  area per ADC     {}", fmt_area_um2(m.area_um2_per_adc));
    println!("  total power      {}", fmt_power_w(m.total_power_w));
    println!("  total area       {}", fmt_area_um2(m.total_area_um2));
    println!(
        "  energy knee      {} (tradeoff bound beyond this)",
        fmt_throughput(crossover)
    );
}

/// The sweep grid selected on the command line. Shard processes of one
/// sweep must pass identical `--spec`-family and `--n`/`--seed` flags;
/// the artifact fingerprint catches any accidental divergence at merge
/// time.
fn sweep_spec_from_args(args: &Args) -> Result<SweepSpec> {
    match args.opt_or("spec", "dense") {
        "dense" => {
            let points = args.usize_or("points", 12)?;
            // 1 is a well-defined degenerate axis (linspace/logspace
            // collapse to the lower bound); only 0 is meaningless.
            if points < 1 {
                return Err(Error::Config("--points must be >= 1".into()));
            }
            Ok(SweepSpec::dense(points))
        }
        "fig5" => {
            let tsteps = args.usize_or("tsteps", 12)?;
            if tsteps < 1 {
                return Err(Error::Config("--tsteps must be >= 1".into()));
            }
            Ok(SweepSpec::fig5(args.f64_or("enob", 7.0)?, tsteps))
        }
        other => Err(Error::Config(format!("unknown sweep spec `{other}` (dense|fig5)"))),
    }
}

/// The sweep's objective set from `--objectives` (absent means the
/// classic `power,area` pair) plus the compute-SNR context knobs.
/// `--snr-sum`/`--snr-cell-bits` are rejected without the tri-objective
/// set — a silently ignored flag would make the printed sweep look like
/// a different one than actually ran.
fn snr_context_from_args(args: &Args) -> Result<Option<SnrContext>> {
    let set = match args.opt("objectives") {
        Some(csv) => ObjectiveSet::parse_csv(csv)?,
        None => ObjectiveSet::PowerArea,
    };
    if set == ObjectiveSet::PowerArea {
        for flag in ["snr-sum", "snr-cell-bits"] {
            if args.opt(flag).is_some() {
                return Err(Error::Config(format!(
                    "--{flag} requires `--objectives energy,area,snr`"
                )));
            }
        }
        return Ok(None);
    }
    let defaults = SnrContext::default();
    let bits = args.usize_or("snr-cell-bits", defaults.cell_bits as usize)?;
    let ctx = SnrContext {
        n_sum: args.usize_or("snr-sum", defaults.n_sum)?,
        cell_bits: u32::try_from(bits)
            .map_err(|_| Error::Config(format!("--snr-cell-bits {bits} exceeds u32")))?,
    };
    ctx.validate()?;
    Ok(Some(ctx))
}

/// Human summary of a streamed sweep rollup (shared by `--summary-json`
/// and `merge-shards`).
fn print_sweep_summary(spec: &SweepSpec, summary: &SweepSummary) {
    println!(
        "  grid: {} ENOBs x {} throughputs x {} nodes x {} ADC counts = {} points \
         ({} evaluated)",
        spec.enobs.len(),
        spec.total_throughputs.len(),
        spec.tech_nms.len(),
        spec.n_adcs.len(),
        spec.len(),
        summary.count()
    );
    match summary.min_eap() {
        None => println!("  (no points evaluated)"),
        Some(p) => println!(
            "  min-EAP point: ENOB {:.1}, {} total, {} nm, {} ADCs -> {}/convert, {} total",
            p.query.enob,
            fmt_throughput(p.query.total_throughput),
            p.query.tech_nm,
            p.query.n_adcs,
            fmt_energy_pj(p.metrics.energy_pj_per_convert),
            fmt_area_um2(p.metrics.total_area_um2),
        ),
    }
    println!("  power-area Pareto front: {} points", summary.front().len());
    if let Some((ctx, front)) = summary.snr_context().zip(summary.snr_front()) {
        println!(
            "  energy-area-SNR Pareto front: {} points (n_sum {}, cell bits {})",
            front.len(),
            ctx.n_sum,
            ctx.cell_bits
        );
    }
    if let Some(e) = summary.extrema() {
        println!(
            "  energy/convert range: {} .. {}",
            fmt_energy_pj(e.min[0]),
            fmt_energy_pj(e.max[0])
        );
    }
}

/// Shard mode of `sweep`: run one planned index sub-range to an artifact,
/// skipping work whose artifact is already on disk (resume).
fn cmd_sweep_shard(
    args: &Args,
    spec: &SweepSpec,
    model: &AdcModel,
    shard_spec: &str,
    snr: Option<SnrContext>,
) -> Result<()> {
    if args.opt_or("backend", "native") != "native" {
        return Err(Error::Config(
            "--shard runs on the native streaming backend only".into(),
        ));
    }
    let selector = ShardSelector::parse(shard_spec)?;
    let plan = ShardPlan::new(spec, selector.n_shards())?;
    let range = plan.range(selector.index());
    // Objective-aware: a tri-objective shard can never be confused with
    // (or resumed from) a classic artifact of the same grid.
    let fingerprint = sweep_fingerprint_with(spec, model, snr.as_ref());
    let out = match args.opt("out") {
        Some(p) => p.to_string(),
        None => cimdse::dse::shard_artifact_file_name(selector.index()),
    };
    if ShardArtifact::load_if_complete(&out, &fingerprint, &range).is_some() {
        println!(
            "shard {selector}: {out} already complete (fingerprint {fingerprint}, points \
             [{}..{})); skipping",
            range.start, range.end
        );
        return Ok(());
    }
    let artifact =
        ShardArtifact::compute_with(spec, model, selector, cimdse::exec::default_workers(), snr)?;
    artifact.write(&out)?;
    println!(
        "shard {selector}: evaluated {} of {} grid points [{}..{}) -> {out} (fingerprint \
         {fingerprint})",
        artifact.summary().count(),
        plan.len(),
        range.start,
        range.end
    );
    Ok(())
}

/// Distributed mode of `sweep`: schedule the grid's shards across a
/// fleet of `cimdse serve` daemons and merge the artifacts. The merged
/// summary (and any `--summary-json` file) is byte-identical to the
/// single-process `sweep --summary-json` over the same spec and model:
/// the launcher sends this process's fitted model with every `shard`
/// request, shard artifacts are bit-exact, and the merge is
/// order-independent — so which worker computed what can never leak
/// into the result.
fn cmd_sweep_workers(
    args: &Args,
    spec: &SweepSpec,
    model: &AdcModel,
    workers: &str,
    snr: Option<SnrContext>,
) -> Result<()> {
    use cimdse::service::{LaunchOptions, run_distributed_sweep};
    if args.opt_or("backend", "native") != "native" {
        return Err(Error::Config(
            "--workers runs on the native streaming backend only (each worker daemon \
             evaluates natively)"
                .into(),
        ));
    }
    let addrs: Vec<String> = workers
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if addrs.is_empty() {
        return Err(Error::Config(
            "--workers needs at least one host:port address (comma-separated)".into(),
        ));
    }
    // Default: 4 shards per worker — enough slack for the queue to
    // balance uneven workers, small enough that redoing a lost shard is
    // cheap. Resume requires re-running with the same shard count (the
    // planned ranges must match the artifacts on disk).
    let n_shards = args.usize_or("shards", 4 * addrs.len())?;
    if n_shards == 0 {
        return Err(Error::Config("--shards must be >= 1".into()));
    }
    let timeout_ms = args.u64_or("timeout-ms", 60_000)?;
    let mut options = LaunchOptions::new(addrs, n_shards);
    options.read_timeout =
        (timeout_ms > 0).then(|| std::time::Duration::from_millis(timeout_ms));
    options.out_dir = args.opt("out").map(std::path::PathBuf::from);
    options.snr = snr;
    if let Some(path) = args.opt("trace-out") {
        // The launcher's own spans (launch root + per-shard leases);
        // workers started with their own --trace-out record the linked
        // server-side spans, and `cimdse trace` joins the concatenation.
        cimdse::obs::init_file(path, "launcher")?;
    }
    let report = run_distributed_sweep(spec, model, &options)?;
    println!(
        "distributed sweep: {} shards over {} workers ({} computed, {} resumed, {} \
         reassignments; fingerprint {})",
        report.n_shards,
        report.workers.len(),
        report.computed,
        report.resumed,
        report.retries,
        report.merged.fingerprint
    );
    for w in &report.workers {
        let latency = match (w.latency_quantile_s(0.50), w.latency_quantile_s(0.99)) {
            (Some(p50), Some(p99)) => format!(
                "shard latency p50 {}  p99 {}",
                cimdse::bench_util::fmt_secs(p50),
                cimdse::bench_util::fmt_secs(p99)
            ),
            _ => "no shards completed".to_string(),
        };
        println!(
            "  worker {:<21}  {} shards, {} failures{}  {latency}",
            w.addr,
            w.shards_served,
            w.failures,
            if w.retired { " (retired)," } else { "," }
        );
    }
    print_sweep_summary(spec, &report.merged.summary);
    if let Some(path) = args.opt("summary-json") {
        // The canonical summary only — byte-identical to the
        // single-process `sweep --summary-json` (launcher observability
        // goes to stdout / --launch-json, never into this file).
        std::fs::write(path, report.merged.summary.to_json_string()? + "\n")?;
        println!("wrote distributed sweep summary to {path}");
    }
    if let Some(path) = args.opt("launch-json") {
        std::fs::write(path, report.to_value().to_json_string()? + "\n")?;
        println!("wrote launch report to {path}");
    }
    Ok(())
}

fn cmd_merge_shards(args: &Args) -> Result<()> {
    // `--allow-partial` is a declared boolean flag (`BOOLEAN_FLAGS`), so
    // flag-first invocations cannot swallow a following file path.
    let files = args.positionals();
    if files.is_empty() {
        return Err(Error::Config(
            "merge-shards needs at least one shard artifact path".into(),
        ));
    }
    let artifacts = files
        .iter()
        .map(|p| ShardArtifact::load(p))
        .collect::<Result<Vec<_>>>()?;
    let merged = merge_shards(&artifacts)?;
    if !merged.is_complete() && !args.flag("allow-partial") {
        let gaps: Vec<String> = merged
            .missing
            .iter()
            .map(|r| format!("{}..{}", r.start, r.end))
            .collect();
        return Err(Error::Config(format!(
            "merged shards cover {} of {} grid points (missing index ranges: {}); re-run \
             the missing shards or pass --allow-partial",
            merged.covered,
            merged.total,
            gaps.join(", ")
        )));
    }
    println!(
        "merged {} shard artifact(s): {}/{} grid points (fingerprint {})",
        artifacts.len(),
        merged.covered,
        merged.total,
        merged.fingerprint
    );
    print_sweep_summary(&merged.spec, &merged.summary);
    if let Some(path) = args.opt("out") {
        std::fs::write(path, merged.summary.to_json_string()? + "\n")?;
        println!("wrote merged summary to {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = fitted_model(args.usize_or("n", 700)?, args.u64_or("seed", 1997)?)?;
    let spec = sweep_spec_from_args(args)?;
    let snr = snr_context_from_args(args)?;
    let tier = match args.opt("tier") {
        Some(name) => SweepTier::parse(name)?,
        None => SweepTier::Exact,
    };
    if tier == SweepTier::Fast {
        // Fingerprinted / byte-pinned outputs always run the exact tier
        // (mirrors the --shard/--summary-json mutual exclusion below).
        if args.opt("shard").is_some() {
            return Err(Error::Config(
                "--tier fast and --shard are mutually exclusive (shard artifacts are \
                 fingerprinted bit-exact outputs; shards always run the exact tier)"
                    .into(),
            ));
        }
        if args.opt("workers").is_some() {
            return Err(Error::Config(
                "--tier fast and --workers are mutually exclusive (distributed shard \
                 artifacts and their merged summary are fingerprinted bit-exact outputs)"
                    .into(),
            ));
        }
        if args.opt("summary-json").is_some() {
            return Err(Error::Config(
                "--tier fast and --summary-json are mutually exclusive (the summary is \
                 byte-identical to shard merges and served sweeps, so it always runs \
                 the exact tier)"
                    .into(),
            ));
        }
        if args.opt_or("backend", "native") != "native" {
            return Err(Error::Config(
                "--tier fast runs on the native backend only".into(),
            ));
        }
    }
    if let Some(shard_spec) = args.opt("shard") {
        if args.opt("workers").is_some() {
            return Err(Error::Config(
                "--shard and --workers are mutually exclusive (--shard runs one \
                 sub-range in this process; --workers schedules every shard across \
                 serving daemons)"
                    .into(),
            ));
        }
        if args.opt("summary-json").is_some() {
            return Err(Error::Config(
                "--shard and --summary-json are mutually exclusive (a shard writes its \
                 artifact to --out; merge artifacts with `merge-shards --out`)"
                    .into(),
            ));
        }
        return cmd_sweep_shard(args, &spec, &model, shard_spec, snr);
    }
    if let Some(workers) = args.opt("workers") {
        return cmd_sweep_workers(args, &spec, &model, workers, snr);
    }
    if let Some(path) = args.opt("summary-json") {
        if args.opt_or("backend", "native") != "native" {
            return Err(Error::Config(
                "--summary-json runs on the native streaming backend only".into(),
            ));
        }
        // Single-process streaming rollup — byte-identical to what
        // `merge-shards --out` writes for a complete shard set.
        let summary =
            SweepSummary::compute_with(&spec, &model, cimdse::exec::default_workers(), snr);
        std::fs::write(path, summary.to_json_string()? + "\n")?;
        print_sweep_summary(&spec, &summary);
        println!("wrote sweep summary to {path}");
        return Ok(());
    }
    let backend = args.opt_or("backend", "native");

    let evaluated = match backend {
        "pjrt" => {
            let manifest = Manifest::locate()?;
            let engine = AdcModelEngine::load(&manifest)?;
            let eval = PjrtEvaluator::new(engine, model);
            println!("sweeping {} design points on the PJRT artifact...", spec.len());
            run_sweep(&spec, &eval)?
        }
        "native" if tier == SweepTier::Fast => {
            println!(
                "sweeping {} design points natively (fast tier, {} lanes)...",
                spec.len(),
                cimdse::util::fastmath::fast_backend()
            );
            run_sweep_prepared_tier(&spec, &model, cimdse::exec::default_workers(), tier)?
        }
        "native" => {
            let eval = NativeEvaluator::new(model);
            println!("sweeping {} design points natively...", spec.len());
            run_sweep(&spec, &eval)?
        }
        other => return Err(Error::Config(format!("unknown backend `{other}`"))),
    };

    if let Some(ctx) = snr {
        // Tri-objective front over (energy/convert, total area, -SNR):
        // same indices as the streaming `sweep_energy_area_snr_front`
        // (SNR enters negated so every objective minimizes).
        let objectives: Vec<[f64; 3]> = evaluated
            .iter()
            .map(|p| {
                [
                    p.metrics.energy_pj_per_convert,
                    p.metrics.total_area_um2,
                    -ctx.compute_snr_db(p.query.enob),
                ]
            })
            .collect();
        let front = pareto_front_k(&objectives);
        println!(
            "{} points on the energy-area-SNR Pareto front (n_sum {}, cell bits {}):\n",
            front.len(),
            ctx.n_sum,
            ctx.cell_bits
        );
        let mut t = Table::new(vec![
            "ENOB", "total thpt", "tech", "n_adcs", "E/convert", "area", "SNR",
        ]);
        for &i in front.iter().take(args.usize_or("top", 20)?) {
            let p = &evaluated[i];
            t.row(vec![
                format!("{:.1}", p.query.enob),
                fmt_throughput(p.query.total_throughput),
                format!("{} nm", p.query.tech_nm),
                p.query.n_adcs.to_string(),
                fmt_energy_pj(p.metrics.energy_pj_per_convert),
                fmt_area_um2(p.metrics.total_area_um2),
                format!("{:.2} dB", ctx.compute_snr_db(p.query.enob)),
            ]);
        }
        println!("{}", t.render());
    } else {
        // Pareto front over (total power, total area).
        let objectives: Vec<(f64, f64)> = evaluated
            .iter()
            .map(|p| (p.metrics.total_power_w, p.metrics.total_area_um2))
            .collect();
        let front = pareto_front(&objectives);
        println!("{} points on the power-area Pareto front:\n", front.len());
        let mut t = Table::new(vec![
            "ENOB", "total thpt", "tech", "n_adcs", "E/convert", "power", "area",
        ]);
        for &i in front.iter().take(args.usize_or("top", 20)?) {
            let p = &evaluated[i];
            t.row(vec![
                format!("{:.1}", p.query.enob),
                fmt_throughput(p.query.total_throughput),
                format!("{} nm", p.query.tech_nm),
                p.query.n_adcs.to_string(),
                fmt_energy_pj(p.metrics.energy_pj_per_convert),
                fmt_power_w(p.metrics.total_power_w),
                fmt_area_um2(p.metrics.total_area_um2),
            ]);
        }
        println!("{}", t.render());
    }
    if let Some(path) = args.opt("csv") {
        let mut csv = String::from(
            "enob,total_throughput,tech_nm,n_adcs,energy_pj,area_um2,power_w,total_area_um2\n",
        );
        for p in &evaluated {
            csv.push_str(&format!(
                "{},{},{},{},{},{},{},{}\n",
                p.query.enob,
                p.query.total_throughput,
                p.query.tech_nm,
                p.query.n_adcs,
                p.metrics.energy_pj_per_convert,
                p.metrics.area_um2_per_adc,
                p.metrics.total_power_w,
                p.metrics.total_area_um2
            ));
        }
        std::fs::write(path, csv)?;
        println!("wrote sweep CSV to {path}");
    }
    Ok(())
}

fn variant_from_name(name: &str) -> Result<RaellaVariant> {
    match name.to_lowercase().as_str() {
        "s" | "small" => Ok(RaellaVariant::Small),
        "m" | "medium" => Ok(RaellaVariant::Medium),
        "l" | "large" => Ok(RaellaVariant::Large),
        "xl" | "extra-large" => Ok(RaellaVariant::ExtraLarge),
        other => Err(Error::Config(format!("unknown variant `{other}` (s|m|l|xl)"))),
    }
}

fn cmd_estimate(args: &Args) -> Result<()> {
    // The Accelergy-style plug-in query path (adc::plugin).
    let model = fitted_model(args.usize_or("n", 700)?, args.u64_or("seed", 1997)?)?;
    let estimator = cimdse::adc::Estimator::new(model);
    let class = args.opt_or("class", "adc");
    let mut attributes = cimdse::adc::plugin::Attributes::new();
    for key in ["resolution", "enob", "throughput", "total_throughput", "technology", "tech_nm", "n_adcs"] {
        if let Some(v) = args.opt(key) {
            let v: f64 = v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad number `{v}`")))?;
            attributes.insert(key.to_string(), v);
        }
    }
    let energy = estimator.estimate_energy(class, &attributes, "convert")?;
    let area = estimator.estimate_area(class, &attributes)?;
    println!("class `{class}` with {attributes:?}:");
    println!("  energy/convert = {} (accuracy {}%)", fmt_energy_pj(energy.value), energy.accuracy);
    println!("  area per ADC   = {} (accuracy {}%)", fmt_area_um2(area.value), area.accuracy);
    Ok(())
}

/// One row of the accelerator-DSE Pareto table:
/// (config, energy_pj, area_um2, adc_energy_fraction, latency_s).
type AccelRow = (String, f64, f64, f64, f64);

/// The accelerator-DSE Pareto table — shared by `explore` and
/// `query --op accel` so served output cannot drift from the direct
/// subcommand's format.
fn accel_front_table(rows: impl Iterator<Item = AccelRow>) -> Table {
    let mut t = Table::new(vec!["config", "energy", "area", "ADC E%", "latency (ms)"]);
    for (config, energy_pj, area_um2, adc_fraction, latency_s) in rows {
        t.row(vec![
            config,
            fmt_energy_pj(energy_pj),
            fmt_area_um2(area_um2),
            format!("{:.0}%", 100.0 * adc_fraction),
            format!("{:.2}", latency_s * 1e3),
        ]);
    }
    t
}

fn cmd_explore(args: &Args) -> Result<()> {
    use cimdse::dse::accel::{AccelSweepSpec, accel_pareto, run_accel_sweep};
    let model = fitted_model(args.usize_or("n", 700)?, args.u64_or("seed", 1997)?)?;
    let workload = cimdse::workload::zoo::by_name(args.opt_or("workload", "resnet18"))?;
    let spec = AccelSweepSpec::default();
    println!(
        "exploring {} candidate architectures on {}...",
        spec.len(),
        workload.name
    );
    let points = run_accel_sweep(&spec, &model, &workload, cimdse::exec::default_workers())?;
    let mut front: Vec<_> = accel_pareto(&points).iter().map(|&i| &points[i]).collect();
    front.sort_by(|a, b| a.eap.total_cmp(&b.eap));
    let t = accel_front_table(front.iter().take(args.usize_or("top", 12)?).map(|p| {
        (
            p.arch.name.clone(),
            p.energy_pj,
            p.area_um2,
            p.adc_energy_fraction,
            p.latency_s,
        )
    }));
    println!(
        "{} Pareto-optimal configurations (showing best-EAP first):\n{}",
        front.len(),
        t.render()
    );
    Ok(())
}

fn cmd_survey(args: &Args) -> Result<()> {
    let survey = match args.opt("survey-csv") {
        Some(path) => cimdse::survey::load_survey_csv(path)?,
        None => generate_survey(&SurveyConfig {
            n_records: args.usize_or("n", 700)?,
            seed: args.u64_or("seed", 1997)?,
            ..SurveyConfig::default()
        }),
    };
    println!("{} records\n", survey.len());
    println!("{}", cimdse::survey::stats::render_summary(&survey));
    Ok(())
}

fn load_workload(args: &Args) -> Result<cimdse::workload::Workload> {
    if let Some(path) = args.opt("workload-file") {
        return cimdse::workload::zoo::from_toml(&std::fs::read_to_string(path)?);
    }
    cimdse::workload::zoo::by_name(args.opt_or("workload", "resnet18"))
}

fn cmd_map(args: &Args) -> Result<()> {
    let model = fitted_model(args.usize_or("n", 700)?, args.u64_or("seed", 1997)?)?;
    let arch = match args.opt("arch-file") {
        Some(path) => cimdse::arch::from_toml(&std::fs::read_to_string(path)?)?,
        None => raella(variant_from_name(args.opt_or("arch", "m"))?),
    };
    let net = load_workload(args)?;

    if let Some(layer_name) = args.opt("layer") {
        let layer = net
            .layer(layer_name)
            .ok_or_else(|| Error::Config(format!("no layer `{layer_name}` in resnet18")))?;
        let m = cimdse::mapper::map_layer(&arch, layer)?;
        let e = layer_energy(&arch, &model, layer)?;
        println!("{} on {}:", layer.name, arch.name);
        println!("  row chunks    {}", m.row_chunks);
        println!("  cols used     {}", m.cols_used);
        println!("  arrays        {}", m.arrays_used);
        println!("  utilization   {:.3}", m.utilization);
        println!("  ADC converts  {:.3e}", m.counts.adc_converts);
        let lat = cimdse::energy::latency_of_mapping(&arch, &m);
        println!("  latency       {:.3e} s (bottleneck: {})", lat.critical_s(), lat.bottleneck());
        println!("  ADC energy    {}", fmt_energy_pj(e.adc_pj));
        println!(
            "  total energy  {} (ADC {:.0}%)",
            fmt_energy_pj(e.total_pj()),
            100.0 * e.adc_fraction()
        );
        return Ok(());
    }

    println!("{}", figures::per_layer_table(&model, &arch, &net)?.render());
    let total = workload_energy(&arch, &model, &net)?;
    let arrays = cimdse::mapper::arrays_for_workload(&arch, &net.layers);
    let area = accel_area(&arch, &model, AreaScope::Tile { n_arrays: arrays });
    println!(
        "whole-network: energy {} (ADC {:.0}%), area {} over {} arrays (ADC {:.0}%)",
        fmt_energy_pj(total.total_pj()),
        100.0 * total.adc_fraction(),
        fmt_area_um2(area.total_um2()),
        arrays,
        100.0 * area.adc_fraction(),
    );
    Ok(())
}

fn cmd_bench_report(args: &Args) -> Result<()> {
    // CI gate: parse a `BENCH_*.json` perf artifact (bench_util::JsonReport
    // schema), validate its shape, and summarize it. Any structural
    // problem is a hard error so ci.sh fails on missing/malformed output.
    let path = args.require_opt("path")?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("cannot read bench report {path}: {e}")))?;
    let doc = cimdse::config::parse_json(&text)?;
    let schema = doc.require_usize("schema")?;
    if schema != 2 {
        return Err(Error::Config(format!(
            "unsupported bench report schema {schema} (expected 2 — schema 2 added the \
             `tiers` table; regenerate with `cargo bench`)"
        )));
    }
    let bench = doc.require_str("bench")?;
    let cases = match doc.get("cases") {
        Some(cimdse::config::Value::Table(map)) if !map.is_empty() => map,
        _ => return Err(Error::Config("bench report has no `cases` table".into())),
    };
    // Schema 2: the artifact must say which numeric tier each backend
    // resolved to, so perf numbers are comparable across hosts.
    let tiers = match doc.get("tiers") {
        Some(cimdse::config::Value::Table(map)) if !map.is_empty() => map,
        _ => return Err(Error::Config("bench report has no `tiers` table (schema 2)".into())),
    };
    for key in ["exact", "fast"] {
        if tiers.get(key).and_then(cimdse::config::Value::as_str).is_none() {
            return Err(Error::Config(format!(
                "bench report `tiers` table lacks a string `{key}` entry"
            )));
        }
    }
    let mut t = Table::new(vec!["case", "median", "Mpts/s", "points"]);
    for (name, case) in cases {
        let median = case.require_f64("median_s")?;
        if !(median.is_finite() && median > 0.0) {
            return Err(Error::Config(format!("case `{name}`: bad median_s {median}")));
        }
        t.row(vec![
            name.clone(),
            cimdse::bench_util::fmt_secs(median),
            match case.get("mpts_per_s").and_then(cimdse::config::Value::as_f64) {
                Some(v) => format!("{v:.2}"),
                None => "-".into(),
            },
            match case.get("points").and_then(cimdse::config::Value::as_f64) {
                Some(v) => format!("{v:.0}"),
                None => "-".into(),
            },
        ]);
    }
    println!(
        "bench `{bench}` (quick={}, {} workers): {} cases",
        doc.get("quick").and_then(cimdse::config::Value::as_bool).unwrap_or(false),
        doc.require_f64("workers")? as usize,
        cases.len()
    );
    println!(
        "tiers: exact={} fast={}",
        tiers.get("exact").and_then(cimdse::config::Value::as_str).unwrap_or("?"),
        tiers.get("fast").and_then(cimdse::config::Value::as_str).unwrap_or("?")
    );
    println!("{}", t.render());
    if let Some(cimdse::config::Value::Table(derived)) = doc.get("derived") {
        for (name, v) in derived {
            let x = v.as_f64().ok_or_else(|| {
                Error::Config(format!("derived metric `{name}` is not a number"))
            })?;
            if !x.is_finite() {
                return Err(Error::Config(format!("derived metric `{name}` is {x}")));
            }
            println!("  {name} = {x:.3}");
        }
    }
    if bench == "serve" {
        // The serve bench must carry histogram-derived latency quantiles
        // (one p50/p99 pair per core) so latency regressions gate CI, not
        // just throughput.
        let derived = match doc.get("derived") {
            Some(cimdse::config::Value::Table(map)) => map.clone(),
            _ => Default::default(),
        };
        for prefix in ["latency_p50_s_", "latency_p99_s_"] {
            if !derived.keys().any(|k| k.starts_with(prefix)) {
                return Err(Error::Config(format!(
                    "serve bench report lacks a `{prefix}*` derived metric \
                     (regenerate with `cargo bench --bench bench_serve`)"
                )));
            }
        }
    }
    println!("bench report ok: {path}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::io::Write as _;
    let n = args.usize_or("n", 700)?;
    let seed = args.u64_or("seed", 1997)?;
    let cache = args.usize_or("cache", 32)?;
    if cache == 0 {
        return Err(Error::Config("--cache must be >= 1".into()));
    }
    let max_sweep_points = match args.opt("max-sweep-points") {
        None => None,
        Some(_) => {
            let budget = args.usize_or("max-sweep-points", 0)?;
            if budget == 0 {
                return Err(Error::Config(
                    "--max-sweep-points must be >= 1 (omit the flag for no budget)".into(),
                ));
            }
            Some(budget)
        }
    };
    // Same default fit as `model`/`sweep`, so served responses diff
    // cleanly against the direct subcommands.
    let model = fitted_model(n, seed)?;
    let core: cimdse::service::ServeCore = args.opt_or("core", "event-loop").parse()?;
    let progress_every = match args.opt("progress-every") {
        None => None,
        Some(_) => {
            let every = args.usize_or("progress-every", 0)?;
            if every == 0 {
                return Err(Error::Config(
                    "--progress-every must be >= 1 (omit the flag to disable progress frames)"
                        .into(),
                ));
            }
            Some(every)
        }
    };
    let options = cimdse::service::ServeOptions {
        addr: args.opt_or("addr", "127.0.0.1:0").to_string(),
        model,
        cache_capacity: cache,
        workers: cimdse::exec::default_workers(),
        max_sweep_points,
        core,
        progress_every,
    };
    let workers = options.workers;
    let budget = match max_sweep_points {
        Some(b) => format!(", budget {b} pts"),
        None => String::new(),
    };
    let core_tag = match core {
        cimdse::service::ServeCore::EventLoop => "event-loop",
        cimdse::service::ServeCore::Threads => "threads",
    };
    let server = cimdse::service::Server::bind(options)?;
    if let Some(path) = args.opt("trace-out") {
        // Label events with the actual bound address (ephemeral ports
        // resolve here), so a fleet's per-worker traces concatenate into
        // one forest with distinguishable processes.
        cimdse::obs::init_file(path, &server.local_addr().to_string())?;
        println!("cimdse serve: tracing spans to {path}");
    }
    println!(
        "cimdse serve: listening on {} ({core_tag} core, {workers} workers, cache {cache}, \
         model fit n={n} seed={seed}{budget})",
        server.local_addr()
    );
    // Scripts poll stdout for the line above; don't let it sit in the
    // pipe buffer.
    std::io::stdout().flush()?;
    server.serve()?;
    println!("cimdse serve: drained cleanly");
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    use cimdse::config::Value;
    let addr = args.require_opt("addr")?;
    let op = args.opt_or("op", "metrics");
    let mut client = cimdse::service::Client::connect(addr)?;
    match op {
        "eval" => {
            let query = AdcQuery {
                enob: args.f64_or("enob", 8.0)?,
                total_throughput: args.f64_or("throughput", 1e9)?,
                tech_nm: args.f64_or("tech", 32.0)?,
                n_adcs: n_adcs_arg(args)?,
            };
            query.validate()?;
            // bits=true: the response floats travel as IEEE-754 bit-hex,
            // so what we print is exactly what the server computed.
            let result = client.eval(&query, None, true)?;
            let point = result
                .get("points")
                .and_then(Value::as_array)
                .and_then(<[Value]>::first)
                .ok_or_else(|| Error::Runtime("query: eval result has no points".into()))?;
            let metrics = cimdse::service::protocol::metrics_from_value(
                point
                    .get("metrics")
                    .ok_or_else(|| Error::Runtime("query: point lacks `metrics`".into()))?,
            )
            .map_err(|r| Error::Runtime(format!("query: bad metrics payload: {}", r.message)))?;
            let crossover = cimdse::service::protocol::flex_f64(
                point.get("crossover_throughput").ok_or_else(|| {
                    Error::Runtime("query: point lacks `crossover_throughput`".into())
                })?,
                "crossover_throughput",
            )
            .map_err(|r| Error::Runtime(format!("query: bad crossover: {}", r.message)))?;
            print_model_point(&query, &metrics, crossover);
        }
        "sweep" => {
            let spec = sweep_spec_from_args(args)?;
            let snr = snr_context_from_args(args)?;
            let (_result, summary) = client.sweep_with(&spec, None, snr.as_ref())?;
            print_sweep_summary(&spec, &summary);
            if let Some(path) = args.opt("out") {
                // Canonical summary JSON — byte-identical to what
                // `cimdse sweep --summary-json` writes for the same spec
                // and model (ci.sh cmp's the two files).
                std::fs::write(path, summary.to_json_string()? + "\n")?;
                println!("wrote served sweep summary to {path}");
            }
        }
        "accel" => {
            let result = client.accel(args.opt_or("workload", "resnet18"), None)?;
            let front = result
                .get("front")
                .and_then(Value::as_array)
                .ok_or_else(|| Error::Runtime("query: accel result lacks `front`".into()))?;
            let rows = front
                .iter()
                .take(args.usize_or("top", 12)?)
                .map(|p| {
                    Ok((
                        p.require_str("config")?.to_string(),
                        p.require_f64("energy_pj")?,
                        p.require_f64("area_um2")?,
                        p.require_f64("adc_energy_fraction")?,
                        p.require_f64("latency_s")?,
                    ))
                })
                .collect::<Result<Vec<AccelRow>>>()?;
            println!(
                "{} on {}: {} candidates, {} Pareto-optimal (best-EAP first):\n{}",
                result.require_str("workload")?,
                addr,
                result.require_f64("candidates")? as usize,
                front.len(),
                accel_front_table(rows.into_iter()).render()
            );
        }
        "metrics" => {
            let snapshot = client.metrics()?;
            match args.opt_or("format", "text") {
                "text" => print!("{}", cimdse::service::ServiceMetrics::render(&snapshot)?),
                "prometheus" => print!(
                    "{}",
                    cimdse::service::ServiceMetrics::render_prometheus(&snapshot)?
                ),
                other => {
                    return Err(Error::Config(format!(
                        "unknown metrics format `{other}` (text|prometheus)"
                    )));
                }
            }
        }
        "shutdown" => {
            client.shutdown()?;
            println!("server draining");
        }
        other => {
            return Err(Error::Config(format!(
                "unknown query op `{other}` (eval|sweep|accel|metrics|shutdown)"
            )));
        }
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let model = fitted_model(args.usize_or("n", 700)?, args.u64_or("seed", 1997)?)?;
    let survey = generate_survey(&SurveyConfig::default());
    let which = args.opt_or("fig", "all");

    if which == "2" || which == "all" {
        let d = figures::fig2(&survey, &model, 40);
        println!(
            "{}",
            figures::render_fig23(
                &d,
                "Fig. 2: ADC throughput vs energy (32 nm)",
                "energy (pJ/convert)"
            )
        );
    }
    if which == "3" || which == "all" {
        let d = figures::fig3(&survey, &model, 40);
        println!(
            "{}",
            figures::render_fig23(&d, "Fig. 3: ADC throughput vs area (32 nm)", "area (µm²)")
        );
    }
    if which == "4" || which == "all" {
        println!("Fig. 4: RAELLA S/M/L/XL energy on ResNet18 layer groups");
        println!("{}", figures::render_fig4(&figures::fig4(&model)?).render());
    }
    if which == "5" || which == "all" {
        println!("Fig. 5: EAP vs number of ADCs for varying total throughput");
        println!("{}", figures::render_fig5(&figures::fig5(&model, 5)?).render());
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    // Accept the file as a positional (`cimdse trace FILE`) or --path;
    // several files' worth of NDJSON may be concatenated into one (the
    // fleet case: launcher + per-worker traces).
    let positionals = args.positionals();
    let path = positionals
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("path"))
        .ok_or_else(|| {
            Error::Config("trace needs an NDJSON trace file (cimdse trace FILE)".into())
        })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("cannot read trace file {path}: {e}")))?;
    let events = cimdse::obs::analyze::parse_trace(&text)?;
    print!("{}", cimdse::obs::analyze::render_report(&events));
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = args
        .positionals()
        .first()
        .map(String::as_str)
        .unwrap_or(".");
    let report = cimdse::lint::lint_root(std::path::Path::new(root))?;
    if args.flag("json") {
        println!(
            "{}",
            cimdse::lint::report::to_json_value(&report).to_json_string()?
        );
    } else {
        print!("{}", cimdse::lint::report::render_text(&report));
    }
    if report.findings.is_empty() {
        Ok(())
    } else {
        Err(Error::Config(format!(
            "lint: {} finding(s) in {root}",
            report.findings.len()
        )))
    }
}
