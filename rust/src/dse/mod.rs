//! Design-space exploration engine.
//!
//! Generates design-point grids ([`sweep`]), evaluates them through either
//! the native Rust model (threaded) or the AOT-compiled PJRT artifact
//! ([`Evaluator`]), extracts Pareto fronts ([`pareto`]), and regenerates
//! the paper's figures ([`figures`]).

pub mod accel;
pub mod figures;
pub mod pareto;
pub mod sweep;

pub use accel::{AccelPoint, AccelSweepSpec, run_accel_sweep};
pub use pareto::pareto_front;
pub use sweep::SweepSpec;

use crate::adc::{AdcMetrics, AdcModel, AdcQuery};
use crate::error::Result;
use crate::exec::parallel_chunks;
use crate::runtime::AdcModelEngine;

/// A design-point evaluator: queries in, ADC metrics out.
pub trait Evaluator {
    /// Evaluate a batch of queries.
    fn eval(&self, queries: &[AdcQuery]) -> Result<Vec<AdcMetrics>>;

    /// Human-readable backend name.
    fn backend_name(&self) -> &'static str;
}

/// Native Rust evaluation, threaded across `workers`.
pub struct NativeEvaluator {
    /// The model to evaluate.
    pub model: AdcModel,
    /// Worker thread count (1 = serial).
    pub workers: usize,
    /// Chunk size per dispatch (amortizes thread hand-off).
    pub chunk: usize,
}

impl NativeEvaluator {
    /// Evaluator with sensible defaults.
    pub fn new(model: AdcModel) -> Self {
        NativeEvaluator { model, workers: crate::exec::default_workers(), chunk: 4096 }
    }

    /// Serial evaluator (useful for micro-benchmarks).
    pub fn serial(model: AdcModel) -> Self {
        NativeEvaluator { model, workers: 1, chunk: usize::MAX }
    }
}

impl Evaluator for NativeEvaluator {
    fn eval(&self, queries: &[AdcQuery]) -> Result<Vec<AdcMetrics>> {
        let chunk = self.chunk.min(queries.len().max(1));
        Ok(parallel_chunks(queries, chunk, self.workers, |qs| {
            qs.iter().map(|q| self.model.eval(q)).collect()
        }))
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

/// PJRT evaluation through the compiled `adc_model.hlo.txt` artifact.
///
/// Tuned models ride through via [`AdcModel::folded_coefficients`]. The
/// PJRT client is single-threaded here; batching (the artifact's 4096
/// design points per execute) is what amortizes dispatch.
pub struct PjrtEvaluator {
    engine: AdcModelEngine,
    model: AdcModel,
}

impl PjrtEvaluator {
    /// Wrap a compiled engine and the model whose coefficients to use.
    pub fn new(engine: AdcModelEngine, model: AdcModel) -> Self {
        PjrtEvaluator { engine, model }
    }
}

impl Evaluator for PjrtEvaluator {
    fn eval(&self, queries: &[AdcQuery]) -> Result<Vec<AdcMetrics>> {
        self.engine.eval(queries, &self.model.folded_coefficients())
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }
}

/// One evaluated design point.
#[derive(Clone, Copy, Debug)]
pub struct EvaluatedPoint {
    /// The query.
    pub query: AdcQuery,
    /// The model's outputs.
    pub metrics: AdcMetrics,
}

/// Evaluate a whole sweep.
pub fn run_sweep(spec: &SweepSpec, evaluator: &dyn Evaluator) -> Result<Vec<EvaluatedPoint>> {
    let queries = spec.points();
    let metrics = evaluator.eval(&queries)?;
    Ok(queries
        .into_iter()
        .zip(metrics)
        .map(|(query, metrics)| EvaluatedPoint { query, metrics })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_parallel_matches_serial() {
        let model = AdcModel::default();
        let spec = SweepSpec {
            enobs: vec![4.0, 8.0, 12.0],
            total_throughputs: vec![1e6, 1e8, 1e10],
            tech_nms: vec![16.0, 32.0],
            n_adcs: vec![1, 4],
        };
        let serial = run_sweep(&spec, &NativeEvaluator::serial(model)).unwrap();
        let par = run_sweep(&spec, &NativeEvaluator::new(model)).unwrap();
        assert_eq!(serial.len(), 3 * 3 * 2 * 2);
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.metrics, b.metrics);
        }
    }

    #[test]
    fn evaluated_points_preserve_query_order() {
        let spec = SweepSpec {
            enobs: vec![4.0, 8.0],
            total_throughputs: vec![1e8],
            tech_nms: vec![32.0],
            n_adcs: vec![1],
        };
        let out = run_sweep(&spec, &NativeEvaluator::serial(AdcModel::default())).unwrap();
        assert_eq!(out[0].query.enob, 4.0);
        assert_eq!(out[1].query.enob, 8.0);
    }
}
